"""Render EXPERIMENTS.md tables from results/dryrun.json, and diff
BENCH_<stamp>.json perf records.

Usage: PYTHONPATH=src python -m benchmarks.report [path]
       PYTHONPATH=src python -m benchmarks.report diff OLD.json NEW.json
The first form prints markdown for S Dry-run and S Roofline; the second
compares two `benchmarks/run.py --json` records with a % regression
column (positive = NEW is slower).
"""
import json
import sys

import jax
import numpy as np


def _model_flops_ratio(r):
    """MODEL_FLOPS / HLO_FLOPs for the cell (see launch/roofline.py)."""
    from repro.configs import SHAPES, get_config
    from repro.launch.roofline import count_params, model_flops
    if r["arch"].startswith("ising"):
        # minimal spin-update work: ~10 flops per spin flip decision
        useful = 10.0 * r.get("spins", 0) / r["chips"]
        return useful / r["flops"] if r.get("flops") else None
    cfg = get_config(r["arch"])
    shape = SHAPES[r["shape"]]
    params = jax.eval_shape(
        lambda k: __import__("repro.models", fromlist=["init_model"])
        .init_model(cfg, k), jax.random.PRNGKey(0))
    frac = (cfg.top_k / cfg.n_routed) if cfg.moe else 1.0
    counts = count_params(params, active_moe_frac=frac)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        mf = model_flops(counts["active"], tokens, "train")
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        mf = model_flops(counts["active"], tokens, "fwd")
    else:
        mf = model_flops(counts["active"], shape.global_batch, "fwd")
    return (mf / r["chips"]) / r["flops"] if r.get("flops") else None


def diff(old_path, new_path):
    """Markdown diff of two BENCH_<stamp>.json records by row name.

    When the NEW record was a filtered run (``--only``/``--engines`` in
    its meta), baseline rows outside the filter were never attempted --
    they are skipped rather than reported as "removed", so the CI smoke
    subset diffs cleanly against a full committed baseline.
    """
    with open(old_path) as f:
        old = json.load(f)
    with open(new_path) as f:
        new = json.load(f)
    old_rows = {r["name"]: r for r in old["rows"]}
    new_rows = {r["name"]: r for r in new["rows"]}
    filtered = bool(new["meta"].get("only") or new["meta"].get("engines"))
    print(f"### Bench diff — {old['meta'].get('stamp', old_path)} → "
          f"{new['meta'].get('stamp', new_path)}"
          + (" (filtered run: unselected baseline rows skipped)"
             if filtered else "") + "\n")
    print("| bench | old us/call | new us/call | Δ% | old flips/ns |"
          " new flips/ns |")
    print("|---|---|---|---|---|---|")
    for name in sorted(set(old_rows) | set(new_rows)):
        o, n = old_rows.get(name), new_rows.get(name)
        if n is None and filtered:
            continue
        if o is None or n is None:
            status = "added" if o is None else "removed"
            ou = "-" if o is None else f"{o['us_per_call']:.1f}"
            nu = "-" if n is None else f"{n['us_per_call']:.1f}"
            print(f"| {name} ({status}) | {ou} | {nu} | - | - | - |")
            continue
        ou, nu = o["us_per_call"], n["us_per_call"]
        pct = (nu - ou) / ou * 100.0 if ou else float("nan")
        of = o["derived"].get("flips_per_ns", "-")
        nf = n["derived"].get("flips_per_ns", "-")
        print(f"| {name} | {ou:.1f} | {nu:.1f} | {pct:+.1f}% | {of} |"
              f" {nf} |")


def main(path="results/dryrun.json"):
    with open(path) as f:
        cells = json.load(f)
    cells.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))

    print("### Dry-run status (all cells)\n")
    print("| arch | shape | mesh | status | compile_s | HLO GFLOPs/dev |"
          " HLO GB/dev | coll MB/dev | temp GB/dev |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in cells:
        if r["status"] == "skipped":
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | SKIP:"
                  f" {r['skip_reason'][:48]} | | | | | |")
            continue
        mem = r.get("memory") or {}
        temp = mem.get("temp_size_in_bytes", 0) / 1e9
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['status']}"
              f" | {r.get('compile_s','')} | {r.get('flops',0)/1e9:.1f}"
              f" | {r.get('bytes',0)/1e9:.2f}"
              f" | {r.get('coll_bytes',0)/1e6:.1f} | {temp:.2f} |")

    print("\n### Roofline terms (per device, single-pod 16x16 unless noted)\n")
    print("| arch | shape | mesh | t_compute s | t_memory s |"
          " t_collective s | dominant | MODEL/HLO flops |")
    print("|---|---|---|---|---|---|---|---|")
    for r in cells:
        if r["status"] != "ok":
            continue
        try:
            ratio = _model_flops_ratio(r)
            ratio_s = f"{ratio:.3f}" if ratio is not None else "-"
        except Exception:
            ratio_s = "-"
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']}"
              f" | {r['t_compute_s']:.4f} | {r['t_memory_s']:.4f}"
              f" | {r['t_collective_s']:.4f} | **{r['dominant']}**"
              f" | {ratio_s} |")


if __name__ == "__main__":
    if len(sys.argv) > 1 and sys.argv[1] == "diff":
        diff(*sys.argv[2:])
    else:
        main(*sys.argv[1:])
