"""Render EXPERIMENTS.md tables from results/dryrun.json, diff
BENCH_<stamp>.json perf records, and render the bench trend report.

Usage: PYTHONPATH=src python -m benchmarks.report [path]
       PYTHONPATH=src python -m benchmarks.report diff OLD.json NEW.json
       PYTHONPATH=src python -m benchmarks.report trend [DIR_OR_FILE...] \
           [--csv out.csv]

The first form prints markdown for S Dry-run and S Roofline; ``diff``
compares two `benchmarks/run.py --json` records with a % regression
column (positive = NEW is slower) and prints a warning line per row
slower than ``--warn-threshold`` (default the legacy 25%); ``trend``
(also spelled ``--trend``) renders the accumulated BENCH history --
default search path ``benchmarks/`` + ``results/`` -- into a per-engine
flips/ns timeline (markdown to stdout, long-format CSV with ``--csv``).
"""
import argparse
import glob
import json
import os
import sys

import jax


def _model_flops_ratio(r):
    """MODEL_FLOPS / HLO_FLOPs for the cell (see launch/roofline.py)."""
    from repro.configs import SHAPES, get_config
    from repro.launch.roofline import count_params, flip_cost, model_flops
    if r["arch"].startswith("ising"):
        # useful work per attempted flip from the per-engine flip-cost
        # model (launch/roofline.py), replacing the old flat 10 flops
        engine = (r["arch"].split("-", 1)[1] if "-" in r["arch"]
                  else "multispin")
        cost = flip_cost(engine)
        useful = (cost.flops_per_flip * cost.replicas
                  * r.get("spins", 0) / r["chips"])
        return useful / r["flops"] if r.get("flops") else None
    cfg = get_config(r["arch"])
    shape = SHAPES[r["shape"]]
    params = jax.eval_shape(
        lambda k: __import__("repro.models", fromlist=["init_model"])
        .init_model(cfg, k), jax.random.PRNGKey(0))
    frac = (cfg.top_k / cfg.n_routed) if cfg.moe else 1.0
    counts = count_params(params, active_moe_frac=frac)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        mf = model_flops(counts["active"], tokens, "train")
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        mf = model_flops(counts["active"], tokens, "fwd")
    else:
        mf = model_flops(counts["active"], shape.global_batch, "fwd")
    return (mf / r["chips"]) / r["flops"] if r.get("flops") else None


def _row_us(row):
    """Timing of one bench row, tolerating both formats: the noise-model
    median when recorded, else the legacy single mean."""
    if "n_trials" in row:
        return float(row["median_us_per_call"])
    return float(row["us_per_call"])


def diff(old_path, new_path, warn_threshold=0.25):
    """Markdown diff of two BENCH_<stamp>.json records by row name.

    When the NEW record was a filtered run (``--only``/``--engines`` in
    its meta), baseline rows outside the filter were never attempted --
    they are skipped rather than reported as "removed", so the CI smoke
    subset diffs cleanly against a full committed baseline.

    Rows more than ``warn_threshold`` slower additionally print a
    ``# WARNING`` line (the legacy flat check; the statistical gate is
    ``python -m repro.perf.gate``).  Returns ``{"rows": [...],
    "warnings": [names]}`` so the logic is testable.
    """
    with open(old_path) as f:
        old = json.load(f)
    with open(new_path) as f:
        new = json.load(f)
    old_rows = {r["name"]: r for r in old["rows"]}
    new_rows = {r["name"]: r for r in new["rows"]}
    filtered = bool(new["meta"].get("only") or new["meta"].get("engines"))
    print(f"### Bench diff — {old['meta'].get('stamp', old_path)} → "
          f"{new['meta'].get('stamp', new_path)}"
          + (" (filtered run: unselected baseline rows skipped)"
             if filtered else "") + "\n")
    print("| bench | old us/call | new us/call | Δ% | old flips/ns |"
          " new flips/ns |")
    print("|---|---|---|---|---|---|")
    out = {"rows": [], "warnings": []}
    for name in sorted(set(old_rows) | set(new_rows)):
        o, n = old_rows.get(name), new_rows.get(name)
        if n is None and filtered:
            continue
        if o is None or n is None:
            status = "added" if o is None else "removed"
            ou = "-" if o is None else f"{_row_us(o):.1f}"
            nu = "-" if n is None else f"{_row_us(n):.1f}"
            print(f"| {name} ({status}) | {ou} | {nu} | - | - | - |")
            out["rows"].append({"name": name, "status": status})
            continue
        ou, nu = _row_us(o), _row_us(n)
        pct = (nu - ou) / ou * 100.0 if ou else float("nan")
        of = o["derived"].get("flips_per_ns", "-")
        nf = n["derived"].get("flips_per_ns", "-")
        print(f"| {name} | {ou:.1f} | {nu:.1f} | {pct:+.1f}% | {of} |"
              f" {nf} |")
        out["rows"].append({"name": name, "status": "both",
                            "old_us": ou, "new_us": nu, "pct": pct})
        if ou and nu / ou > 1.0 + warn_threshold:
            out["warnings"].append(name)
    for name in out["warnings"]:
        print(f"# WARNING: {name} more than "
              f"{warn_threshold:.0%} slower than baseline")
    return out


# ---------------------------------------------------------------------------
# trend: the accumulated BENCH history as a per-engine flips/ns timeline
# ---------------------------------------------------------------------------

def _collect_records(paths):
    """BENCH records from files/dirs, sorted by meta stamp."""
    files = []
    for p in paths:
        if os.path.isdir(p):
            files.extend(sorted(glob.glob(os.path.join(p,
                                                       "BENCH_*.json"))))
        else:
            files.append(p)
    records = []
    seen = set()
    for path in files:
        real = os.path.realpath(path)
        if real in seen:
            continue
        seen.add(real)
        with open(path) as f:
            records.append((path, json.load(f)))
    records.sort(key=lambda t: str(t[1].get("meta", {}).get("stamp", "")))
    return records


def trend(paths=("benchmarks", "results"), csv_path=None):
    """Per-engine flips/ns timeline over the accumulated BENCH history.

    Markdown to stdout: one line per bench row that carries a
    throughput metric, one column per record (ordered by stamp), plus
    the first→last Δ%.  ``csv_path`` additionally writes the long-form
    CSV (one line per (stamp, row)) CI uploads as an artifact.
    Returns ``{"stamps": [...], "series": {name: {stamp: value}}}``.
    """
    from repro.perf.gate import throughput
    records = _collect_records(paths)
    stamps, engines, series, pcts = [], {}, {}, {}
    csv_lines = ["stamp,backend,name,engine,metric,value_flips_per_ns,"
                 "median_us_per_call,n_trials,pct_of_roofline"]
    for path, rec in records:
        meta = rec.get("meta", {})
        stamp = str(meta.get("stamp", os.path.basename(path)))
        stamps.append(stamp)
        for row in rec.get("rows", []):
            key, v = throughput(row)
            if v is None:
                continue
            name = row["name"]
            series.setdefault(name, {})[stamp] = v
            # newer records may add the engine tag rows in the oldest
            # baseline predate -- any tagged record labels the series
            eng = row["derived"].get("engine")
            if eng:
                engines[name] = eng
            else:
                engines.setdefault(name, "-")
            pct = row["derived"].get("pct_of_roofline", "")
            pcts.setdefault(name, {})[stamp] = pct
            med = (row.get("median_us_per_call", row["us_per_call"]))
            csv_lines.append(
                f"{stamp},{meta.get('backend', '-')},{name},"
                f"{row['derived'].get('engine', '-')},{key},{v},"
                f"{med},{row.get('n_trials', 1)},{pct}")
    print(f"### Bench trend — flips/ns over {len(records)} records\n")
    if len(records) < 2:
        print(f"(only {len(records)} BENCH record(s) found under "
              f"{list(paths)} — commit or generate more to see a trend)\n")
    header = "| engine | bench row | " + " | ".join(stamps) \
        + " | Δ% first→last |"
    print(header)
    print("|" + "---|" * (len(stamps) + 3))
    for name in sorted(series,
                       key=lambda n: (engines.get(n, "-"), n)):
        vals = [series[name].get(s) for s in stamps]
        cells = ["-" if v is None else f"{v:.4f}" for v in vals]
        present = [v for v in vals if v is not None]
        if len(present) >= 2 and present[0]:
            delta = (present[-1] - present[0]) / present[0] * 100.0
            dcell = f"{delta:+.1f}%"
        else:
            dcell = "-"
        print(f"| {engines.get(name, '-')} | {name} | "
              + " | ".join(cells) + f" | {dcell} |")
    if csv_path:
        os.makedirs(os.path.dirname(csv_path) or ".", exist_ok=True)
        with open(csv_path, "w") as f:
            f.write("\n".join(csv_lines) + "\n")
        print(f"\n(csv: {csv_path})")
    return {"stamps": stamps, "series": series}


def main(path="results/dryrun.json"):
    with open(path) as f:
        cells = json.load(f)
    cells.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))

    print("### Dry-run status (all cells)\n")
    print("| arch | shape | mesh | status | compile_s | HLO GFLOPs/dev |"
          " HLO GB/dev | coll MB/dev | temp GB/dev |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in cells:
        if r["status"] == "skipped":
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | SKIP:"
                  f" {r['skip_reason'][:48]} | | | | | |")
            continue
        mem = r.get("memory") or {}
        temp = mem.get("temp_size_in_bytes", 0) / 1e9
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['status']}"
              f" | {r.get('compile_s','')} | {r.get('flops',0)/1e9:.1f}"
              f" | {r.get('bytes',0)/1e9:.2f}"
              f" | {r.get('coll_bytes',0)/1e6:.1f} | {temp:.2f} |")

    print("\n### Roofline terms (per device, single-pod 16x16 unless noted)\n")
    print("| arch | shape | mesh | t_compute s | t_memory s |"
          " t_collective s | dominant | MODEL/HLO flops |")
    print("|---|---|---|---|---|---|---|---|")
    for r in cells:
        if r["status"] != "ok":
            continue
        try:
            ratio = _model_flops_ratio(r)
            ratio_s = f"{ratio:.3f}" if ratio is not None else "-"
        except Exception:
            ratio_s = "-"
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']}"
              f" | {r['t_compute_s']:.4f} | {r['t_memory_s']:.4f}"
              f" | {r['t_collective_s']:.4f} | **{r['dominant']}**"
              f" | {ratio_s} |")


def cli(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    # legacy spellings: `report.py diff A B`, `report.py [dryrun.json]`,
    # plus the `--trend` flag form the issue tracker asked for
    if argv and argv[0] == "--trend":
        argv[0] = "trend"
    if argv and argv[0] == "diff":
        ap = argparse.ArgumentParser(prog="benchmarks.report diff")
        ap.add_argument("old")
        ap.add_argument("new")
        ap.add_argument("--warn-threshold", type=float, default=0.25)
        args = ap.parse_args(argv[1:])
        diff(args.old, args.new, warn_threshold=args.warn_threshold)
        return 0
    if argv and argv[0] == "trend":
        ap = argparse.ArgumentParser(prog="benchmarks.report trend")
        ap.add_argument("paths", nargs="*",
                        default=["benchmarks", "results"],
                        help="BENCH_*.json files or directories "
                             "containing them (default: benchmarks/ "
                             "and results/)")
        ap.add_argument("--csv", default=None,
                        help="also write the long-form CSV here")
        args = ap.parse_args(argv[1:])
        trend(args.paths or ["benchmarks", "results"],
              csv_path=args.csv)
        return 0
    main(*argv)
    return 0


if __name__ == "__main__":
    raise SystemExit(cli())
