import os
# 8 host devices so the scaling benches (paper Tables 3-5) run multi-device
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")
"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  ``derived`` is flips/ns
(the paper's metric) for engine benches, or the relevant table quantity.

IMPORTANT CONTEXT: this container executes on ONE CPU core -- absolute
flips/ns are not comparable to the paper's V100 numbers.  What the harness
preserves is the *structure* of every paper table (same engines, same
sweeps, same scaling axes); on TPU hardware the same functions produce the
paper-comparable numbers.  The roofline table (from the dry-run artifacts)
is the hardware-independent performance evidence -- see EXPERIMENTS.md.
"""
import argparse
import collections
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.telemetry as tel

# set by --trials: overrides every bench's iter count so each row gets
# an n_trials-deep timing sample (median + IQR -- the noise model the
# perf gate needs; EXPERIMENTS.md S Perf-gate)
_TRIALS = None

#: one timing measurement: steady-state mean/samples, the separately
#: timed first warmup call (compile + run -- so first-dispatch cost
#: never contaminates single-trial medians), and the MEASURED dispatch
#: count per timed call (telemetry counter delta; 0.0 for benches that
#: bypass the instrumented engine/session wrappers, e.g. raw kernels)
Timed = collections.namedtuple(
    "Timed", ["mean_s", "out", "times_s", "compile_s", "dispatches"])


def _timeit(fn, *args, iters=3, warmup=1, label=None):
    """Time ``fn(*args)`` -> :class:`Timed` (device-complete walls)."""
    iters = _TRIALS or iters
    compile_s = None
    for i in range(warmup):
        # first call pays XLA compilation: timed apart under its own span
        with tel.span("bench.warmup", label=label, first=i == 0):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            dt = time.perf_counter() - t0
        if i == 0:
            compile_s = dt
    times = []
    d0 = tel.DISPATCHES.value  # warmup dispatches excluded
    for _ in range(iters):
        with tel.span("bench.trial", label=label):
            t0 = time.perf_counter()
            out = jax.block_until_ready(fn(*args))
            times.append(time.perf_counter() - t0)
    dispatches = (tel.DISPATCHES.value - d0) / iters
    return Timed(sum(times) / len(times), out, times, compile_s,
                 dispatches)


# set in main(): a repro.analysis.RunRecorder; rows accumulate so --json
# can write a BENCH_<stamp>.json perf record (EXPERIMENTS.md S Bench)
_RECORDER = None


def _row(name, us, derived, engine=None, k=1, times=None, timed=None):
    """One bench row.  ``engine`` attributes the row to a registry
    engine: the flips/ns measurement gains ``pct_of_roofline`` for the
    backend it ran on (``launch/roofline.py`` flip-cost model) and an
    ``engine=`` tag the trend report groups by.  ``k`` is the resident
    tier's sweeps/dispatch (divides the model's HBM bytes/flip).
    ``timed`` (a :class:`Timed`) adds the noise-model fields plus the
    compile/steady split (``compile_ms``) and the MEASURED per-call
    dispatch count (omitted when 0: the bench bypassed the
    instrumented wrappers, so no honest count exists); single-shot
    rows stay in the legacy format."""
    from repro.analysis.recorder import parse_derived
    d = parse_derived(derived)
    if timed is not None:
        times = timed.times_s
        if timed.compile_s is not None:
            d["compile_ms"] = round(timed.compile_s * 1e3, 3)
        if timed.dispatches:
            d["dispatches"] = timed.dispatches
    if engine is not None:
        from repro.launch import roofline as rl
        d["engine"] = engine
        metric = d.get("replica_flips_per_ns", d.get("flips_per_ns"))
        if isinstance(metric, float):
            pct = rl.pct_of_roofline(metric, engine,
                                     jax.default_backend(), k=k)
            if pct is not None:
                d["pct_of_roofline"] = round(pct, 4)
    if _RECORDER is None:  # bench called directly, outside main()
        extras = ";".join(f"{k_}={v}" for k_, v in d.items())
        print(f"{name},{us:.1f},{extras}")
        return
    _RECORDER.record(name, us,
                     times_us=[t * 1e6 for t in times] if times else None,
                     **d)


# ---------------------------------------------------------------------------
# Table 1: single-device engine comparison, driven through the registry --
# every registered engine is benchmarked with the same (init, sweep) calls
# ---------------------------------------------------------------------------

# wolff excluded: a "sweep" (one cluster flip) is not comparable in
# flips/ns; spinglass/stencil run but have no paper column (EXPERIMENTS.md)
T1_ENGINES = ("basic", "basic_philox", "multispin", "tensorcore",
              "stencil_pallas", "spinglass", "bitplane")

# set in main() by --engines: restricts engine benches to a name subset
_ENGINE_FILTER = ()


def _engine_selected(name):
    return not _ENGINE_FILTER or name in _ENGINE_FILTER


def _rebind_stepper(advance, state):
    """Timing closure that REBINDS the state each call: the sweep paths
    donate their state buffers (EXPERIMENTS.md H1.8), so reusing a
    passed-in buffer across timed calls would hit a deleted array."""
    box = [state]

    def step():
        box[0] = advance(box[0])
        return box[0]

    return step


def _sweep_stepper(eng, state, sweeps):
    return _rebind_stepper(lambda s: eng.sweeps(s, sweeps, 0), state)


def table1_single_device(n=256, sweeps=10):
    from repro.core.engine import ENGINES, make_engine
    from repro.core.sim import SimConfig
    spins = n * n * sweeps
    for name in T1_ENGINES:
        if not _engine_selected(name):
            continue
        cfg = SimConfig(n=n, m=n, temperature=2.27, seed=1, engine=name,
                        tc_block=64)
        eng = make_engine(cfg)
        state = eng.init_state(jax.random.PRNGKey(0))
        t = _timeit(_sweep_stepper(eng, state, sweeps),
                    label=f"t1_{name}")
        dt = t.mean_s
        reps = ENGINES[name].replicas
        _row(f"t1_{name}", dt * 1e6,
             f"flips_per_ns={reps*spins/dt/1e9:.4f}",
             engine=name, timed=t)


# ---------------------------------------------------------------------------
# Table 2: multispin engine vs lattice size, plus the batched-ensemble
# variant (TPU-cluster follow-up): B replicas in one vmapped sweep
# ---------------------------------------------------------------------------

def table2_multispin_sizes(sweeps=5):
    from repro.core import lattice as lat, multispin as ms
    key = jax.random.PRNGKey(1)
    beta = jnp.float32(1 / 1.5)
    for n in (128, 256, 512, 1024):
        full = lat.init_lattice(key, n, n)
        step = _rebind_stepper(
            lambda s: ms.run_sweeps_packed(*s, beta, sweeps, seed=1),
            ms.pack_lattice(*lat.split_checkerboard(full)))
        t = _timeit(step, iters=2, label=f"t2_multispin_{n}x{n}")
        dt = t.mean_s
        _row(f"t2_multispin_{n}x{n}", dt * 1e6,
             f"flips_per_ns={n*n*sweeps/dt/1e9:.4f}",
             engine="multispin", timed=t)


def table2_ensemble_batch(sweeps=5, batch=8):
    """Replica batching: flips/ns of one vmapped sweep over B replicas --
    the aggregate-throughput lever the TPU-cluster paper exploits."""
    from repro.core.ensemble import Ensemble
    for n in (128, 256):
        ens = Ensemble(n=n, m=n, temperatures=[1.5] * batch,
                       seeds=list(range(batch)), engine="multispin")
        t = _timeit(lambda: ens.run(sweeps), iters=2,
                    label=f"t2_ensemble_B{batch}_{n}")
        dt = t.mean_s
        _row(f"t2_ensemble_B{batch}_multispin_{n}x{n}", dt * 1e6,
             f"flips_per_ns={batch*n*n*sweeps/dt/1e9:.4f}",
             engine="multispin", timed=t)


# ---------------------------------------------------------------------------
# Tables 3/4: weak + strong scaling of the distributed engines
# ---------------------------------------------------------------------------

def _mesh(nd):
    from repro.launch.mesh import make_mesh
    return make_mesh((nd, 1), ("data", "model"))


def table3_weak_scaling(per_dev_rows=256, cols=512, sweeps=5):
    from repro.core import distributed as dist, lattice as lat
    key = jax.random.PRNGKey(2)
    beta = jnp.float32(1 / 2.27)
    for nd in (1, 2, 4, 8):
        n = per_dev_rows * nd
        full = lat.init_lattice(key, n, cols)
        b, w = lat.split_checkerboard(full)
        mesh = _mesh(nd)
        step, sh = dist.make_ising_step(mesh, n=n, m=cols, seed=3,
                                        n_sweeps=sweeps)
        tick = _rebind_stepper(
            lambda s: step(*s, beta, jnp.uint32(0)),
            (jax.device_put(b, sh), jax.device_put(w, sh)))
        t = _timeit(tick, iters=2, label=f"t3_weak_{nd}dev")
        dt = t.mean_s
        _row(f"t3_weak_basic_{nd}dev", dt * 1e6,
             f"flips_per_ns={n*cols*sweeps/dt/1e9:.4f}",
             engine="basic", timed=t)


def table4_strong_scaling(n=1024, cols=512, sweeps=5):
    from repro.core import distributed as dist, lattice as lat
    key = jax.random.PRNGKey(3)
    beta = jnp.float32(1 / 2.27)
    full = lat.init_lattice(key, n, cols)
    b, w = lat.split_checkerboard(full)
    for nd in (1, 2, 4, 8):
        mesh = _mesh(nd)
        step, sh = dist.make_ising_step(mesh, n=n, m=cols, seed=3,
                                        n_sweeps=sweeps)
        # copies: b/w are reused across meshes, the step donates, and
        # device_put may alias on the 1-device mesh (H1.8)
        tick = _rebind_stepper(
            lambda s: step(*s, beta, jnp.uint32(0)),
            (jax.device_put(b.copy(), sh), jax.device_put(w.copy(), sh)))
        t = _timeit(tick, iters=2, label=f"t4_strong_{nd}dev")
        dt = t.mean_s
        _row(f"t4_strong_basic_{nd}dev", dt * 1e6,
             f"flips_per_ns={n*cols*sweeps/dt/1e9:.4f}",
             engine="basic", timed=t)


def table5_packed_scaling(per_dev_rows=256, cols=1024, sweeps=5):
    """Weak scaling of the optimized (packed multispin) engine -- the
    paper's Table 3 headline engine."""
    from repro.core import distributed as dist, lattice as lat, \
        multispin as ms
    key = jax.random.PRNGKey(4)
    beta = jnp.float32(1 / 2.27)
    for nd in (1, 2, 4, 8):
        n = per_dev_rows * nd
        full = lat.init_lattice(key, n, cols)
        bw, ww = ms.pack_lattice(*lat.split_checkerboard(full))
        mesh = _mesh(nd)
        step, sh = dist.make_packed_ising_step(mesh, n=n, m=cols, seed=3,
                                               n_sweeps=sweeps)
        tick = _rebind_stepper(
            lambda s: step(*s, beta, jnp.uint32(0)),
            (jax.device_put(bw, sh), jax.device_put(ww, sh)))
        t = _timeit(tick, iters=2, label=f"t5_weak_{nd}dev")
        dt = t.mean_s
        _row(f"t5_weak_multispin_{nd}dev", dt * 1e6,
             f"flips_per_ns={n*cols*sweeps/dt/1e9:.4f}",
             engine="multispin", timed=t)


# ---------------------------------------------------------------------------
# Table 6 (S15 follow-up): weak scaling of the SHARDED RESIDENT tier --
# per-shard VMEM k-sweep kernels with in-loop halo exchange
# ---------------------------------------------------------------------------

def table6_dist_weakscale(devices=(1, 2, 4, 8), sweeps=4):
    """Weak scaling of the sharded resident tier: a (D, 1) mesh with
    base_n * D lattice rows per point, so per-shard work is constant.
    Rows carry the planner decision and the MEASURED halo traffic per
    call (telemetry counter deltas); shared measurement code with the
    standalone ``python -m repro.dist.weakscale`` CLI the CI dist job
    runs."""
    from repro.dist import weakscale as ws
    for row in ws.measure_rows(devices, sweeps=sweeps,
                               trials=_TRIALS or 2):
        derived = ";".join(f"{k_}={v}"
                           for k_, v in row["derived"].items())
        _row(row["name"], row["us"], derived, engine=row["engine"],
             k=row["k"], times=row["times_s"])


# ---------------------------------------------------------------------------
# Table 1 addendum: fused measure_scan vs legacy per-sample Python loop --
# the dispatch-count win of the measurement subsystem (DESIGN.md S7)
# ---------------------------------------------------------------------------

def table1_measure_fusion(n=64, n_measure=64, sweeps_between=1):
    """Fused measure_scan vs the legacy per-sample loop.  Both rows'
    ``dispatches`` columns are MEASURED (telemetry counter delta inside
    ``_timeit``), not asserted: the fused row must stay at 1 per
    measure block (CI gates on it), the legacy row at ``n_measure``."""
    from repro.analysis.measure import MeasurementPlan
    from repro.core.sim import SimConfig, Simulation

    cfg = dict(n=n, m=n, temperature=2.27, seed=5, engine="multispin")
    spins = n * n * n_measure * sweeps_between

    sim = Simulation(SimConfig(**cfg))

    def legacy_loop():
        # the pre-analysis-subsystem trajectory(): one device dispatch
        # (and one host round-trip) per sample
        out = np.empty(n_measure, np.float32)
        for i in range(n_measure):
            sim.run(sweeps_between)
            out[i] = sim.magnetization()
        return out

    t = _timeit(legacy_loop, iters=2, label=f"t1_traj_loop_{n}")
    dt = t.mean_s
    _row(f"t1_traj_loop_multispin_{n}", dt * 1e6,
         f"us_per_sample={dt*1e6/n_measure:.1f};"
         f"flips_per_ns={spins/dt/1e9:.4f}", engine="multispin", timed=t)

    sim2 = Simulation(SimConfig(**cfg))
    plan = MeasurementPlan(n_measure, sweeps_between, fields=("m",))
    t = _timeit(lambda: sim2.measure(plan)["m"], iters=2,
                label=f"t1_traj_scan_{n}")
    dt = t.mean_s
    _row(f"t1_traj_scan_multispin_{n}", dt * 1e6,
         f"us_per_sample={dt*1e6/n_measure:.1f};"
         f"flips_per_ns={spins/dt/1e9:.4f}", engine="multispin", timed=t)


# ---------------------------------------------------------------------------
# Table 1 addendum: bitplane vs nibble multispin -- per-replica flips/ns
# and the shared-draw randomness budget (DESIGN.md S8)
# ---------------------------------------------------------------------------

def table1_bitplane(n=256, sweeps=10, pallas_n=64, pallas_sweeps=2):
    """Bitplane (32 replicas/word, ONE shared Philox uint32 per site)
    against the nibble multispin engine on the same lattice.  The
    ``philox_draws_per_spin`` column is the randomness budget per
    *replica-spin*: 8 draws per 8-spin word for nibble multispin (1.0)
    vs 1 draw per 32-replica word for bitplane (1/32) -- the ~32x draw
    reduction of the shared-randoms scheme.  Acceptance criterion: the
    bitplane ``replica_flips_per_ns`` must beat the multispin row."""
    from repro.core.engine import ENGINES, make_engine
    from repro.core.sim import SimConfig

    for name in ("multispin", "bitplane"):
        if not _engine_selected(name):
            continue
        cfg = SimConfig(n=n, m=n, temperature=2.27, seed=1, engine=name)
        eng = make_engine(cfg)
        state = eng.init_state(jax.random.PRNGKey(0))
        t = _timeit(_sweep_stepper(eng, state, sweeps),
                    label=f"t1_bitplane_{name}")
        dt = t.mean_s
        reps = ENGINES[name].replicas
        flips = reps * n * n * sweeps
        _row(f"t1_bitplane_{name}_{n}", dt * 1e6,
             f"replica_flips_per_ns={flips/dt/1e9:.4f};"
             f"philox_draws_per_spin={1.0/reps:.5f}",
             engine=name, timed=t)

    # interpret-mode Pallas smoke (CI artifact row): small lattice, the
    # interpreter is orders of magnitude off real-kernel throughput
    if _engine_selected("bitplane_pallas"):
        cfg = SimConfig(n=pallas_n, m=pallas_n, temperature=2.27, seed=1,
                        engine="bitplane_pallas")
        eng = make_engine(cfg)
        state = eng.init_state(jax.random.PRNGKey(0))
        t = _timeit(_sweep_stepper(eng, state, pallas_sweeps),
                    iters=1, warmup=1, label="t1_bitplane_pallas")
        dt = t.mean_s
        flips = eng.replicas * pallas_n * pallas_n * pallas_sweeps
        _row(f"t1_bitplane_pallas_interp_{pallas_n}", dt * 1e6,
             f"replica_flips_per_ns={flips/dt/1e9:.4f};"
             f"philox_draws_per_spin={1.0/eng.replicas:.5f}",
             engine="bitplane_pallas", timed=t)


# ---------------------------------------------------------------------------
# Table 1 addendum: resident-sweep tier (DESIGN.md S9) -- k full sweeps
# per kernel dispatch, spins VMEM-resident, vs the per-half-sweep tier
# ---------------------------------------------------------------------------

def table1_resident(n=64, k=8):
    """Resident vs per-half-sweep tier on the three Pallas families.

    A k-sweep block is ONE resident kernel dispatch (both planes staged
    into VMEM once) vs 2k per-half-sweep kernel dispatches (each
    round-tripping both planes through HBM).  The fallback engine is
    the same object with its VMEM plan cleared, so the two rows differ
    ONLY in tier.  On this CPU container both tiers run the Pallas
    interpreter, so the speedup mostly reflects dispatch overhead; on
    TPU the HBM-traffic ratio dominates (EXPERIMENTS.md H1.9)."""
    from repro.core.engine import ENGINES, make_engine
    from repro.core.sim import SimConfig
    for name in ("stencil_pallas", "multispin_pallas", "bitplane_pallas"):
        if not _engine_selected(name):
            continue
        cfg = SimConfig(n=n, m=n, temperature=2.27, seed=1, engine=name)
        reps = ENGINES[name].replicas
        flips = reps * n * n * k

        eng = make_engine(cfg)
        assert eng.resident_plan is not None, (name, n)
        state = eng.init_state(jax.random.PRNGKey(0))
        t_res = _timeit(_sweep_stepper(eng, state, k), iters=2,
                        label=f"t1_resident_{name}")
        dt_res = t_res.mean_s

        fb = make_engine(cfg)
        fb.resident_plan = None   # force the per-half-sweep tier
        state = fb.init_state(jax.random.PRNGKey(0))
        dt_half = _timeit(_sweep_stepper(fb, state, k), iters=2,
                          label=f"t1_halfsweep_{name}").mean_s

        _row(f"t1_resident_{name}_{n}_k{k}", dt_res * 1e6,
             f"k_sweeps_per_dispatch={k};kernel_dispatches_per_block=1;"
             f"halfsweep_dispatches_per_block={2 * k};"
             f"flips_per_ns={flips / dt_res / 1e9:.4f};"
             f"halfsweep_flips_per_ns={flips / dt_half / 1e9:.4f};"
             f"speedup_vs_halfsweep={dt_half / dt_res:.2f}",
             engine=name, k=k, timed=t_res)


# ---------------------------------------------------------------------------
# spec-driven bench: time any serialized RunSpec and record the spec in
# the row, so every perf number is replayable (python -m repro run)
# ---------------------------------------------------------------------------

def spec_bench(path, sweeps=10):
    """Benchmark the run a ``RunSpec`` JSON file describes.

    With a sweep plan: times one fused ``Session.measure`` dispatch
    (after a compile warmup).  Without: times ``sweeps``-sweep
    ``Session.run`` blocks.  The serialized spec lands in the row of
    the BENCH_*.json record (EXPERIMENTS.md S Bench).
    """
    from repro.api import RunSpec, Session
    with open(path) as f:
        spec = RunSpec.from_json(f.read())
    n, m = spec.lattice.n, spec.lattice.m
    batch = 1 if spec.batch is None else spec.batch.size
    from repro.core.engine import ENGINES
    reps = ENGINES[spec.engine.name].replicas
    session = Session.open(spec)
    if spec.sweep is not None:
        total = spec.sweep.total_sweeps
        t = _timeit(lambda: session.measure(), iters=2,
                    label="spec_measure")
        kind, flips = "measure", reps * batch * n * m * total
    else:
        t = _timeit(lambda: session.run(sweeps), iters=2,
                    label="spec_run")
        kind, flips = "run", reps * batch * n * m * sweeps
    dt = t.mean_s
    name = f"spec_{kind}_{spec.engine.name}_{spec.mode}_{n}x{m}"
    if _RECORDER is None:
        print(f"{name},{dt * 1e6:.1f},flips_per_ns={flips/dt/1e9:.4f}")
        return
    from repro.launch import roofline as rl
    pct = rl.pct_of_roofline(flips / dt / 1e9, spec.engine.name,
                             jax.default_backend())
    extra = {} if pct is None else {"pct_of_roofline": round(pct, 4)}
    if t.compile_s is not None:
        extra["compile_ms"] = round(t.compile_s * 1e3, 3)
    if t.dispatches:
        extra["dispatches"] = t.dispatches
    _RECORDER.record(name, dt * 1e6, spec=spec.to_json(),
                     times_us=[s * 1e6 for s in t.times_s],
                     flips_per_ns=flips / dt / 1e9, batch=batch,
                     engine=spec.engine.name, **extra)


# ---------------------------------------------------------------------------
# Fig 5/6: physics validation vs Onsager
# ---------------------------------------------------------------------------

def fig5_validation():
    from repro.core import observables as obs
    from repro.core.sim import SimConfig, Simulation
    for temp in (1.5, 2.0, 2.5, 3.0):
        t0 = time.perf_counter()
        sim = Simulation(SimConfig(n=96, m=96, temperature=temp, seed=11,
                                   engine="multispin"))
        sim.run(300)
        m = float(np.abs(sim.trajectory(10, 10)).mean())
        exact = float(obs.onsager_magnetization(temp))
        dt = time.perf_counter() - t0
        _row(f"fig5_T{temp}", dt * 1e6,
             f"m={m:.4f};onsager={exact:.4f};abs_err={abs(m-exact):.4f}")


# ---------------------------------------------------------------------------
# roofline summary from the dry-run artifact (deliverable d/g)
# ---------------------------------------------------------------------------

def roofline_summary(path="results/dryrun.json"):
    if not os.path.exists(path):
        print(f"# roofline: {path} missing (run repro.launch.dryrun)")
        return
    with open(path) as f:
        cells = json.load(f)
    for r in sorted(cells, key=lambda r: (r["arch"], r["shape"],
                                          r["mesh"])):
        if r.get("status") != "ok":
            continue
        name = f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}"
        tot = (r["t_compute_s"] + 1e-30)
        _row(name, r["t_compute_s"] * 1e6,
             f"dom={r['dominant']};t_mem_s={r['t_memory_s']:.5f};"
             f"t_coll_s={r['t_collective_s']:.5f};"
             f"compute_frac={r['t_compute_s']/max(r['t_compute_s'], r['t_memory_s'], r['t_collective_s']):.3f}")


def kernel_block_sweep(n=128, sweeps=3):
    """Multispin Pallas kernel: block_rows trades VMEM footprint against
    grid overhead (kernel docstring) -- sweep it in interpret mode and
    report the per-step VMEM working set (4 row blocks x width)."""
    import jax
    from repro.core import lattice as lat, multispin as ms
    from repro.kernels.multispin.ops import run_sweeps_multispin
    key = jax.random.PRNGKey(7)
    full = lat.init_lattice(key, n, n)
    bw, ww = ms.pack_lattice(*lat.split_checkerboard(full))
    beta = jnp.float32(1 / 2.0)
    width_words = n // 2 // 8
    for block_rows in (8, 16, 32, 64, 128):
        vmem_kb = 4 * block_rows * width_words * 4 / 1024
        # copies: the wrapper donates and bw/ww are reused per block size
        t = _timeit(lambda: run_sweeps_multispin(
            bw.copy(), ww.copy(), beta, sweeps, seed=1,
            block_rows=block_rows, interpret=True), iters=1, warmup=1,
            label=f"kblocks_rows{block_rows}")
        dt = t.mean_s
        _row(f"kblocks_multispin_rows{block_rows}", dt * 1e6,
             f"vmem_working_set_kb={vmem_kb:.0f}", timed=t)


# ---------------------------------------------------------------------------
# resilience: checkpoint overhead + recovery latency (DESIGN.md S13)
# ---------------------------------------------------------------------------

def resilience_ckpt(n=128, sweeps=16):
    """Integrity tax and recovery latency of the resilience subsystem.

    Four rows: CRC32C ladder throughput (the per-byte integrity tax on
    every checkpointed array), one verified checkpoint save (npz +
    manifest + atomic commit), one verified restore (discover newest
    valid step, CRC-check every array -- the recovery-latency number),
    and a supervised run with cadence OFF vs a plain ``Session.run`` of
    the same sweeps (the zero-hot-path-overhead contract: the ratio
    must stay ~1)."""
    import shutil
    import tempfile

    from repro.api import EngineSpec, LatticeSpec, RunSpec, Session
    from repro.ckpt import Checkpointer
    from repro.resilience import Supervisor, integrity

    buf = np.random.default_rng(0).bytes(4 << 20)
    t = _timeit(lambda: integrity.crc32c(buf), label="resil_crc")
    _row("resil_crc32c_4MiB", t.mean_s * 1e6,
         f"mb_per_s={len(buf)/t.mean_s/1e6:.1f}", timed=t)

    spec = RunSpec(lattice=LatticeSpec(n=n, m=n),
                   engine=EngineSpec("multispin"),
                   temperature=2.27, seed=9)
    d = tempfile.mkdtemp(prefix="bench_resil_")
    try:
        s = Session.open(spec)
        s.run(2)
        arrays = s._runner.state_arrays()
        nbytes = sum(np.asarray(v).nbytes for v in arrays.values())
        ck = Checkpointer(d, keep=2)
        step_box = [0]

        def save():
            step_box[0] += 1
            ck.save(step_box[0], arrays, spec_json=spec.to_json())
            return step_box[0]

        t = _timeit(save, label="resil_save")
        _row(f"resil_ckpt_save_{n}", t.mean_s * 1e6,
             f"state_kb={nbytes/1024:.0f};"
             f"mb_per_s={nbytes/t.mean_s/1e6:.2f}", timed=t)

        t = _timeit(lambda: ck.load_arrays()[0], label="resil_restore")
        _row(f"resil_ckpt_restore_{n}", t.mean_s * 1e6,
             f"state_kb={nbytes/1024:.0f};"
             f"mb_per_s={nbytes/t.mean_s/1e6:.2f}", timed=t)
    finally:
        shutil.rmtree(d, ignore_errors=True)

    def plain():
        s = Session.open(spec)
        s.run(sweeps)
        return s.magnetization()

    def supervised():
        dd = tempfile.mkdtemp(prefix="bench_resil_sup_")
        try:
            sup = Supervisor(spec, dd, every_sweeps=0, chunk=sweeps,
                             install_signal_handlers=False)
            res = sup.run(sweeps)
            return res.step_count
        finally:
            shutil.rmtree(dd, ignore_errors=True)

    dt_plain = _timeit(plain, iters=2, label="resil_plain").mean_s
    t = _timeit(supervised, iters=2, label="resil_supervised")
    _row(f"resil_supervised_overhead_{n}", t.mean_s * 1e6,
         f"plain_us={dt_plain*1e6:.1f};"
         f"overhead_ratio={t.mean_s/dt_plain:.3f}", timed=t)


def serve_throughput(n=64, k=8, sweeps=64):
    """Sweep-farm ingestion rate in specs/sec (DESIGN.md S14).

    One persistent farm, waves of ``k`` compatible single-lattice
    specs per timed call: the first wave compiles, steady waves hit
    the compiled-runner pool (``_EnsembleRunner.rebind``) and fuse
    into ONE vmapped dispatch -- the measured ``dispatches`` field is
    the coalescing evidence (~1/call coalesced vs ~k/call solo).  The
    solo row runs the same waves at ``max_batch=1`` so the coalescing
    win is a ratio inside one bench record."""
    import shutil
    import tempfile

    from repro.api import EngineSpec, LatticeSpec, RunSpec
    from repro.serve.server import SweepFarm

    def run_waves(max_batch, tag):
        d = tempfile.mkdtemp(prefix=f"bench_farm_{tag}_")
        farm = SweepFarm(d, max_batch=max_batch, chunk=sweeps,
                         max_queue=1_000_000)
        wave = [0]

        def one_wave():
            w = wave[0]
            wave[0] += 1
            for i in range(k):
                spec = RunSpec(
                    lattice=LatticeSpec(n=n, m=n),
                    engine=EngineSpec("multispin"),
                    temperature=2.0 + 0.05 * i, seed=k * w + i)
                farm.submit({"spec": spec.to_dict(),
                             "sweeps": sweeps})
            return farm.run_until_idle()

        try:
            t = _timeit(one_wave, iters=2, label=f"serve_{tag}")
            _row(f"serve_{tag}_k{k}_{n}", t.mean_s * 1e6,
                 f"specs_per_s={k / t.mean_s:.2f};k={k};"
                 f"sweeps={sweeps};max_batch={max_batch}", timed=t)
        finally:
            farm.close()
            shutil.rmtree(d, ignore_errors=True)

    run_waves(k, "coalesced")
    run_waves(1, "solo")


def main() -> None:
    global _RECORDER, _ENGINE_FILTER, _TRIALS
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma-separated substrings: run benches whose "
                         "name contains any of them")
    ap.add_argument("--trials", type=int, default=None, metavar="N",
                    help="time every bench N times (overrides per-bench "
                         "iter counts) so each row records n_trials + "
                         "median + IQR -- the noise model the perf gate "
                         "consumes; use >= 5 when refreshing the "
                         "committed baseline (EXPERIMENTS.md S Perf-gate)")
    ap.add_argument("--engines", default="",
                    help="comma-separated engine names: restrict the "
                         "registry-driven engine benches (table1) to this "
                         "subset, e.g. --engines multispin,bitplane")
    ap.add_argument("--json", nargs="?", const=".", default=None,
                    metavar="DIR_OR_PATH",
                    help="also write a BENCH_<stamp>.json perf record "
                         "(diff two with benchmarks/report.py diff A B)")
    ap.add_argument("--spec", default=None, metavar="SPEC_JSON",
                    help="benchmark the run this RunSpec file describes "
                         "(recorded with the serialized spec; runs "
                         "alone unless --only also selects benches)")
    ap.add_argument("--trace", default="", metavar="PATH",
                    help="enable span tracing; write the Chrome trace "
                         "(.json) or .jsonl stream + metrics snapshot "
                         "here after the benches run")
    args, _ = ap.parse_known_args()
    if args.trace:
        tel.enable()
    _ENGINE_FILTER = tuple(e for e in args.engines.split(",") if e)
    _TRIALS = args.trials
    if _TRIALS is not None and _TRIALS < 1:
        ap.error(f"--trials must be >= 1, got {_TRIALS}")
    from repro.core.engine import ENGINES
    unknown = sorted(set(_ENGINE_FILTER) - set(ENGINES))
    if unknown:
        ap.error(f"--engines: unknown engine(s) {unknown}; "
                 f"registered: {sorted(ENGINES)}")

    from repro.analysis.recorder import RunRecorder
    stamp = time.strftime("%Y%m%d_%H%M%S")
    _RECORDER = RunRecorder(echo=True, meta={
        "stamp": stamp, "backend": jax.default_backend(),
        "device_count": jax.device_count(), "only": args.only,
        "engines": args.engines, "spec_file": args.spec,
        "trials": args.trials})

    benches = [table1_single_device, table1_measure_fusion,
               table1_bitplane, table1_resident, table2_multispin_sizes,
               table2_ensemble_batch, table3_weak_scaling,
               table4_strong_scaling, table5_packed_scaling,
               table6_dist_weakscale,
               fig5_validation, kernel_block_sweep, resilience_ckpt,
               serve_throughput, roofline_summary]
    only = [tok for tok in args.only.split(",") if tok]
    selected = [b for b in benches
                if not only or any(tok in b.__name__ for tok in only)]
    if args.spec and not only:
        selected = []          # --spec alone: just the spec bench
    elif not selected:
        ap.error(f"--only {args.only!r} matches no bench; benches: "
                 f"{[b.__name__ for b in benches]}")
    for b in selected:
        b()
    if args.spec:
        spec_bench(args.spec)

    if args.json is not None:
        # every emitted record must pass the perf-record schema -- a
        # malformed row dies here, not in a later gate/trend run
        from repro.perf.schema import validate_record
        validate_record({"meta": _RECORDER.meta, "rows": _RECORDER.rows})
        path = _RECORDER.write_json(args.json)
        print(f"# wrote {path}")
    if args.trace:
        print(f"# wrote trace "
              f"{tel.export(args.trace, meta={'stamp': stamp, 'bench': True, 'only': args.only})}")


if __name__ == "__main__":
    main()
