"""Per-shard VMEM-resident k-sweep Pallas kernels (DESIGN.md S15).

Global-index-keyed variants of the S9 resident kernels
(``kernels/{stencil,multispin,bitplane}/resident.py``): the half-sweep
math is IMPORTED from those modules (same fusion structure, same float
op order, bit-exactness by construction) -- the only difference is
that Philox draws are keyed on precomputed uint32 *global* index
planes instead of in-kernel iota, because the planes these kernels see
are halo-EXTENDED shards whose cells live at arbitrary (and, across
the periodic wrap, non-contiguous) global positions.

Each kernel stages the extended planes plus the index plane(s) into
VMEM once, runs ``n_sweeps`` full sweeps in an in-kernel
``lax.fori_loop`` with offsets advanced per (sweep, color) by
``core.rng.half_sweep_offset``, and writes the planes back once
(extended inputs aliased to the outputs).  Every half-sweep updates
the WHOLE extended plane -- no masks: the wraparound taps at the
extended edge read garbage, but garbage propagates inward at exactly
one ring per half-sweep, so after ``2k`` half-sweeps only the
``h = 2k`` halo rings are contaminated and the caller's interior
slice ``[h:-h, h:-h]`` is exact (the S15 double-halo argument).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import rng as crng
from repro.kernels.bitplane import resident as bp_res
from repro.kernels.multispin import resident as ms_res
from repro.kernels.stencil import resident as st_res

_VMEM = pl.BlockSpec(memory_space=pltpu.VMEM)
_SMEM = pl.BlockSpec(memory_space=pltpu.SMEM)


def _loop(half_sweep, seeds_ref, n_sweeps, black_ref, white_ref,
          black_out, white_out):
    """The shared (sweep, color) offset loop over a half-sweep fn."""
    start = seeds_ref[2]

    def body(i, carry):
        b, w = carry
        b = half_sweep(b, w, True, crng.half_sweep_offset(start, i, 0))
        w = half_sweep(w, b, False, crng.half_sweep_offset(start, i, 1))
        return (b, w)

    b, w = jax.lax.fori_loop(0, n_sweeps, body,
                             (black_ref[...], white_ref[...]))
    black_out[...] = b
    white_out[...] = w


def _stencil_kernel(beta_ref, seeds_ref, gidx_ref, black_ref, white_ref,
                    black_out, white_out, *, n_sweeps: int):
    inv_temp = beta_ref[0]
    k0, k1 = seeds_ref[0], seeds_ref[1]
    gidx = gidx_ref[...]
    _loop(lambda t, op, is_b, off: st_res._half_sweep(
              t, op, inv_temp, is_b, k0, k1, off, gidx=gidx),
          seeds_ref, n_sweeps, black_ref, white_ref, black_out,
          white_out)


def stencil_shard_sweeps(black, white, inv_temp, gidx, *,
                         n_sweeps: int, seed, start_offset,
                         interpret: bool = False):
    """``n_sweeps`` sweeps of one halo-extended int8 shard, resident."""
    assert n_sweeps >= 1, n_sweeps
    beta = jnp.array([inv_temp], jnp.float32)
    k0, k1 = crng.seed_keys(seed)
    seeds = jnp.stack([k0, k1,
                       jnp.asarray(start_offset, jnp.uint32)])
    return pl.pallas_call(
        functools.partial(_stencil_kernel, n_sweeps=n_sweeps),
        in_specs=[_SMEM, _SMEM, _VMEM, _VMEM, _VMEM],
        out_specs=(_VMEM, _VMEM),
        out_shape=(jax.ShapeDtypeStruct(black.shape, black.dtype),
                   jax.ShapeDtypeStruct(white.shape, white.dtype)),
        input_output_aliases={3: 0, 4: 1},
        interpret=interpret,
    )(beta, seeds, gidx, black, white)


def _multispin_kernel(seeds_ref, thr_ref, widx_ref, black_ref,
                      white_ref, black_out, white_out, *,
                      n_sweeps: int):
    k0, k1 = seeds_ref[0], seeds_ref[1]
    thr = [thr_ref[c] for c in range(10)]  # SMEM scalar reads
    widx = widx_ref[...]
    _loop(lambda t, op, is_b, off: ms_res._half_sweep(
              t, op, is_b, thr, k0, k1, off, widx=widx),
          seeds_ref, n_sweeps, black_ref, white_ref, black_out,
          white_out)


def multispin_shard_sweeps(black, white, thresholds, widx, *,
                           n_sweeps: int, seed, start_offset,
                           interpret: bool = False):
    """``n_sweeps`` sweeps of one halo-extended packed-word shard."""
    assert n_sweeps >= 1, n_sweeps
    k0, k1 = crng.seed_keys(seed)
    seeds = jnp.stack([k0, k1,
                       jnp.asarray(start_offset, jnp.uint32)])
    return pl.pallas_call(
        functools.partial(_multispin_kernel, n_sweeps=n_sweeps),
        in_specs=[_SMEM, _SMEM, _VMEM, _VMEM, _VMEM],
        out_specs=(_VMEM, _VMEM),
        out_shape=(jax.ShapeDtypeStruct(black.shape, black.dtype),
                   jax.ShapeDtypeStruct(white.shape, white.dtype)),
        input_output_aliases={3: 0, 4: 1},
        interpret=interpret,
    )(seeds, thresholds, widx, black, white)


def _bitplane_kernel(seeds_ref, thr_ref, gidx_ref, lane_ref, black_ref,
                     white_ref, black_out, white_out, *, n_sweeps: int):
    k0, k1 = seeds_ref[0], seeds_ref[1]
    thr = [thr_ref[c] for c in range(10)]  # SMEM scalar reads
    gidx = gidx_ref[...]
    lane = lane_ref[...]
    _loop(lambda t, op, is_b, off: bp_res._half_sweep(
              t, op, is_b, thr, k0, k1, off, gidx=gidx, lane=lane),
          seeds_ref, n_sweeps, black_ref, white_ref, black_out,
          white_out)


def bitplane_shard_sweeps(black, white, thresholds, gidx, lane, *,
                          n_sweeps: int, seed, start_offset,
                          interpret: bool = False):
    """``n_sweeps`` sweeps of one halo-extended 32-replica bit shard."""
    assert n_sweeps >= 1, n_sweeps
    k0, k1 = crng.seed_keys(seed)
    seeds = jnp.stack([jnp.asarray(k0, jnp.uint32),
                       jnp.asarray(k1, jnp.uint32),
                       jnp.asarray(start_offset, jnp.uint32)])
    return pl.pallas_call(
        functools.partial(_bitplane_kernel, n_sweeps=n_sweeps),
        in_specs=[_SMEM, _SMEM, _VMEM, _VMEM, _VMEM, _VMEM],
        out_specs=(_VMEM, _VMEM),
        out_shape=(jax.ShapeDtypeStruct(black.shape, black.dtype),
                   jax.ShapeDtypeStruct(white.shape, white.dtype)),
        input_output_aliases={4: 0, 5: 1},
        interpret=interpret,
    )(seeds, thresholds, gidx, lane, black, white)
