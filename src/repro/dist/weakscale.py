"""Weak-scaling bench of the sharded resident tier (DESIGN.md S15).

One row per (family, device count): a ``(D, 1)`` mesh over the first
``D`` devices with ``base_n * D`` lattice rows -- per-shard work is
constant along the axis, so ideal weak scaling is a flat us/call
column.  Every row records the sweep throughput (flips/ns), the shard
planner's decision (``halo_k``, ``sharded_resident``), the MEASURED
halo traffic per call (telemetry counter deltas -- the evidence that
the resident tier exchanges once per k sweeps instead of twice per
sweep), and the serialized ``RunSpec``, so each number is replayable
with ``python -m repro run``.

Two consumers share :func:`measure_rows`:

* ``benchmarks/run.py`` (``table6_dist_weakscale``) -- the full
  harness, whose committed ``BENCH_*.json`` baselines carry the
  ``dist_*`` rows the perf gate compares against;
* ``python -m repro.dist.weakscale --devices 2,8 --json DIR`` -- the
  standalone CLI the CI ``dist`` job runs; its record marks itself
  filtered (``meta.only = "dist"``) so the gate skips the non-dist
  baseline rows.
"""
import os

# must precede any jax backend init: the weak-scaling axis needs
# multiple (forced host) devices
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import argparse
import time
from typing import Dict, Iterable, List

#: resident family -> the registry engine that carries it
FAMILY_ENGINES = {
    "stencil": "stencil_pallas",
    "multispin": "multispin_pallas",
    "bitplane": "bitplane_pallas",
}


def measure_rows(devices: Iterable[int], *, base_n: int = 64,
                 cols: int = 128, sweeps: int = 4,
                 trials: int = 2) -> List[Dict]:
    """Time the sharded families along the weak-scaling axis.

    Returns one dict per row: ``name`` (``dist_<family>_d<D>``),
    ``us`` (mean us/call), ``times_s`` (per-trial walls), ``engine``,
    ``k`` (planner sweeps-per-exchange, 1 when demoted), ``spec``
    (serialized RunSpec), and ``derived`` (flips/ns + planner decision
    + measured per-call halo traffic).
    """
    import jax
    import repro.telemetry as tel
    from repro.api import (EngineSpec, LatticeSpec, MeshSpec, RunSpec,
                           Session)
    from repro.core.engine import ENGINES

    rows: List[Dict] = []
    for nd in devices:
        if nd > jax.device_count():
            raise SystemExit(
                f"weakscale: {nd} devices requested, "
                f"{jax.device_count()} available (set XLA_FLAGS="
                f"--xla_force_host_platform_device_count={nd})")
        for family, engine in FAMILY_ENGINES.items():
            n = base_n * nd
            spec = RunSpec(
                lattice=LatticeSpec(n=n, m=cols),
                engine=EngineSpec(engine), temperature=2.27, seed=3,
                mesh=MeshSpec(shape=(nd, 1),
                              axis_names=("rows", "cols")))
            session = Session.open(spec)
            session.run(sweeps)            # warmup: compile + place
            session.magnetization()
            hx0 = tel.HALO_EXCHANGES.value
            hb0 = tel.HALO_BYTES.value
            times = []
            for _ in range(trials):
                t0 = time.perf_counter()
                session.run(sweeps)
                session.magnetization()    # host sync
                times.append(time.perf_counter() - t0)
            hx = (tel.HALO_EXCHANGES.value - hx0) / trials
            hb = (tel.HALO_BYTES.value - hb0) / trials
            attrs = session._runner._dist_attrs
            reps = ENGINES[engine].replicas
            dt = sum(times) / len(times)
            rows.append({
                "name": f"dist_{family}_d{nd}",
                "us": dt * 1e6,
                "times_s": times,
                "engine": engine,
                "k": int(attrs.get("halo_k", 1)),
                "spec": spec.to_json(),
                "derived": {
                    "flips_per_ns": reps * n * cols * sweeps / dt / 1e9,
                    "devices": nd,
                    "sweeps": sweeps,
                    "sharded_resident":
                        int(attrs.get("sharded_resident", False)),
                    "halo_k": int(attrs.get("halo_k", 1)),
                    "halo_exchanges_per_call": hx,
                    "halo_kb_per_call": round(hb / 1024, 3),
                },
            })
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.dist.weakscale",
        description="weak-scaling bench of the sharded resident tier")
    ap.add_argument("--devices", default="1,2,4,8",
                    help="comma list of device counts (each a (D,1) "
                         "mesh over the first D devices)")
    ap.add_argument("--base-n", type=int, default=64,
                    help="lattice rows PER DEVICE (n = base_n * D)")
    ap.add_argument("--cols", type=int, default=128)
    ap.add_argument("--sweeps", type=int, default=4,
                    help="sweeps per timed call")
    ap.add_argument("--trials", type=int, default=2)
    ap.add_argument("--json", nargs="?", const=".", default=None,
                    metavar="DIR_OR_PATH",
                    help="write a BENCH_<stamp>.json perf record "
                         "(marked filtered: meta.only = 'dist')")
    args = ap.parse_args(argv)
    devices = [int(d) for d in args.devices.split(",") if d]
    if not devices or any(d < 1 for d in devices):
        ap.error(f"--devices must be positive ints, got {args.devices!r}")

    import jax
    from repro.analysis.recorder import RunRecorder
    from repro.launch import roofline as rl
    stamp = time.strftime("%Y%m%d_%H%M%S")
    rec = RunRecorder(echo=True, meta={
        "stamp": stamp, "backend": jax.default_backend(),
        "device_count": jax.device_count(), "only": "dist",
        "trials": args.trials})
    for row in measure_rows(devices, base_n=args.base_n,
                            cols=args.cols, sweeps=args.sweeps,
                            trials=args.trials):
        derived = dict(row["derived"])
        derived["engine"] = row["engine"]
        pct = rl.pct_of_roofline(derived["flips_per_ns"],
                                 row["engine"], jax.default_backend(),
                                 k=row["k"])
        if pct is not None:
            derived["pct_of_roofline"] = round(pct, 4)
        rec.record(row["name"], row["us"], spec=row["spec"],
                   times_us=[t * 1e6 for t in row["times_s"]],
                   **derived)
    if args.json is not None:
        from repro.perf.schema import validate_record
        validate_record({"meta": rec.meta, "rows": rec.rows})
        print(f"# wrote {rec.write_json(args.json)}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
