"""``repro.dist`` -- the sharded resident tier (DESIGN.md S15).

Fuses the two big execution wins that previously did not compose:

* the **resident tier** (S9): one Pallas dispatch runs ``k`` full
  sweeps with both compact color planes VMEM-resident;
* the **distributed step** (S6): shard_map pencil decomposition with
  ring-shift halo exchange over the mesh axes.

The fusion is the *double-halo trick*: instead of exchanging 1-wide
halos every half-sweep (``core.distributed``), each shard gathers a
width ``h = 2k`` halo ring ONCE, then a per-shard VMEM-resident kernel
runs ``k`` full sweeps updating the whole extended plane.  Wrong values
creep inward from the extended edge at one ring per half-sweep, so
after ``2k`` half-sweeps exactly the ``h`` halo rings are contaminated
and the owned interior -- all the shard keeps -- is bit-exact.  Net:
one exchange per ``k`` sweeps instead of ``2k`` exchanges.

Philox draws are keyed on *global* lattice positions (precomputed
index planes ride into the kernel), so the trajectory is bit-identical
to the single-device resident tier on any mesh -- which also makes
checkpoints portable across mesh shapes (tests/test_dist.py).

Layout of the subsystem:

* :mod:`repro.dist.planner` -- shard-aware fit/halo/k decisions
  (:func:`plan_shard_resident`, :func:`shard_decision_attrs`);
* :mod:`repro.dist.kernels` -- the per-shard Pallas k-sweep kernels
  (global-index-keyed variants of the S9 resident kernels);
* :mod:`repro.dist.driver`  -- the shard_map step factory
  (:func:`make_resident_step`) with the in-loop halo gather;
* :mod:`repro.dist.weakscale` -- the weak-scaling bench CLI
  (``python -m repro.dist.weakscale``).
"""
from __future__ import annotations

from .driver import make_resident_step
from .planner import (ShardPlan, plan_shard_resident,
                      shard_decision_attrs)

__all__ = [
    "ShardPlan", "plan_shard_resident", "shard_decision_attrs",
    "make_resident_step",
]
