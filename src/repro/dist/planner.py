"""Shard-aware resident planner: fit, halo width, and k per shard.

Extends the single-device VMEM planner (``kernels/resident.py``) to
pencil-sharded lattices.  The decision is per *shard*: the kernel's
working set is the EXTENDED plane -- the ``(n_loc, w_loc)`` owned cells
plus a ``h = 2k`` halo ring on every side -- so both the VMEM budget
and the halo-feasibility constraints depend on the device grid, not
just the lattice.

Constraints (DESIGN.md S15 decision table):

* **halo fit**: ``h <= min(n_loc, w_loc)`` -- the ring-shift gather
  takes the outermost ``h`` rows/columns of each neighbor shard, so a
  halo wider than the shard itself would need multi-hop gathers the
  driver does not implement (and that would be slower than the
  per-half-sweep fallback anyway);
* **VMEM fit**: the extended working set -- extended cells times the
  family's S9 temporaries multiplier, plus the uint32 global-index
  planes the kernel needs for Philox keying -- must fit the same
  8 MiB budget the single-device planner uses;
* **overlap cap**: the extended area may be at most
  :data:`MAX_OVERLAP` times the owned area.  The halo cells are
  *redundantly* swept every half-sweep (that is the double-halo
  trade: compute for communication), so past ~2x the redundant work
  erases the exchange savings;
* **parity**: per-shard row counts must be even (checkerboard parity
  uniform across shards -- same rule as ``core.distributed``); the
  halo ``h = 2k`` is always even, so the extended plane's first row
  keeps global parity 0 and the kernels' local iota parity is exact.

``plan_shard_resident`` picks the largest feasible ``k`` up to
``k_cap`` and returns ``None`` when no ``k >= 1`` fits -- the caller
(``api.session._ShardedRunner``) then demotes to the per-half-sweep
distributed tier, which is bit-exact by the shared global-position
Philox keying.  A (family, lattice) demoted at runtime by
``resilience.degrade`` (e.g. a RESOURCE_EXHAUSTED launch) never fits
again this process, exactly like the single-device planner.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

from repro.kernels.resident import _FAMILIES, VMEM_BUDGET_BYTES
from repro.resilience import degrade

#: default cap on sweeps-per-exchange: past this the redundant halo
#: compute and the h^2 VMEM growth beat the exchange savings
K_CAP: int = 4

#: max extended-area / owned-area ratio before the redundant halo
#: sweep work disqualifies a k (see module docstring)
MAX_OVERLAP: float = 2.0

#: family -> (cells per plane row given lattice m, bytes per cell,
#: uint32 index planes the kernel needs for global Philox keying)
#: Cell = one element of the compact color plane: an int8 site
#: (stencil), a uint32 8-spin word (multispin, m/16 per row), or a
#: uint32 32-replica word (bitplane, m/2 per row).
_GEOMETRY = {
    "stencil": (lambda m: m // 2, 1, 1),      # gidx
    "multispin": (lambda m: m // 16, 4, 1),   # widx
    "bitplane": (lambda m: m // 2, 4, 2),     # group + lane
}


@dataclasses.dataclass(frozen=True)
class ShardPlan:
    """A positive fit: this (family, lattice, grid) runs the sharded
    resident tier with ``k`` sweeps per halo exchange."""

    family: str
    n: int                  # global plane rows
    m: int                  # global lattice columns
    rows_devs: int          # device-grid rows
    cols_devs: int          # device-grid columns
    n_loc: int              # owned plane rows per shard
    w_loc: int              # owned plane cells per shard row
    k: int                  # full sweeps per halo exchange
    halo: int               # halo ring width = 2k (always even)
    working_set_bytes: int  # modeled per-shard VMEM peak
    budget_bytes: int

    @property
    def width(self) -> int:
        """Global plane cells per row (family packing units)."""
        return _GEOMETRY[self.family][0](self.m)

    @property
    def cell_bytes(self) -> int:
        return _GEOMETRY[self.family][1]

    def exchanges(self, n_sweeps: int) -> int:
        """Halo exchange events one dispatch of ``n_sweeps`` performs:
        one per full k-sweep block plus one for the remainder block."""
        return max(1, math.ceil(n_sweeps / self.k))

    @property
    def halo_bytes_per_exchange(self) -> int:
        """Bytes moved across the mesh per exchange event: per shard,
        both color planes each gather 2 column strips ``(n_loc, h)``
        and then 2 row strips ``(h, w_loc + 2h)`` (the row strips ride
        on the column-extended plane so they carry the corners);
        summed over every shard in the grid."""
        h = self.halo
        per_plane = 2 * self.n_loc * h + 2 * h * (self.w_loc + 2 * h)
        return (2 * per_plane * self.cell_bytes
                * self.rows_devs * self.cols_devs)


def shard_working_set_bytes(family: str, n_loc: int, w_loc: int,
                            halo: int) -> int:
    """Modeled per-shard VMEM peak of the extended-plane kernel.

    Same temporaries model as the single-device planner (the S9
    multipliers in ``kernels/resident._FAMILIES``) applied to the
    extended cell count, plus one uint32 global-index plane per
    index input the kernel takes (Philox keying, S15).
    """
    _, mult = _FAMILIES[family]
    _, cell_bytes, n_idx = _GEOMETRY[family]
    ext = (n_loc + 2 * halo) * (w_loc + 2 * halo)
    return int(ext * (cell_bytes * mult + 4 * n_idx))


def plan_shard_resident(family: str, n: int, m: int, rows_devs: int,
                        cols_devs: int, *,
                        budget_bytes: Optional[int] = None,
                        k_cap: int = K_CAP,
                        max_overlap: Optional[float] = None
                        ) -> Optional[ShardPlan]:
    """Fit decision for one (family, lattice, device grid).

    Returns the :class:`ShardPlan` with the largest feasible
    ``k <= k_cap``, or ``None`` when even ``k = 1`` violates a
    constraint -- the caller then runs the per-half-sweep distributed
    tier (bit-exact fallback).  ``max_overlap`` overrides
    :data:`MAX_OVERLAP` (tests pin k on small shards with it; the
    driver is exact at ANY feasible k, the cap is pure perf policy).
    """
    if family not in _GEOMETRY:
        raise ValueError(f"unknown resident family {family!r}; "
                         f"known: {sorted(_GEOMETRY)}")
    budget = VMEM_BUDGET_BYTES if budget_bytes is None else budget_bytes
    overlap = MAX_OVERLAP if max_overlap is None else max_overlap
    width_of, _, _ = _GEOMETRY[family]
    width = width_of(m)
    if degrade.demotion_reason(family, n, m) is not None:
        return None
    if n % rows_devs or width % cols_devs:
        return None
    n_loc, w_loc = n // rows_devs, width // cols_devs
    if n_loc % 2:
        return None
    for k in range(max(1, k_cap), 0, -1):
        h = 2 * k
        if h > min(n_loc, w_loc):
            continue
        ext = (n_loc + 2 * h) * (w_loc + 2 * h)
        if ext > overlap * n_loc * w_loc:
            continue
        ws = shard_working_set_bytes(family, n_loc, w_loc, h)
        if ws > budget:
            continue
        return ShardPlan(family=family, n=n, m=m, rows_devs=rows_devs,
                         cols_devs=cols_devs, n_loc=n_loc, w_loc=w_loc,
                         k=k, halo=h, working_set_bytes=ws,
                         budget_bytes=budget)
    return None


def shard_decision_attrs(family: str, n: int, m: int, rows_devs: int,
                         cols_devs: int, *,
                         budget_bytes: Optional[int] = None,
                         k_cap: int = K_CAP) -> dict:
    """The shard planner's decision as one flat JSON-scalar dict --
    the single rendering shared by ``--dry-run`` (``describe``), the
    sharded dispatch span attributes, and tests, mirroring the
    single-device ``kernels.resident.decision_attrs`` contract."""
    budget = VMEM_BUDGET_BYTES if budget_bytes is None else budget_bytes
    plan = plan_shard_resident(family, n, m, rows_devs, cols_devs,
                               budget_bytes=budget, k_cap=k_cap)
    attrs = {"family": family, "grid": f"{rows_devs}x{cols_devs}",
             "sharded_resident": plan is not None,
             "budget_bytes": budget}
    if plan is not None:
        attrs.update(halo_k=plan.k, halo_width=plan.halo,
                     n_loc=plan.n_loc, w_loc=plan.w_loc,
                     working_set_bytes=plan.working_set_bytes,
                     halo_bytes_per_exchange=plan.halo_bytes_per_exchange)
        return attrs
    demoted = degrade.demotion_reason(family, n, m)
    width_of, _, _ = _GEOMETRY[family]
    if demoted is not None:
        attrs["demoted"] = True
        attrs["reason"] = (f"demoted to per-half-sweep distributed "
                           f"tier: {demoted}")
    elif n % rows_devs or width_of(m) % cols_devs \
            or (n // rows_devs) % 2:
        attrs["reason"] = ("lattice does not tile the device grid "
                           "evenly: per-half-sweep distributed tier")
    else:
        attrs["reason"] = ("no k satisfies halo/VMEM/overlap "
                           "constraints: per-half-sweep distributed "
                           "tier")
    return attrs
