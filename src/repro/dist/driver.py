"""shard_map driver for the sharded resident tier (DESIGN.md S15).

``make_resident_step(mesh, plan)`` builds the distributed analogue of
the S9 resident dispatch: one jitted call advances ``n_sweeps`` full
sweeps, but instead of exchanging 1-wide halos every half-sweep
(``core.distributed``), each shard

1. **gathers** a width ``h = 2k`` halo ring in two ring-shift stages
   -- columns first, then rows on the column-extended plane, so the
   row strips carry the corner cells (a diagonal neighbor's data
   arrives in two hops, never needing a diagonal ppermute);
2. **sweeps** ``k`` full sweeps in ONE per-shard Pallas kernel
   (``dist.kernels``) on the extended plane, VMEM-resident, with
   Philox draws keyed on precomputed global-index planes;
3. **slices** the owned interior ``[h:-h, h:-h]`` back out -- exact,
   because edge garbage creeps inward one ring per half-sweep and
   ``2k`` half-sweeps contaminate exactly the ``h`` halo rings.

Blocks repeat inside a ``fori_loop`` (one exchange per ``k`` sweeps);
a static remainder block of ``n_sweeps % k`` sweeps reuses the same
halo width (its contamination depth ``2(n_sweeps % k) < h`` stays
inside the ring).

Stream invariance: the index planes hold TRUE global positions
(modular arithmetic across the periodic wrap), and offsets advance by
``core.rng.half_sweep_offset`` from a half-sweep-unit ``start``
argument -- the same counter layout as every other tier -- so the
trajectory is bit-identical to the single-device resident kernels on
any mesh, and checkpoints restore across mesh shapes
(tests/test_dist.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import compat
from repro.core.distributed import ring_shift

from . import kernels as dk
from .planner import ShardPlan


def _multi_index(axes):
    """Linear device index over a product of mesh axes (msb first)."""
    idx = jnp.int32(0)
    for a in axes:
        idx = idx * compat.axis_size(a) + jax.lax.axis_index(a)
    return idx


def _extend(x, h: int, row_axes, col_axes):
    """Halo-extend one shard plane by ``h`` rings: two-stage ring-shift
    gather (columns, then rows on the column-extended plane) so the
    row strips carry the corners."""
    left = ring_shift(x[:, -h:], col_axes, +1)
    right = ring_shift(x[:, :h], col_axes, -1)
    xw = jnp.concatenate([left, x, right], axis=1)
    top = ring_shift(xw[-h:, :], row_axes, +1)
    bottom = ring_shift(xw[:h, :], row_axes, -1)
    return jnp.concatenate([top, xw, bottom], axis=0)


def make_resident_step(mesh, plan: ShardPlan, *, seed: int = 0,
                       n_sweeps: int = 1, row_axes=None, col_axes=None,
                       interpret=None):
    """Build the jitted sharded-resident sweep for ``mesh``/``plan``.

    Returns ``(step, sharding)`` where
    ``step(black, white, inv_temp, start)`` advances ``n_sweeps`` full
    sweeps from half-sweep offset ``start`` (uint32 -- pass
    ``2 * step_count``, the S9 resident ``start_offset`` convention)
    and the plane buffers are donated.  ``interpret=None`` resolves to
    the engines' convention (interpreter off only on real TPUs).
    """
    names = list(mesh.axis_names)
    row_axes = tuple(row_axes if row_axes is not None else names[:-1])
    col_axes = tuple(col_axes if col_axes is not None else names[-1:])
    rows_devs = 1
    for a in row_axes:
        rows_devs *= mesh.shape[a]
    cols_devs = 1
    for a in col_axes:
        cols_devs *= mesh.shape[a]
    assert (rows_devs, cols_devs) == (plan.rows_devs, plan.cols_devs), (
        f"plan grid {plan.rows_devs}x{plan.cols_devs} != mesh grid "
        f"{rows_devs}x{cols_devs}")
    assert n_sweeps >= 1, n_sweeps
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    fam, h, k = plan.family, plan.halo, plan.k
    width = plan.width
    n_blocks, rem = divmod(n_sweeps, k)
    spec = P(row_axes, col_axes)

    def _ext_positions():
        """Global (row, col) int32 planes of the EXTENDED shard cells,
        modular across the periodic wrap."""
        r0 = _multi_index(row_axes) * plan.n_loc
        c0 = _multi_index(col_axes) * plan.w_loc
        rows = jnp.mod(
            r0 - h + jnp.arange(plan.n_loc + 2 * h, dtype=jnp.int32),
            plan.n)[:, None]
        cols = jnp.mod(
            c0 - h + jnp.arange(plan.w_loc + 2 * h, dtype=jnp.int32),
            width)[None, :]
        return rows, cols

    @functools.partial(compat.shard_map, mesh=mesh,
                       in_specs=(spec, spec, P(), P()),
                       out_specs=(spec, spec), check_vma=False)
    def _sweeps(black, white, inv_temp, start):
        rows, cols = _ext_positions()
        if fam == "stencil":
            gidx = (rows * width + cols).astype(jnp.uint32)
            gidx = jnp.broadcast_to(gidx, (rows.shape[0], cols.shape[1]))

            def run_block(b, w, off, sweeps):
                bx, wx = (_extend(b, h, row_axes, col_axes),
                          _extend(w, h, row_axes, col_axes))
                bx, wx = dk.stencil_shard_sweeps(
                    bx, wx, inv_temp, gidx, n_sweeps=sweeps, seed=seed,
                    start_offset=off, interpret=interpret)
                return bx[h:-h, h:-h], wx[h:-h, h:-h]
        elif fam == "multispin":
            from repro.core import multispin as ms
            thresholds = ms.acceptance_thresholds(inv_temp)
            widx = (rows * width + cols).astype(jnp.uint32)
            widx = jnp.broadcast_to(widx, (rows.shape[0], cols.shape[1]))

            def run_block(b, w, off, sweeps):
                bx, wx = (_extend(b, h, row_axes, col_axes),
                          _extend(w, h, row_axes, col_axes))
                bx, wx = dk.multispin_shard_sweeps(
                    bx, wx, thresholds, widx, n_sweeps=sweeps,
                    seed=seed, start_offset=off, interpret=interpret)
                return bx[h:-h, h:-h], wx[h:-h, h:-h]
        else:  # bitplane
            from repro.core import multispin as ms
            thresholds = ms.acceptance_thresholds(inv_temp)
            shape = (rows.shape[0], cols.shape[1])
            g = jnp.broadcast_to(
                (rows * (width // 4) + cols // 4).astype(jnp.uint32),
                shape)
            lane = jnp.broadcast_to((cols % 4).astype(jnp.uint32),
                                    shape)

            def run_block(b, w, off, sweeps):
                bx, wx = (_extend(b, h, row_axes, col_axes),
                          _extend(w, h, row_axes, col_axes))
                bx, wx = dk.bitplane_shard_sweeps(
                    bx, wx, thresholds, g, lane, n_sweeps=sweeps,
                    seed=seed, start_offset=off, interpret=interpret)
                return bx[h:-h, h:-h], wx[h:-h, h:-h]

        def body(j, carry):
            b, w = carry
            off = start + jnp.uint32(2 * k) * j.astype(jnp.uint32)
            return run_block(b, w, off, k)

        b, w = black, white
        if n_blocks:
            b, w = jax.lax.fori_loop(0, n_blocks, body, (b, w))
        if rem:
            b, w = run_block(b, w,
                             start + jnp.uint32(2 * k * n_blocks), rem)
        return b, w

    return (jax.jit(_sweeps, donate_argnums=(0, 1)),
            jax.sharding.NamedSharding(mesh, spec))
