"""End-to-end training driver with fault tolerance.

``python -m repro.launch.train --arch internlm2-1.8b --smoke --steps 200``

Runs the sharded train step on whatever devices exist (full production
configs are exercised via the dry-run; on this CPU container use --smoke),
with: deterministic restart-exact data skip, periodic async checkpoints,
auto-restore from the latest checkpoint, and optional simulated preemption
(--die-at) to demonstrate the restart path end-to-end.
"""
import argparse
import os
import time

import jax
import jax.numpy as jnp

from repro.ckpt import Checkpointer
from repro.configs import SHAPES, get_config, get_smoke_config
from repro.data import DataIterator
from repro.launch.mesh import make_debug_mesh
from repro.models import init_model
from repro.train import OptConfig, make_train_step, opt_init
from repro.train.sharding import param_shardings


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--die-at", type=int, default=0,
                    help="simulate a node failure after this step")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(
        args.arch)
    mesh = make_debug_mesh()
    print(f"arch={cfg.name} mesh={dict(mesh.shape)} "
          f"devices={len(jax.devices())}")

    params = init_model(cfg, jax.random.PRNGKey(args.seed))
    p_sh = param_shardings(cfg, params, mesh)
    params = jax.tree.map(jax.device_put, params, p_sh)
    opt_state = opt_init(params)

    ocfg = OptConfig(lr=args.lr, warmup=min(20, args.steps // 5 + 1),
                     total_steps=args.steps)
    step_fn = jax.jit(make_train_step(cfg, ocfg, mesh=mesh))

    start_step = 0
    ckpt = None
    if args.ckpt_dir:
        ckpt = Checkpointer(args.ckpt_dir)
        if ckpt.latest_step() is not None:
            start_step, restored = ckpt.restore(
                {"params": params, "opt": opt_state})
            params, opt_state = restored["params"], restored["opt"]
            print(f"restored checkpoint at step {start_step}")

    it = DataIterator(cfg, SHAPES["train_4k"], seed=args.seed,
                      batch_override=args.batch, seq_override=args.seq)
    it.skip_to(start_step)

    t0 = time.time()
    for _ in range(start_step, args.steps):
        step, batch = next(it)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if (step + 1) % args.log_every == 0 or step == start_step:
            print(f"step {step + 1:5d} loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"lr={float(metrics['lr']):.2e} "
                  f"({(time.time() - t0):.1f}s)")
        if ckpt and (step + 1) % args.ckpt_every == 0:
            ckpt.save_async(step + 1, {"params": params, "opt": opt_state})
        if args.die_at and step + 1 == args.die_at:
            if ckpt:
                ckpt.wait()
            print(f"simulated failure at step {step + 1}; restart me")
            return 42
    if ckpt:
        ckpt.save(args.steps, {"params": params, "opt": opt_state})
        ckpt.wait()
    print("done")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
