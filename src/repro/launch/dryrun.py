import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This is the proof that the distribution config is coherent without real
hardware: ``jax.jit(step, in_shardings, out_shardings).lower(...)
.compile()`` must succeed on the single-pod (16x16) and multi-pod
(2x16x16) production meshes for every assigned architecture x input shape,
plus the paper's own Ising workload.  Parameters/optimizer/caches are
``jax.eval_shape`` abstractions -- nothing is allocated.

Per cell we record memory_analysis, cost_analysis, the parsed per-kind
collective bytes, and the three roofline terms into a JSON that
EXPERIMENTS.md S Dry-run / S Roofline are generated from.

Usage:
  python -m repro.launch.dryrun --arch all --shape all --mesh both \
      --out results/dryrun.json
  python -m repro.launch.dryrun --arch ising-multispin --mesh multi
"""
import argparse
import json
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES, get_config, get_smoke_config
from repro.configs.base import shape_applicable
from repro.data.pipeline import make_batch
from repro.launch import roofline
from repro.launch.mesh import make_production_mesh
from repro.models import decode_step, forward, init_cache, init_model
from repro.train import OptConfig, make_prefill_step, make_serve_step, \
    make_train_step, opt_init
from repro.train.sharding import (activation_spec, batch_specs, cache_specs,
                                  mesh_axes, param_shardings)

ISING_SHAPES = {
    # (rows, cols) of the full lattice; engine = packed multispin words
    "lat_256k": (262144, 262144),     # 6.9e10 spins ~ paper's 30GB/GPU x16
    "lat_1m": (1048576, 1048576),     # 1.1e12 spins: the 512-chip cell
}


def _ns(mesh, spec):
    return NamedSharding(mesh, spec)


def _tree_shardings(mesh, specs):
    return jax.tree.map(lambda s: _ns(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------

def auto_fsdp(a_params, mesh) -> bool:
    """H2.1 (EXPERIMENTS.md S Perf): FSDP weight all-gathers are pure
    collective waste when params + optimizer state already fit under TP
    alone.  Enable FSDP only when the TP-sharded state (16 bytes/param:
    f32 master + grad + 2 Adam moments) would exceed ~6 GB/device."""
    n_params = sum(float(l.size) for l in jax.tree.leaves(a_params))
    tp = mesh.shape[list(mesh.axis_names)[-1]]
    return n_params * 16.0 / tp > 6e9


def scan_length(cfg, kind: str) -> int:
    """Trip count of the dominant layer scan (H10 cost correction)."""
    if cfg.family == "ssm":
        return 1                      # python loop: costed exactly
    if cfg.family == "moe":
        return cfg.n_layers - cfg.first_dense
    return cfg.n_layers


def lower_lm_cell(arch: str, shape_name: str, mesh, *, fsdp=None,
                  smoke: bool = False, sp: bool = True,
                  scan_unroll: int = 1, microbatches=None):
    """Build + lower one (arch, shape, mesh) cell. Returns lowered.

    fsdp: True/False to force, None = auto policy.  scan_unroll feeds the
    H10 cost correction (compile at 1 and 2, diff = per-layer cost)."""
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return None, why
    sliding = cfg.long_sliding_window if shape.name == "long_500k" else 0

    key = jax.random.PRNGKey(0)
    a_params = jax.eval_shape(lambda k: init_model(cfg, k), key)
    if fsdp is None:
        fsdp = auto_fsdp(a_params, mesh)
    p_sh = param_shardings(cfg, a_params, mesh, fsdp=fsdp)
    act_sh = _ns(mesh, activation_spec(mesh, sp=sp))

    if shape.kind == "train":
        a_opt = jax.eval_shape(opt_init, a_params)
        o_sh = {"mu": p_sh, "nu": p_sh,
                "count": _ns(mesh, P())}
        batch = make_batch(cfg, shape, abstract=True)
        b_sh = _tree_shardings(mesh, batch_specs(
            cfg, mesh, global_batch=shape.global_batch))
        from repro.train.step import cross_entropy

        def loss_fn(p, bb):
            logits, aux = forward(cfg, p, bb, remat=True,
                                  sliding_window=sliding,
                                  act_sharding=act_sh,
                                  scan_unroll=scan_unroll)
            ce = cross_entropy(logits, bb["labels"])
            return ce + 0.01 * aux, (ce, aux)

        # H9: gradient accumulation bounds live activation memory; 4
        # microbatches for full-size train cells (smoke stays at 1).
        # The cost-accounting pass (microbatches=1 override) avoids
        # nesting the layer scan inside a second uncounted loop.
        if microbatches is None:
            mb = 1 if smoke or shape.global_batch % 4 else 4
        else:
            mb = microbatches
        step = make_train_step(cfg, OptConfig(), loss_fn=loss_fn,
                               microbatches=mb)
        jitted = jax.jit(step,
                         in_shardings=(p_sh, o_sh, b_sh),
                         out_shardings=(p_sh, o_sh, None),
                         donate_argnums=(0, 1))
        return jitted.lower(a_params, a_opt, batch), None

    if shape.kind == "prefill":
        batch = make_batch(cfg, shape, abstract=True)
        batch.pop("labels")
        b_sh = {k: v for k, v in _tree_shardings(
            mesh, batch_specs(cfg, mesh,
                              global_batch=shape.global_batch)).items()
            if k in batch}

        def prefill(params, b):
            logits, _ = forward(cfg, params, b, remat=False,
                                sliding_window=sliding,
                                act_sharding=act_sh,
                                scan_unroll=scan_unroll)
            return logits
        jitted = jax.jit(prefill, in_shardings=(p_sh, b_sh),
                         out_shardings=None)
        return jitted.lower(a_params, batch), None

    # decode
    b = shape.global_batch
    maxlen = shape.seq_len
    a_cache = jax.eval_shape(
        lambda: init_cache(cfg, b, maxlen, window=sliding))
    c_specs = cache_specs(cfg, a_cache, mesh, batch=b)
    c_sh = jax.tree.map(lambda s: _ns(mesh, s), c_specs,
                        is_leaf=lambda x: isinstance(x, P))
    dp_axes, _ = mesh_axes(mesh)
    dp = 1
    for a in dp_axes:
        dp *= mesh.shape[a]
    tok_spec = P(dp_axes if len(dp_axes) > 1 else dp_axes[0], None) \
        if b % dp == 0 else P(None, None)
    tokens = jax.ShapeDtypeStruct((b, 1), jnp.int32)

    def serve(params, cache, toks):
        logits, new_cache = decode_step(cfg, params, cache, toks,
                                        sliding_window=sliding,
                                        scan_unroll=scan_unroll)
        nxt = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        return nxt.astype(toks.dtype), new_cache

    jitted = jax.jit(serve,
                     in_shardings=(p_sh, c_sh, _ns(mesh, tok_spec)),
                     out_shardings=None, donate_argnums=(1,))
    return jitted.lower(a_params, a_cache, tokens), None


# ---------------------------------------------------------------------------
# Ising cells (the paper's workload on the production mesh)
# ---------------------------------------------------------------------------

def lower_ising_cell(shape_name: str, mesh, engine: str = "multispin"):
    """Distributed Ising sweep on packed uint32 words (multispin), 32
    replica bitplanes (bitplane, DESIGN.md S8), or int8 planes (basic),
    pencil-decomposed over the whole mesh."""
    from repro.core import distributed as dist

    n, m = ISING_SHAPES[shape_name]
    if engine == "multispin":
        step_fn, sharding = dist.make_packed_ising_step(mesh, n=n, m=m,
                                                        seed=0, n_sweeps=1)
        half_words = m // 2 // 8
        black = jax.ShapeDtypeStruct((n, half_words), jnp.uint32)
        white = jax.ShapeDtypeStruct((n, half_words), jnp.uint32)
    elif engine == "bitplane":
        step_fn, sharding = dist.make_bitplane_ising_step(mesh, n=n, m=m,
                                                          seed=0,
                                                          n_sweeps=1)
        black = jax.ShapeDtypeStruct((n, m // 2), jnp.uint32)
        white = jax.ShapeDtypeStruct((n, m // 2), jnp.uint32)
    else:
        step_fn, sharding = dist.make_ising_step(mesh, n=n, m=m, seed=0,
                                                 n_sweeps=1)
        black = jax.ShapeDtypeStruct((n, m // 2), jnp.int8)
        white = jax.ShapeDtypeStruct((n, m // 2), jnp.int8)
    beta = jax.ShapeDtypeStruct((), jnp.float32)
    sweep0 = jax.ShapeDtypeStruct((), jnp.uint32)
    return step_fn.lower(black, white, beta, sweep0), None


# ---------------------------------------------------------------------------
# cell runner
# ---------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, mesh_kind: str, *,
             fsdp=None, smoke: bool = False,
             verbose: bool = True) -> Dict:
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    rec: Dict = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                 "chips": mesh.size}
    t0 = time.time()
    try:
        if arch.startswith("ising"):
            engine = arch.split("-", 1)[1] if "-" in arch else "multispin"
            lowered, skip = lower_ising_cell(shape_name, mesh, engine)
            n, m = ISING_SHAPES[shape_name]
            rec["spins"] = float(n) * m
        else:
            with mesh:
                lowered, skip = lower_lm_cell(arch, shape_name, mesh,
                                              fsdp=fsdp, smoke=smoke)
        if lowered is None:
            rec["status"] = "skipped"
            rec["skip_reason"] = skip
            return rec
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t0, 1)
        cost = roofline.extract_cost(compiled)
        mem = roofline.memory_per_device(compiled)
        coll = roofline.collective_bytes(compiled.as_text())

        if not arch.startswith("ising") and not smoke:
            # H10: XLA cost_analysis counts while-loop bodies ONCE; the
            # layer scan has L iterations.  Compile again with the scan
            # body unrolled x2 (cost accounting at microbatches=1) and
            # reconstruct: total = c1 + (c2 - c1) * (L - 1).
            from repro.configs import get_config
            cfg = get_config(arch)
            L = scan_length(cfg, "")
            if L > 1:
                with mesh:
                    low1, _ = lower_lm_cell(arch, shape_name, mesh,
                                            fsdp=fsdp, scan_unroll=1,
                                            microbatches=1)
                    low2, _ = lower_lm_cell(arch, shape_name, mesh,
                                            fsdp=fsdp, scan_unroll=2,
                                            microbatches=1)
                c1 = roofline.extract_cost(low1.compile())
                comp2 = low2.compile()
                c2 = roofline.extract_cost(comp2)
                coll1 = roofline.collective_bytes(low1.compile().as_text())
                coll2 = roofline.collective_bytes(comp2.as_text())
                cost = {k: c1[k] + max(c2[k] - c1[k], 0.0) * (L - 1)
                        for k in c1}
                coll = {k: coll1.get(k, 0)
                        + max(coll2.get(k, 0) - coll1.get(k, 0), 0)
                        * (L - 1) for k in coll1}
                rec["scan_trip_count"] = L
                rec["cost_correction"] = "unroll-diff (H10)"

        terms = roofline.roofline_terms(cost["flops"], cost["bytes"], coll,
                                        mesh.size)
        rec.update(status="ok", **cost, collectives=coll, **terms,
                   memory=mem)
        if arch.startswith("ising"):
            # flip-cost attribution (EXPERIMENTS.md S Roofline): the
            # analytic bytes/flip of the engine's state layout next to
            # what the compiled HLO actually moves, plus the flips/ns
            # the TPU roofline admits -- the honest denominator for
            # every committed flips/ns number
            engine = arch.split("-", 1)[1] if "-" in arch else "multispin"
            fc = roofline.flip_cost(engine)
            flips_per_dev = rec["spins"] * fc.replicas / mesh.size
            rec["engine"] = engine
            rec["model_bytes_per_flip"] = fc.bytes_per_flip
            rec["hlo_bytes_per_flip"] = cost["bytes"] / flips_per_dev
            rec["peak_flips_per_ns_per_device"] = \
                roofline.roofline_flips_per_ns(engine, "tpu")
        if verbose:
            print(f"-- {arch} x {shape_name} x {mesh_kind} "
                  f"({rec['compile_s']}s)")
            print(f"   memory_analysis: {mem}")
            print(f"   cost_analysis: flops={cost['flops']:.3e} "
                  f"bytes={cost['bytes']:.3e}")
            print(f"   collectives: { {k: v for k, v in coll.items() if v} }")
            print(f"   roofline: compute={terms['t_compute_s']:.4f}s "
                  f"memory={terms['t_memory_s']:.4f}s "
                  f"collective={terms['t_collective_s']:.4f}s "
                  f"dominant={terms['dominant']}")
    except Exception as e:  # a failing cell is a bug; record and continue
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            print(f"-- {arch} x {shape_name} x {mesh_kind} FAILED: "
                  f"{rec['error']}")
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help="arch id | all | ising-multispin | "
                         "ising-bitplane | ising-basic")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--no-fsdp", action="store_true",
                    help="force FSDP off (default: auto policy)")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced configs (CI sanity of the harness)")
    args = ap.parse_args(argv)

    archs = list(ARCH_IDS) if args.arch == "all" else [args.arch]
    meshes = (["single", "multi"] if args.mesh == "both" else [args.mesh])

    results = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    done = {(r["arch"], r["shape"], r["mesh"]) for r in results
            if r.get("status") in ("ok", "skipped")}

    for arch in archs:
        shapes = (list(ISING_SHAPES) if arch.startswith("ising")
                  else list(SHAPES))
        if args.shape != "all":
            shapes = [args.shape]
        for shape in shapes:
            for mk in meshes:
                if (arch, shape, mk) in done:
                    continue
                rec = run_cell(arch, shape, mk,
                               fsdp=False if args.no_fsdp else None,
                               smoke=args.smoke)
                results = [r for r in results
                           if (r["arch"], r["shape"], r["mesh"])
                           != (arch, shape, mk)]
                results.append(rec)
                os.makedirs(os.path.dirname(args.out) or ".",
                            exist_ok=True)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
    bad = [r for r in results if r.get("status") == "error"]
    print(f"\n{len(results)} cells, {len(bad)} errors")
    return 1 if bad else 0


if __name__ == "__main__":
    raise SystemExit(main())
