"""DEPRECATED seed-era scaffold -- use :mod:`repro.serve` instead.

This module used to drive continuous-batching token decoding for the
LLM stack the repo was seeded from.  That workload has nothing to do
with the Ising study; the serving surface of THIS repo is the
fault-tolerant sweep farm:

    python -m repro serve DIR          # the server (DESIGN.md S14)
    python -m repro.serve.smoke        # its crash drill

The module is kept as an import-compatible stub for one release so
stale ``from repro.launch.serve import main`` call sites fail with a
pointer instead of an ImportError traceback.
"""
from __future__ import annotations

import sys

_MSG = ("repro.launch.serve is retired: it served LLM token decoding "
        "from the repo's seed, not Ising sweeps.  Use the sweep-farm "
        "service instead: `python -m repro serve DIR` "
        "(repro.serve, DESIGN.md S14).")


def main(argv=None) -> int:
    print(_MSG, file=sys.stderr)
    return 2


if __name__ == "__main__":
    raise SystemExit(main())
