"""Batched serving driver: continuous-batching-lite over serve_step.

``python -m repro.launch.serve --arch internlm2-1.8b --smoke \
      --requests 12 --batch 4 --max-new 16``

A fixed pool of B decode slots runs the jitted single-token serve_step;
finished sequences (EOS or max-new) free their slot, and queued requests
are admitted by resetting that slot's cache lane.  Per-slot state is a
(length, remaining) pair; the KV cache is shared across slots as one
batched pytree -- the standard TPU serving layout.  Prefill is one
forward pass per admitted request (teacher-forced into the cache).
"""
import argparse
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models import decode_step, init_cache, init_model
from repro.train import make_serve_step


def _admit(cfg, params, cache, slot, prompt, step_fn):
    """Prefill `prompt` (list[int]) into cache lane `slot` token-by-token.

    Lane-wise admission keeps the example simple; a production stack would
    run a batched prefill kernel (the prefill_32k dry-run cells cover that
    path's lowering).
    """
    for t in prompt:
        tok = jnp.zeros((cache_batch(cache), 1), jnp.int32)
        tok = tok.at[slot, 0].set(t)
        _, cache = step_fn(params, cache, tok)
    return cache


def cache_batch(cache):
    for leaf in jax.tree.leaves(cache):
        if hasattr(leaf, "ndim") and leaf.ndim >= 2:
            return leaf.shape[1]
    raise ValueError


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(
        args.arch)
    key = jax.random.PRNGKey(args.seed)
    params = init_model(cfg, key)
    serve = jax.jit(make_serve_step(cfg))
    decode = jax.jit(lambda p, c, t: decode_step(cfg, p, c, t))

    # request queue: random prompts of 3-6 tokens
    queue = deque()
    for r in range(args.requests):
        k = jax.random.fold_in(key, r)
        plen = int(jax.random.randint(k, (), 3, 7))
        queue.append((r, list(np.asarray(
            jax.random.randint(k, (plen,), 1, cfg.vocab)))))

    b = args.batch
    cache = init_cache(cfg, b, args.max_len)
    cur_tok = jnp.zeros((b, 1), jnp.int32)
    remaining = np.zeros(b, np.int32)           # 0 = free slot
    req_of_slot = [-1] * b
    outputs = {}
    t0 = time.time()
    steps = 0

    while queue or remaining.any():
        # admit into free slots (simplified: shared cache length means we
        # restart the pool when all slots free; fine for equal-length demo)
        for s in range(b):
            if remaining[s] == 0 and queue:
                rid, prompt = queue.popleft()
                for t in prompt:               # lane prefill
                    tok = cur_tok.at[s, 0].set(t)
                    _, cache_new = decode(params, cache, tok)
                    cache = cache_new
                req_of_slot[s] = rid
                remaining[s] = args.max_new
                outputs[rid] = []
        # one batched decode step for every active slot
        nxt, cache = serve(params, cache, cur_tok)
        steps += 1
        nxt_np = np.asarray(nxt)
        for s in range(b):
            if remaining[s] > 0:
                outputs[req_of_slot[s]].append(int(nxt_np[s, 0]))
                remaining[s] -= 1
        cur_tok = nxt
        if int(cache["length"]) >= args.max_len - 1:
            break

    dt = time.time() - t0
    done = sum(1 for v in outputs.values() if v)
    print(f"served {done}/{args.requests} requests, {steps} batched steps,"
          f" {steps * b / dt:.1f} tok/s (CPU)")
    for rid in sorted(outputs)[:4]:
        print(f"  req {rid}: {outputs[rid][:8]}...")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
