"""Ising simulation driver (the paper's workload).

``python -m repro.launch.simulate --size 512 --temp 2.0 --sweeps 2000``

Single-process: picks the engine, runs sweeps with periodic measurement
and atomic checkpoints, reports flips/ns and magnetization vs Onsager.
For the multi-device engine use --distributed (shards over all local
devices; the production 256/512-chip decomposition is validated by
repro.launch.dryrun --arch ising-multispin).
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import observables as obs
from repro.core.sim import ENGINES, SimConfig, Simulation


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", type=int, default=512)
    ap.add_argument("--temp", type=float, default=2.0)
    ap.add_argument("--sweeps", type=int, default=1000)
    ap.add_argument("--measure-every", type=int, default=100)
    ap.add_argument("--engine", default="multispin", choices=ENGINES)
    ap.add_argument("--seed", type=int, default=1234)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--restore", action="store_true")
    ap.add_argument("--distributed", action="store_true")
    args = ap.parse_args(argv)

    if args.distributed:
        return _run_distributed(args)

    if args.restore and args.ckpt:
        sim = Simulation.restore(args.ckpt)
        print(f"restored at sweep {sim.step_count}")
    else:
        sim = Simulation(SimConfig(n=args.size, m=args.size,
                                   temperature=args.temp, seed=args.seed,
                                   engine=args.engine))
    t0 = time.time()
    done = sim.step_count
    while done < args.sweeps:
        chunk = min(args.measure_every, args.sweeps - done)
        sim.run(chunk)
        done = sim.step_count
        m = sim.magnetization()
        print(f"sweep {done:7d} m={m:+.4f}")
        if args.ckpt:
            sim.save(args.ckpt)
    dt = time.time() - t0
    flips = args.size * args.size * (args.sweeps - 0)
    exact = float(obs.onsager_magnetization(args.temp))
    print(f"flips/ns={flips/dt/1e9:.4f}  |m|={abs(sim.magnetization()):.4f} "
          f"onsager={exact:.4f}")
    return 0


def _run_distributed(args) -> int:
    from repro.core import distributed as dist, lattice as lat, \
        multispin as ms
    n = args.size
    nd = len(jax.devices())
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((nd, 1), ("data", "model"))
    key = jax.random.PRNGKey(args.seed)
    full = lat.init_lattice(key, n, n)
    beta = jnp.float32(1.0 / args.temp)
    if args.engine == "multispin":
        bw, ww = ms.pack_lattice(*lat.split_checkerboard(full))
        step, sh = dist.make_packed_ising_step(
            mesh, n=n, m=n, seed=args.seed,
            n_sweeps=args.measure_every)
    else:
        bw, ww = lat.split_checkerboard(full)
        step, sh = dist.make_ising_step(mesh, n=n, m=n, seed=args.seed,
                                        n_sweeps=args.measure_every)
    bw, ww = jax.device_put(bw, sh), jax.device_put(ww, sh)
    t0 = time.time()
    for s in range(0, args.sweeps, args.measure_every):
        bw, ww = step(bw, ww, beta, jnp.uint32(s))
    jax.block_until_ready((bw, ww))
    dt = time.time() - t0
    print(f"{nd} devices: flips/ns={n*n*args.sweeps/dt/1e9:.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
