"""Roofline-term extraction from compiled dry-run artifacts, plus the
Ising sweep kernels' analytic flip-cost model.

Per (arch x shape x mesh):
  compute   = HLO_FLOPs  / (chips * PEAK_FLOPS)
  memory    = HLO_bytes  / (chips * HBM_BW)
  collective= coll_bytes / (chips * ICI_BW)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``.  Collective
bytes are NOT in cost_analysis: we parse the compiled (post-SPMD) HLO text,
build a name->shape table from op definitions, and sum the operand sizes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute.  Hardware constants: TPU v5e-class -- 197 bf16
TFLOP/s, 819 GB/s HBM, ~50 GB/s/link ICI (task spec).

The flip-cost model (:data:`ISING_FLIP_COSTS`) is the per-engine
bytes/flip and flops/flip of one attempted Metropolis update, derived
from each engine's actual state layout (DESIGN.md S2/S8/S9) -- the
denominators Block et al. (arXiv 1007.3726) and Bisson et al. (arXiv
2502.18624) anchor their multi-spin numbers against.  Every bench row
with a flips/ns measurement divides by the matching roofline bound
(:func:`pct_of_roofline`), so committed numbers are self-describing
about how far from the hardware limit they ran.
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Dict, Optional

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
ICI_BW = 50e9            # bytes/s / link

#: Nominal peak (flops/s, HBM bytes/s) per jax backend, used to turn a
#: measured flips/ns into a %-of-roofline.  ``tpu`` is the v5e-class
#: chip above; ``gpu`` is the paper's V100 (14 f32 TFLOP/s, 900 GB/s
#: HBM2); ``cpu`` is a nominal single core of this CI container class
#: (~100 f32 GFLOP/s peak SIMD+FMA, ~25 GB/s single-core stream BW).
#: CPU numbers are order-of-magnitude attribution, not a measured
#: STREAM run -- see EXPERIMENTS.md S Roofline for what a CPU
#: pct_of_roofline does (and does not) mean.
BACKEND_PEAKS: Dict[str, Dict[str, float]] = {
    "tpu": {"flops": PEAK_FLOPS, "mem_bw": HBM_BW},
    "gpu": {"flops": 14e12, "mem_bw": 900e9},
    "cpu": {"flops": 100e9, "mem_bw": 25e9},
}


@dataclass(frozen=True)
class FlipCost:
    """Analytic cost of ONE attempted (replica-)flip for an engine.

    ``bytes_per_flip`` is the HBM traffic of a half-sweep color update
    divided by the updates it performs: read target plane + read
    opposite plane + write target plane, at the engine's packing
    density.  ``flops_per_flip`` counts the arithmetic of the accept
    decision (neighbor reduction + threshold compare + Philox share).
    ``replicas`` is how many replica-spins one lattice site carries
    (bitplane packs 32) -- flips/ns rows for those engines already
    count replica-flips, so the cost here is *per replica-flip*.
    """

    bytes_per_flip: float
    flops_per_flip: float
    replicas: int = 1


#: Derivations (3 planes touched per half-sweep; density = bytes/site):
#: * int8 color planes (basic/basic_philox/stencil_pallas): 1 B/site
#:   -> 3 B/flip; ~10 ops (4 neighbor adds, couple, threshold, Philox
#:   share) per flip.
#: * nibble multispin: 8 spins/uint32 word = 0.5 B/site -> 1.5 B/flip;
#:   word-parallel ops amortize to ~4/flip.
#: * bitplane: 32 replicas/word = 0.125 B/replica-site -> 0.375
#:   B/replica-flip; the 8-op CSA + 10-class threshold per word serves
#:   32 replicas -> ~1.25 ops/replica-flip (DESIGN.md S8).
#: * tensorcore: 4 int8 quarter-planes, all read + one written per
#:   plane update -> 5 B/flip; the banded neighbor matmul does ~2*64
#:   MACs per spin at the default block -- the paper's point that the
#:   MXU recast is compute-wasteful.
#: * spinglass: int8 lattice read/write + 2 quenched coupling planes
#:   -> 5 B/flip; coupling multiplies add ~4 ops.
ISING_FLIP_COSTS: Dict[str, FlipCost] = {
    "basic": FlipCost(3.0, 10.0),
    "basic_philox": FlipCost(3.0, 10.0),
    "stencil_pallas": FlipCost(3.0, 10.0),
    "multispin": FlipCost(1.5, 4.0),
    "multispin_pallas": FlipCost(1.5, 4.0),
    "bitplane": FlipCost(0.375, 1.25, replicas=32),
    "bitplane_pallas": FlipCost(0.375, 1.25, replicas=32),
    "tensorcore": FlipCost(5.0, 128.0),
    "spinglass": FlipCost(5.0, 14.0),
}


def flip_cost(engine: str) -> FlipCost:
    """The flip-cost model row for ``engine`` (KeyError when unmodeled,
    e.g. ``wolff`` -- a cluster flip is not a sweep flip)."""
    return ISING_FLIP_COSTS[engine]


def roofline_flips_per_ns(engine: str, backend: str,
                          k: int = 1) -> Optional[float]:
    """Peak attempted flips/ns the backend's roofline admits.

    ``min(mem_bw / bytes_per_flip, flops / flops_per_flip)``.  ``k`` is
    the resident tier's sweeps-per-dispatch (DESIGN.md S9): a k-sweep
    resident block crosses HBM once instead of k times, dividing
    bytes/flip by k; the arithmetic is unchanged.  Returns None for
    engines or backends outside the model.
    """
    peaks = BACKEND_PEAKS.get(backend)
    cost = ISING_FLIP_COSTS.get(engine)
    if peaks is None or cost is None:
        return None
    mem_bound = peaks["mem_bw"] / (cost.bytes_per_flip / max(k, 1))
    compute_bound = peaks["flops"] / cost.flops_per_flip
    return min(mem_bound, compute_bound) / 1e9


def pct_of_roofline(flips_per_ns: float, engine: str, backend: str,
                    k: int = 1) -> Optional[float]:
    """Measured flips/ns as a percentage of the backend's roofline
    bound for this engine (None outside the model)."""
    peak = roofline_flips_per_ns(engine, backend, k=k)
    if peak is None or peak <= 0.0:
        return None
    return 100.0 * flips_per_ns / peak

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+?)\s+"
                     r"([a-z][\w\-]*)\(")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO shape string."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum operand bytes per collective kind from HLO text.

    Uses each op's result shape for in-place-ish collectives (all-reduce,
    collective-permute) and the max(result, summed-operands) heuristic via
    the name->shape table for reshape-ing collectives.
    """
    shapes: Dict[str, str] = {}
    per_kind = {k: 0 for k in _COLLECTIVES}
    lines = hlo_text.splitlines()
    for ln in lines:
        m = _DEF_RE.match(ln)
        if m:
            shapes[m.group(1)] = m.group(2)
    operand_re = re.compile(r"%?([\w\.\-]+)")
    for ln in lines:
        m = _DEF_RE.match(ln)
        if not m:
            continue
        name, result_shape, op = m.groups()
        kind = next((k for k in _COLLECTIVES if op.startswith(k)), None)
        if kind is None:
            continue
        # operand list: text between the first '(' and matching ')'
        inner = ln[ln.index(op) + len(op) + 1:]
        depth = 1
        args = []
        buf = ""
        for ch in inner:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    args.append(buf)
                    break
            if depth >= 1 and ch != ")":
                buf += ch
        operand_bytes = 0
        for tok in args[0].split(",") if args else []:
            tok = tok.strip()
            mm = operand_re.match(tok.lstrip("%"))
            if mm and mm.group(1) in shapes:
                operand_bytes += _shape_bytes(shapes[mm.group(1)])
        result_bytes = _shape_bytes(result_shape)
        per_kind[kind] += max(operand_bytes, result_bytes) \
            if kind in ("all-gather",) else (operand_bytes or result_bytes)
    return per_kind


def extract_cost(compiled) -> Dict[str, float]:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    return {"flops": flops, "bytes": byts}


def memory_per_device(compiled):
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return None
    if ma is None:
        return None
    out = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes"):
        v = getattr(ma, attr, None)
        if v is not None:
            out[attr] = int(v)
    return out


def roofline_terms(flops: float, byts: float, coll: Dict[str, int],
                   n_chips: int) -> Dict[str, float]:
    """cost_analysis on an SPMD module is per-device already; collective
    bytes parsed from the partitioned HLO are likewise per-device."""
    coll_total = float(sum(coll.values()))
    t_compute = flops / PEAK_FLOPS
    t_memory = byts / HBM_BW
    t_coll = coll_total / ICI_BW
    dom = max((t_compute, "compute"), (t_memory, "memory"),
              (t_coll, "collective"))[1]
    return {"t_compute_s": t_compute, "t_memory_s": t_memory,
            "t_collective_s": t_coll, "dominant": dom,
            "coll_bytes": coll_total}


def model_flops(n_params_active: float, n_tokens: float,
                kind: str) -> float:
    """6ND for a train step, 2ND for forward-only (prefill/decode)."""
    return (6.0 if kind == "train" else 2.0) * n_params_active * n_tokens


def count_params(abstract_params, active_moe_frac: float = 1.0,
                 moe_paths=("moe/wi", "moe/wg", "moe/wo")) -> Dict[str, float]:
    """(total, active) param counts from an abstract (eval_shape) pytree."""
    import jax
    total = 0.0
    active = 0.0
    flat = jax.tree_util.tree_flatten_with_path(abstract_params)[0]
    for path, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        n = float(leaf.size)
        total += n
        if any(p in key for p in moe_paths):
            active += n * active_moe_frac
        elif "embed" in key:
            active += 0.0  # embedding lookups are gathers, not matmuls
        else:
            active += n
    return {"total": total, "active": active}
