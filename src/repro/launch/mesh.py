"""Production mesh factories.

Functions, not module-level constants, so importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before first init).

Single pod: (16, 16) = 256 chips, axes (data, model).
Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model) -- ``pod`` is
an outer data-parallel ring (gradient all-reduce crosses the inter-pod
links; everything else stays inside a pod).
"""
from __future__ import annotations

import jax


def make_mesh(shape, axes):
    """Version-compat ``jax.make_mesh``: pass explicit Auto axis_types on
    jax >= 0.5 (where AxisType exists), plain mesh on older releases
    (where every axis is Auto implicitly)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_debug_mesh(n_devices: int = 0, model: int = 2):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = n_devices or len(jax.devices())
    model = min(model, n)
    return make_mesh((n // model, model), ("data", "model"))
