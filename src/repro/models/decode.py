"""Decode path: cache construction + single-token decode_step per family.

Cache layout: per-stack stacked arrays with a leading layer axis, threaded
through the same ``lax.scan`` as the forward pass, plus one global
``length`` scalar.  KV caches are bf16; SSM/recurrent states are f32.

Sliding-window long-context decode uses a RING-BUFFER cache of
``window`` slots (slot = position % window, keys roped at write time, so
slots carry absolute positions); see EXPERIMENTS.md S Perf H3 -- this is
what makes the 500k-context cells run at the memory-roofline minimum.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from . import layers as L
from . import ssm as S
from .model import (_apply_attn_block, _apply_moe_block, _norm_apply,
                    _sinusoid, xlstm_kinds)

Cache = Dict[str, Any]


def _kv(n_layers, b, maxlen, g, hd):
    return {"k": jnp.zeros((n_layers, b, maxlen, g, hd), jnp.bfloat16),
            "v": jnp.zeros((n_layers, b, maxlen, g, hd), jnp.bfloat16)}


def init_cache(cfg: ArchConfig, batch_size: int, max_len: int,
               enc_out=None, params=None, window: int = 0) -> Cache:
    """``window > 0``: allocate attention KV as a ring buffer of
    min(max_len, window) slots (sliding-window decode; H3 in
    EXPERIMENTS.md S Perf -- the 500k-context memory fix)."""
    b = batch_size
    if window:
        max_len = min(max_len, window)
    cache: Cache = {"length": jnp.int32(0)}
    if cfg.family in ("dense", "vlm"):
        cache["kv"] = _kv(cfg.n_layers, b, max_len, cfg.n_kv_heads,
                          cfg.head_dim)
    elif cfg.family == "moe":
        if cfg.mla:
            def mla_c(n):
                return {"ckv": jnp.zeros((n, b, max_len, cfg.kv_lora),
                                         jnp.bfloat16),
                        "kr": jnp.zeros((n, b, max_len, cfg.qk_rope),
                                        jnp.bfloat16)}
            cache["dense_kv"] = mla_c(cfg.first_dense)
            cache["moe_kv"] = mla_c(cfg.n_layers - cfg.first_dense)
        else:
            cache["dense_kv"] = _kv(cfg.first_dense, b, max_len,
                                    cfg.n_kv_heads, cfg.head_dim)
            cache["moe_kv"] = _kv(cfg.n_layers - cfg.first_dense, b,
                                  max_len, cfg.n_kv_heads, cfg.head_dim)
    elif cfg.family == "hybrid":
        d_inner = cfg.mamba_expand * cfg.d_model
        nh = d_inner // cfg.mamba_head_dim
        cache["ssm"] = {
            "state": jnp.zeros((cfg.n_layers, b, nh, cfg.ssm_state,
                                cfg.mamba_head_dim), jnp.float32),
            "conv_tail": jnp.zeros((cfg.n_layers, b, 3,
                                    d_inner + 2 * cfg.ssm_state),
                                   jnp.bfloat16)}
        cache["kv"] = _kv(cfg.n_layers, b, max_len, cfg.n_kv_heads,
                          cfg.head_dim)
    elif cfg.family == "ssm":
        blocks = []
        for kind in xlstm_kinds(cfg):
            if kind == "slstm":
                blocks.append({"h": jnp.zeros((b, cfg.d_model), jnp.float32),
                               "c": jnp.zeros((b, cfg.d_model), jnp.float32),
                               "n": jnp.ones((b, cfg.d_model), jnp.float32)})
            else:
                blocks.append({"state": jnp.zeros(
                    (b, cfg.n_heads, cfg.head_dim, cfg.head_dim),
                    jnp.float32)})
        cache["blocks"] = blocks
    elif cfg.family == "audio":
        cache["kv"] = _kv(cfg.n_layers, b, max_len, cfg.n_kv_heads,
                          cfg.head_dim)
        # cross-attention k/v precomputed from the encoder output
        if enc_out is not None and params is not None:
            def cross(p):
                k = jnp.einsum("bsd,dhk->bshk", L.cast_c(enc_out),
                               L.cast_c(p["xattn"]["wk"]),
                               preferred_element_type=jnp.float32)
                v = jnp.einsum("bsd,dhk->bshk", L.cast_c(enc_out),
                               L.cast_c(p["xattn"]["wv"]),
                               preferred_element_type=jnp.float32)
                if "bk" in p["xattn"]:
                    k = k + p["xattn"]["bk"]
                    v = v + p["xattn"]["bv"]
                return (k.astype(jnp.bfloat16), v.astype(jnp.bfloat16))
            ck, cv = jax.vmap(cross)(params["dec_blocks"])
            cache["cross"] = {"k": ck, "v": cv}
        else:
            cache["cross"] = {
                "k": jnp.zeros((cfg.n_layers, b, cfg.enc_seq,
                                cfg.n_kv_heads, cfg.head_dim), jnp.bfloat16),
                "v": jnp.zeros((cfg.n_layers, b, cfg.enc_seq,
                                cfg.n_kv_heads, cfg.head_dim), jnp.bfloat16)}
    return cache


def decode_step(cfg: ArchConfig, params, cache: Cache, tokens,
                *, sliding_window: int = 0, scan_unroll: int = 1):
    """tokens: (B, 1) int32 -> (logits (B,1,V), new_cache)."""
    na = _norm_apply(cfg)
    # ring mode is a static property of the cache allocation
    ring = bool(sliding_window) and "kv" in cache \
        and cache["kv"]["k"].shape[2] <= sliding_window
    x = L.embed(params["embed"], tokens)
    length = cache["length"]
    positions = length + jnp.arange(1)
    new_cache: Cache = {"length": length + 1}

    if cfg.family in ("dense", "vlm"):
        def body(carry, xs):
            p, k_l, v_l = xs
            lc = {"attn": {"k": k_l, "v": v_l, "length": length}}
            y, nc = _apply_attn_block(cfg, p, carry, positions, cache=lc,
                                      sliding_window=sliding_window,
                                      ring=ring)
            return y, (nc["attn"]["k"], nc["attn"]["v"])
        x, (ks, vs) = jax.lax.scan(
            body, x, (params["blocks"], cache["kv"]["k"], cache["kv"]["v"]), unroll=scan_unroll)
        new_cache["kv"] = {"k": ks, "v": vs}

    elif cfg.family == "moe":
        def mk_local(kv, i=None):
            if cfg.mla:
                return {"attn": {"ckv": kv[0], "kr": kv[1],
                                 "length": length}}
            return {"attn": {"k": kv[0], "v": kv[1], "length": length}}

        def unpack(nc):
            a = nc["attn"]
            if cfg.mla:
                return (a["ckv"], a["kr"])
            return (a["k"], a["v"])

        def cache_arrays(c):
            if cfg.mla:
                return (c["ckv"], c["kr"])
            return (c["k"], c["v"])

        def rewrap(arrs):
            if cfg.mla:
                return {"ckv": arrs[0], "kr": arrs[1]}
            return {"k": arrs[0], "v": arrs[1]}

        def dense_body(carry, xs):
            p, a0, a1 = xs
            y, nc = _apply_attn_block(cfg, p, carry, positions,
                                      cache=mk_local((a0, a1)))
            return y, unpack(nc)
        x, outs = jax.lax.scan(
            dense_body, x,
            (params["dense_blocks"], *cache_arrays(cache["dense_kv"])), unroll=scan_unroll)
        new_cache["dense_kv"] = rewrap(outs)

        def moe_body(carry, xs):
            p, a0, a1 = xs
            y, _, nc = _apply_moe_block(cfg, p, carry, positions,
                                        cache=mk_local((a0, a1)))
            return y, unpack(nc)
        x, outs = jax.lax.scan(
            moe_body, x,
            (params["moe_blocks"], *cache_arrays(cache["moe_kv"])), unroll=scan_unroll)
        new_cache["moe_kv"] = rewrap(outs)

    elif cfg.family == "hybrid":
        shared = params["shared_attn"]
        every = cfg.attn_every
        idxs = jnp.arange(cfg.n_layers)

        def body(carry, xs):
            idx, p, st, tail, k_l, v_l = xs
            h = carry
            h2, nc = S.mamba2_block(
                p["mamba"], na(p["norm1"], h), d_state=cfg.ssm_state,
                expand=cfg.mamba_expand, head_dim=cfg.mamba_head_dim,
                cache={"state": st, "conv_tail": tail})
            h = h + h2

            def with_attn(args):
                hh, kk, vv = args
                lc = {"attn": {"k": kk, "v": vv, "length": length}}
                y, anc = _apply_attn_block(cfg, shared, hh, positions,
                                           cache=lc,
                                           sliding_window=sliding_window,
                                           ring=ring)
                return y, anc["attn"]["k"], anc["attn"]["v"]
            h, k_n, v_n = jax.lax.cond(
                (idx % every) == every - 1, with_attn,
                lambda a: a, (h, k_l, v_l))
            return h, (nc["state"], nc["conv_tail"], k_n, v_n)
        x, (sts, tails, ks, vs) = jax.lax.scan(
            body, x, (idxs, params["blocks"], cache["ssm"]["state"],
                      cache["ssm"]["conv_tail"], cache["kv"]["k"],
                      cache["kv"]["v"]), unroll=scan_unroll)
        # mamba2_block state comes back transposed (h, dk, dv) == (h, N, P)
        new_cache["ssm"] = {"state": sts, "conv_tail": tails}
        new_cache["kv"] = {"k": ks, "v": vs}

    elif cfg.family == "ssm":
        new_blocks = []
        for p, kind, bc in zip(params["blocks_list"], xlstm_kinds(cfg),
                               cache["blocks"]):
            h = na(p["norm1"], x)
            if kind == "slstm":
                y, nc = S.slstm_block(p["cell"], h, cache=bc)
            else:
                y, nc = S.mlstm_block(p["cell"], h, n_heads=cfg.n_heads,
                                      head_dim=cfg.head_dim, cache=bc)
            x = x + y
            new_blocks.append(nc)
        new_cache["blocks"] = new_blocks

    elif cfg.family == "audio":
        x = x + _sinusoid(positions, cfg.d_model).astype(x.dtype)

        def body(carry, xs):
            p, k_l, v_l, xk_l, xv_l = xs
            lc = {"attn": {"k": k_l, "v": v_l, "length": length}}
            y, nc = _apply_attn_block(cfg, p, carry, positions, cache=lc,
                                      enc_kv={"k": xk_l, "v": xv_l})
            return y, (nc["attn"]["k"], nc["attn"]["v"])
        x, (ks, vs) = jax.lax.scan(
            body, x, (params["dec_blocks"], cache["kv"]["k"],
                      cache["kv"]["v"], cache["cross"]["k"],
                      cache["cross"]["v"]), unroll=scan_unroll)
        new_cache["kv"] = {"k": ks, "v": vs}
        new_cache["cross"] = cache["cross"]

    x = na(params["final_norm"], x)
    logits = L.unembed(params["embed"], x)
    return logits, new_cache
