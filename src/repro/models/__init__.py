from .model import init_model, forward, xlstm_kinds  # noqa: F401
from .decode import init_cache, decode_step  # noqa: F401
