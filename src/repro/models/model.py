"""Model assembly: ArchConfig -> init / forward / cache / decode_step.

Families: dense, moe (GQA or MLA), hybrid (Mamba2 + shared attn block),
ssm (xLSTM), vlm (stub patch-embedding prefix + dense backbone), audio
(whisper-style encoder-decoder with a stub conv frontend).

Layer stacks are ``lax.scan`` over stacked params (vmap-init), so compile
time and HLO size are O(1) in depth; each scan body is ``jax.checkpoint``'d
in training for activation rematerialization.  Decode threads a stacked
cache pytree through the same scan.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from . import layers as L
from . import moe as M
from . import ssm as S

Params = Dict[str, Any]

_ACTS = {"silu": jax.nn.silu, "gelu": jax.nn.gelu}


def _norm_init(cfg):
    return (L.init_rmsnorm if cfg.norm == "rms" else L.init_layernorm)


def _norm_apply(cfg):
    return (L.rms_norm if cfg.norm == "rms" else L.layer_norm)


# ---------------------------------------------------------------------------
# per-kind block init
# ---------------------------------------------------------------------------

def _init_attn_block(cfg: ArchConfig, key, cross: bool = False) -> Params:
    ks = jax.random.split(key, 4)
    p = {"norm1": _norm_init(cfg)(cfg.d_model),
         "norm2": _norm_init(cfg)(cfg.d_model)}
    if cfg.mla:
        p["attn"] = L.init_mla(ks[0], cfg.d_model, cfg.n_heads, cfg.kv_lora,
                               cfg.qk_nope, cfg.qk_rope, cfg.head_dim)
    else:
        p["attn"] = L.init_gqa(ks[0], cfg.d_model, cfg.n_heads,
                               cfg.n_kv_heads, cfg.head_dim, cfg.attn_bias)
    if cross:
        p["norm_x"] = _norm_init(cfg)(cfg.d_model)
        p["xattn"] = L.init_gqa(ks[2], cfg.d_model, cfg.n_heads,
                                cfg.n_kv_heads, cfg.head_dim, cfg.attn_bias)
    if cfg.d_ff:
        p["mlp"] = L.init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.gated_mlp)
    return p


def _init_moe_block(cfg: ArchConfig, key) -> Params:
    ks = jax.random.split(key, 2)
    p = {"norm1": _norm_init(cfg)(cfg.d_model),
         "norm2": _norm_init(cfg)(cfg.d_model)}
    if cfg.mla:
        p["attn"] = L.init_mla(ks[0], cfg.d_model, cfg.n_heads, cfg.kv_lora,
                               cfg.qk_nope, cfg.qk_rope, cfg.head_dim)
    else:
        p["attn"] = L.init_gqa(ks[0], cfg.d_model, cfg.n_heads,
                               cfg.n_kv_heads, cfg.head_dim, cfg.attn_bias)
    p["moe"] = M.init_moe(ks[1], cfg.d_model, cfg.d_ff_expert, cfg.n_routed,
                          cfg.n_shared, cfg.top_k)
    return p


def _init_mamba_block(cfg: ArchConfig, key) -> Params:
    return {"norm1": _norm_init(cfg)(cfg.d_model),
            "mamba": S.init_mamba2(key, cfg.d_model, cfg.ssm_state,
                                   cfg.mamba_expand, cfg.mamba_head_dim)}


# ---------------------------------------------------------------------------
# per-kind block apply (cache=None for train/prefill)
# ---------------------------------------------------------------------------

def _apply_attn_block(cfg, p, x, positions, cache=None, *, causal=True,
                      sliding_window=0, enc_kv=None, ring=False):
    na = _norm_apply(cfg)
    h = na(p["norm1"], x)
    if cfg.mla:
        y, new_attn = L.mla_attention(p["attn"], h, positions=positions,
                                      qk_nope=cfg.qk_nope,
                                      qk_rope=cfg.qk_rope,
                                      rope_theta=cfg.rope_theta,
                                      cache=None if cache is None
                                      else cache["attn"])
    else:
        y, new_attn = L.gqa_attention(
            p["attn"], h, positions=positions, causal=causal,
            rotary_frac=cfg.rotary_frac if cfg.use_rope else 0.0,
            rope_theta=cfg.rope_theta, sliding_window=sliding_window,
            cache=None if cache is None else cache["attn"], ring=ring)
    x = x + y
    new_cache = None if cache is None else {"attn": new_attn}
    if enc_kv is not None:
        h = na(p["norm_x"], x)
        # cross attention against precomputed encoder k/v
        q = jnp.einsum("bsd,dhk->bshk", L.cast_c(h),
                       L.cast_c(p["xattn"]["wq"]),
                       preferred_element_type=jnp.float32).astype(x.dtype)
        if "bq" in p["xattn"]:
            q = q + p["xattn"]["bq"].astype(q.dtype)
        y = L.sdpa(q, enc_kv["k"], enc_kv["v"], causal=False)
        y = jnp.einsum("bshk,hkd->bsd", L.cast_c(y),
                       L.cast_c(p["xattn"]["wo"]),
                       preferred_element_type=jnp.float32).astype(x.dtype)
        x = x + y
    if cfg.d_ff and "mlp" in p:
        x = x + L.mlp(p["mlp"], na(p["norm2"], x), act=_ACTS[cfg.act])
    return x, new_cache


def _apply_moe_block(cfg, p, x, positions, cache=None, dropless=False,
                     per_sequence=False, shard_axes=None):
    na = _norm_apply(cfg)
    h = na(p["norm1"], x)
    if cfg.mla:
        y, new_attn = L.mla_attention(p["attn"], h, positions=positions,
                                      qk_nope=cfg.qk_nope,
                                      qk_rope=cfg.qk_rope,
                                      rope_theta=cfg.rope_theta,
                                      cache=None if cache is None
                                      else cache["attn"])
    else:
        y, new_attn = L.gqa_attention(p["attn"], h, positions=positions,
                                      causal=True,
                                      rotary_frac=cfg.rotary_frac,
                                      rope_theta=cfg.rope_theta,
                                      cache=None if cache is None
                                      else cache["attn"])
    x = x + y
    # decode uses dropless capacity (cap >= T * top_k): per-step batches
    # are tiny and token drops would make decode diverge from prefill
    cf = float(cfg.n_routed) if (cache is not None or dropless) else 1.25
    y, aux = M.moe_block(p["moe"], na(p["norm2"], x), top_k=cfg.top_k,
                         capacity_factor=cf,
                         per_sequence=per_sequence or cache is not None,
                         shard_axes=shard_axes)
    x = x + y
    new_cache = None if cache is None else {"attn": new_attn}
    return x, aux, new_cache


# ---------------------------------------------------------------------------
# model init
# ---------------------------------------------------------------------------

def xlstm_kinds(cfg: ArchConfig):
    """Static block-kind pattern for the ssm family (not stored in params)."""
    return ["slstm" if cfg.slstm_every and
            (i % cfg.slstm_every == cfg.slstm_every - 1) else "mlstm"
            for i in range(cfg.n_layers)]


def init_model(cfg: ArchConfig, key) -> Params:
    keys = jax.random.split(key, 8)
    params: Params = {"embed": L.init_embed(keys[0], cfg.vocab, cfg.d_model),
                      "final_norm": _norm_init(cfg)(cfg.d_model)}

    def stack(init_fn, n, key):
        return jax.vmap(init_fn)(jax.random.split(key, n))

    if cfg.family in ("dense", "vlm"):
        params["blocks"] = stack(lambda k: _init_attn_block(cfg, k),
                                 cfg.n_layers, keys[1])
    elif cfg.family == "moe":
        params["dense_blocks"] = stack(lambda k: _init_attn_block(cfg, k),
                                       cfg.first_dense, keys[1])
        params["moe_blocks"] = stack(lambda k: _init_moe_block(cfg, k),
                                     cfg.n_layers - cfg.first_dense, keys[2])
    elif cfg.family == "hybrid":
        params["blocks"] = stack(lambda k: _init_mamba_block(cfg, k),
                                 cfg.n_layers, keys[1])
        params["shared_attn"] = _init_attn_block(cfg, keys[2])
    elif cfg.family == "ssm":
        blocks = []
        for i, kind in enumerate(xlstm_kinds(cfg)):
            kb = jax.random.fold_in(keys[1], i)
            if kind == "slstm":
                blocks.append({"norm1": _norm_init(cfg)(cfg.d_model),
                               "cell": S.init_slstm(kb, cfg.d_model,
                                                    cfg.n_heads)})
            else:
                blocks.append({"norm1": _norm_init(cfg)(cfg.d_model),
                               "cell": S.init_mlstm(kb, cfg.d_model,
                                                    cfg.n_heads,
                                                    cfg.head_dim)})
        params["blocks_list"] = blocks
    elif cfg.family == "audio":
        params["enc_blocks"] = stack(
            lambda k: _init_attn_block(cfg, k), cfg.enc_layers, keys[1])
        params["dec_blocks"] = stack(
            lambda k: _init_attn_block(cfg, k, cross=True),
            cfg.n_layers, keys[2])
        params["enc_norm"] = _norm_init(cfg)(cfg.d_model)
    else:
        raise ValueError(cfg.family)
    return params


# ---------------------------------------------------------------------------
# sinusoidal positions (whisper)
# ---------------------------------------------------------------------------

def _sinusoid(positions, d_model):
    half = d_model // 2
    freqs = jnp.exp(-jnp.arange(half, dtype=jnp.float32)
                    * (jnp.log(10000.0) / (half - 1)))
    ang = positions[:, None].astype(jnp.float32) * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# forward (train / prefill): batch -> logits, aux
# ---------------------------------------------------------------------------

def forward(cfg: ArchConfig, params: Params, batch: Dict[str, jax.Array],
            *, remat: bool = True, sliding_window: int = 0,
            act_sharding=None, dropless_moe: bool = False,
            remat_policy: str = "none", scan_unroll: int = 1):
    # scan_unroll: layer-scan unroll factor.  Functionally inert; the
    # dry-run compiles unroll=1 and unroll=2 to recover per-layer cost
    # (XLA cost_analysis counts while bodies ONCE -- EXPERIMENTS.md H10).
    """act_sharding: optional NamedSharding applied to the residual stream
    at every block boundary -- sequence parallelism (shards S over the
    model axis) that bounds the remat-scan carry memory (DESIGN.md S5)."""
    na = _norm_apply(cfg)
    if remat and remat_policy == "dots":
        # H4 (EXPERIMENTS.md S Perf): save matmul outputs across the remat
        # boundary -- trades activation memory for recompute FLOPs on
        # compute-bound cells (opt-in; default policy saves nothing)
        ck = functools.partial(
            jax.checkpoint,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    else:
        ck = jax.checkpoint if remat else (lambda f: f)

    def cons(h):
        if act_sharding is not None:
            return jax.lax.with_sharding_constraint(h, act_sharding)
        return h

    shard_axes = None
    if act_sharding is not None:
        names = tuple(act_sharding.mesh.axis_names)
        shard_axes = (names[:-1] if len(names[:-1]) > 1 else names[0],
                      names[-1])

    if cfg.family == "audio":
        return _forward_audio(cfg, params, batch, remat=remat,
                              act_sharding=act_sharding,
                              scan_unroll=scan_unroll)

    tokens = batch["tokens"]
    x = L.embed(params["embed"], tokens)
    if cfg.family == "vlm":
        x = jnp.concatenate([batch["patch_emb"].astype(x.dtype), x], axis=1)
    s = x.shape[1]
    positions = jnp.arange(s)
    aux = jnp.float32(0.0)
    x = cons(x)

    if cfg.family in ("dense", "vlm"):
        @ck
        def body(carry, p):
            y, _ = _apply_attn_block(cfg, p, carry, positions,
                                     sliding_window=sliding_window)
            return cons(y), None
        x, _ = jax.lax.scan(body, x, params["blocks"], unroll=scan_unroll)

    elif cfg.family == "moe":
        @ck
        def dense_body(carry, p):
            y, _ = _apply_attn_block(cfg, p, carry, positions)
            return cons(y), None
        x, _ = jax.lax.scan(dense_body, x, params["dense_blocks"])  # len 1

        @ck
        def moe_body(carry, p):
            h, a = carry
            # inference (remat=False) uses the batch-local dispatch
            # layout; training keeps the global buffer (H6)
            y, aux_l, _ = _apply_moe_block(cfg, p, h, positions,
                                           dropless=dropless_moe,
                                           per_sequence=not remat,
                                           shard_axes=shard_axes)
            return (cons(y), a + aux_l), None
        (x, aux), _ = jax.lax.scan(moe_body, (x, aux), params["moe_blocks"],
                                   unroll=scan_unroll)

    elif cfg.family == "hybrid":
        shared = params["shared_attn"]
        every = cfg.attn_every

        @ck
        def body(carry, xs):
            h = carry
            idx, p = xs
            h2, _ = S.mamba2_block(p["mamba"], na(p["norm1"], h),
                                   d_state=cfg.ssm_state,
                                   expand=cfg.mamba_expand,
                                   head_dim=cfg.mamba_head_dim)
            h = h + h2

            def with_attn(hh):
                y, _ = _apply_attn_block(cfg, shared, hh, positions,
                                         sliding_window=sliding_window)
                return y
            h = jax.lax.cond((idx % every) == every - 1, with_attn,
                             lambda hh: hh, h)
            return cons(h), None
        idxs = jnp.arange(cfg.n_layers)
        x, _ = jax.lax.scan(body, x, (idxs, params["blocks"]),
                            unroll=scan_unroll)

    elif cfg.family == "ssm":
        for p, kind in zip(params["blocks_list"], xlstm_kinds(cfg)):
            h = na(p["norm1"], x)
            if kind == "slstm":
                y, _ = S.slstm_block(p["cell"], h)
            else:
                y, _ = S.mlstm_block(p["cell"], h, n_heads=cfg.n_heads,
                                     head_dim=cfg.head_dim)
            x = x + y

    x = na(params["final_norm"], x)
    logits = L.unembed(params["embed"], x)
    return logits, aux


def encode_audio(cfg, params, frames):
    """Encoder-only forward (serving: run once, then cached decode)."""
    na = _norm_apply(cfg)
    enc = frames.astype(jnp.bfloat16)
    enc_pos = jnp.arange(enc.shape[1])
    enc = enc + _sinusoid(enc_pos, cfg.d_model).astype(enc.dtype)

    def enc_body(carry, p):
        y, _ = _apply_attn_block(cfg, p, carry, enc_pos, causal=False)
        return y, None
    enc, _ = jax.lax.scan(enc_body, enc, params["enc_blocks"])
    return na(params["enc_norm"], enc)


def _forward_audio(cfg, params, batch, *, remat=True, act_sharding=None,
                   scan_unroll=1):
    def cons(h):
        if act_sharding is not None:
            return jax.lax.with_sharding_constraint(h, act_sharding)
        return h

    """Whisper-style: frames (stub frontend output) -> encoder; tokens ->
    causal decoder with cross attention."""
    na = _norm_apply(cfg)
    ck = jax.checkpoint if remat else (lambda f: f)

    enc = batch["frames"].astype(jnp.bfloat16)
    enc_pos = jnp.arange(enc.shape[1])
    enc = enc + _sinusoid(enc_pos, cfg.d_model).astype(enc.dtype)

    @ck
    def enc_body(carry, p):
        y, _ = _apply_attn_block(cfg, p, carry, enc_pos, causal=False)
        return cons(y), None
    enc, _ = jax.lax.scan(enc_body, enc, params["enc_blocks"],
                          unroll=scan_unroll)
    enc = na(params["enc_norm"], enc)

    tokens = batch["tokens"]
    x = L.embed(params["embed"], tokens)
    s = x.shape[1]
    positions = jnp.arange(s)
    x = x + _sinusoid(positions, cfg.d_model).astype(x.dtype)

    @ck
    def dec_body(carry, p):
        # per-layer cross k/v from the shared encoder output
        k = jnp.einsum("bsd,dhk->bshk", L.cast_c(enc),
                       L.cast_c(p["xattn"]["wk"]),
                       preferred_element_type=jnp.float32).astype(x.dtype)
        v = jnp.einsum("bsd,dhk->bshk", L.cast_c(enc),
                       L.cast_c(p["xattn"]["wv"]),
                       preferred_element_type=jnp.float32).astype(x.dtype)
        if "bk" in p["xattn"]:
            k = k + p["xattn"]["bk"].astype(k.dtype)
            v = v + p["xattn"]["bv"].astype(v.dtype)
        y, _ = _apply_attn_block(cfg, p, carry, positions,
                                 enc_kv={"k": k, "v": v})
        return cons(y), None
    x, _ = jax.lax.scan(dec_body, x, params["dec_blocks"],
                        unroll=scan_unroll)

    x = na(params["final_norm"], x)
    return L.unembed(params["embed"], x), jnp.float32(0.0)
