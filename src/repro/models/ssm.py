"""State-space / recurrent blocks: Mamba2 (SSD) and xLSTM (mLSTM + sLSTM).

One chunked linear-attention core serves both Mamba2's SSD recurrence and
the mLSTM matrix memory:

    S_t = exp(log_a_t) * S_{t-1} + scale_t * (k_t outer v_t)
    y_t = q_t . S_t

computed chunk-parallel (intra-chunk einsums + a short scan over chunk
states), which is the TPU-friendly formulation: the intra-chunk terms are
MXU matmuls, the cross-chunk scan is O(S/chunk) long.  Decode is the O(1)
single-step recurrence on a cached state -- the reason the `long_500k`
shape runs for these families (DESIGN.md S4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import _dense_init, cast_c


# ---------------------------------------------------------------------------
# chunked linear attention core
# ---------------------------------------------------------------------------

def chunked_linear_attention(q, k, v, log_a, scale, state0=None,
                             chunk: int = 256):
    """q,k: (B,S,H,Dk); v: (B,S,H,Dv); log_a, scale: (B,S,H).

    Returns (y: (B,S,H,Dv), final_state: (B,H,Dk,Dv)).
    """
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk

    def r(x):
        return x.reshape(b, nc, chunk, *x.shape[2:])

    qc, kc, vc = r(q), r(k), r(v)
    la, sc = r(log_a), r(scale)

    cum = jnp.cumsum(la, axis=2)                    # (b,nc,L,h)
    total = cum[:, :, -1]                           # (b,nc,h)

    # intra-chunk: y[i] += sum_{j<=i} exp(cum_i - cum_j) * sc_j * (q_i.k_j) v_j
    decay_ij = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (b,nc,i,j,h)
    ii = jnp.arange(chunk)
    causal = (ii[:, None] >= ii[None, :])[None, None, :, :, None]
    w = jnp.where(causal, jnp.exp(decay_ij), 0.0)
    attn = jnp.einsum("bcihd,bcjhd->bcijh", cast_c(qc), cast_c(kc),
                      preferred_element_type=jnp.float32)
    wattn = attn * w * sc[:, :, None, :, :]
    y_intra = jnp.einsum("bcijh,bcjhv->bcihv", wattn.astype(jnp.bfloat16),
                         cast_c(vc), preferred_element_type=jnp.float32)

    # per-chunk state contribution: sum_j exp(total - cum_j) sc_j k_j (x) v_j
    wk = jnp.exp(total[:, :, None, :] - cum) * sc            # (b,nc,L,h)
    chunk_state = jnp.einsum("bcjh,bcjhd,bcjhv->bchdv",
                             wk.astype(jnp.bfloat16), cast_c(kc), cast_c(vc),
                             preferred_element_type=jnp.float32)

    # scan chunk states: s_c = exp(total_c) * s_{c-1} + chunk_state_c
    if state0 is None:
        state0 = jnp.zeros((b, h, dk, dv), jnp.float32)

    def step(carry, inp):
        tot_c, cs_c = inp
        new = jnp.exp(tot_c)[:, :, None, None] * carry + cs_c
        return new, carry  # emit the INCOMING state for each chunk

    total_t = jnp.moveaxis(total, 1, 0)              # (nc,b,h)
    cs_t = jnp.moveaxis(chunk_state, 1, 0)           # (nc,b,h,dk,dv)
    final, incoming = jax.lax.scan(step, state0.astype(jnp.float32),
                                   (total_t, cs_t))
    incoming = jnp.moveaxis(incoming, 0, 1)          # (b,nc,h,dk,dv)

    # inter-chunk: y[i] += exp(cum_i) * q_i . state_in
    y_inter = jnp.einsum("bcihd,bchdv->bcihv", cast_c(qc),
                         incoming.astype(jnp.bfloat16),
                         preferred_element_type=jnp.float32)
    y_inter = y_inter * jnp.exp(cum)[..., None]

    y = (y_intra + y_inter).reshape(b, s, h, dv)
    return y, final


def linear_attention_step(q, k, v, log_a, scale, state):
    """Single decode step. q,k: (B,1,H,Dk) etc.; state: (B,H,Dk,Dv)."""
    a = jnp.exp(log_a[:, 0])[:, :, None, None]               # (b,h,1,1)
    kv = jnp.einsum("bhd,bhv->bhdv", k[:, 0].astype(jnp.float32),
                    v[:, 0].astype(jnp.float32))
    new_state = a * state + scale[:, 0][:, :, None, None] * kv
    y = jnp.einsum("bhd,bhdv->bhv", q[:, 0].astype(jnp.float32), new_state)
    return y[:, None].astype(v.dtype), new_state


# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------

def init_mamba2(key, d_model, d_state=64, expand=2, head_dim=64,
                conv_width=4):
    d_inner = expand * d_model
    n_heads = d_inner // head_dim
    ks = jax.random.split(key, 5)
    return {
        # projections: z (gate), x, B, C, dt
        "in_proj": _dense_init(
            ks[0], (d_model, 2 * d_inner + 2 * d_state + n_heads), d_model),
        "conv_w": jax.random.normal(ks[1],
                                    (conv_width, d_inner + 2 * d_state),
                                    jnp.float32) * 0.1,
        "a_log": jnp.zeros((n_heads,), jnp.float32),
        "d_skip": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "out_proj": _dense_init(ks[2], (d_inner, d_model), d_inner),
        "norm_scale": jnp.ones((d_inner,), jnp.float32),
    }


def _causal_conv(x, w, tail=None):
    """Depthwise causal conv, width W: x (B,S,C), w (W,C).

    tail: (B, W-1, C) previous context for decode; returns (y, new_tail).
    """
    width = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    ext = jnp.concatenate([tail, x], axis=1)
    y = sum(ext[:, i:i + x.shape[1]] * w[i].astype(x.dtype)
            for i in range(width))
    new_tail = ext[:, -(width - 1):]
    return jax.nn.silu(y), new_tail


def mamba2_block(params, x, *, d_state=64, expand=2, head_dim=64,
                 chunk=256, cache=None):
    """x: (B,S,D). cache: None or {'state','conv_tail'}. -> (y, new_cache)."""
    d_model = x.shape[-1]
    d_inner = expand * d_model
    n_heads = d_inner // head_dim
    proj = jnp.einsum("bsd,de->bse", cast_c(x), cast_c(params["in_proj"]),
                      preferred_element_type=jnp.float32).astype(x.dtype)
    z, xc, bc, cc, dt = jnp.split(
        proj, [d_inner, 2 * d_inner, 2 * d_inner + d_state,
               2 * d_inner + 2 * d_state], axis=-1)

    conv_in = jnp.concatenate([xc, bc, cc], axis=-1)
    conv_tail = cache["conv_tail"] if cache is not None else None
    conv_out, new_tail = _causal_conv(conv_in, params["conv_w"], conv_tail)
    xc = conv_out[..., :d_inner]
    bc = conv_out[..., d_inner:d_inner + d_state]
    cc = conv_out[..., d_inner + d_state:]

    b, s, _ = x.shape
    xh = xc.reshape(b, s, n_heads, head_dim)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"])           # (b,s,h)
    a = -jnp.exp(params["a_log"])                        # (h,)
    log_decay = (a * dt)                                 # (b,s,h)
    kq = jnp.repeat(bc[:, :, None, :], n_heads, axis=2)  # B -> k
    qq = jnp.repeat(cc[:, :, None, :], n_heads, axis=2)  # C -> q

    if cache is None:
        y, final = chunked_linear_attention(qq, kq, xh, log_decay, dt,
                                            chunk=chunk)
        new_cache = None
    else:
        y, final = linear_attention_step(qq, kq, xh, log_decay, dt,
                                         cache["state"])
        new_cache = {"state": final, "conv_tail": new_tail}
    if cache is None:
        new_cache = None
    y = y + params["d_skip"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(b, s, d_inner)
    # gated RMS norm
    yf = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    yf = yf * jax.lax.rsqrt(var + 1e-5) * params["norm_scale"]
    out = jnp.einsum("bse,ed->bsd", cast_c(yf.astype(x.dtype)),
                     cast_c(params["out_proj"]),
                     preferred_element_type=jnp.float32).astype(x.dtype)
    if cache is not None:
        return out, new_cache
    return out, None


# ---------------------------------------------------------------------------
# xLSTM: mLSTM (matrix memory) and sLSTM (scalar memory) blocks
# ---------------------------------------------------------------------------

def init_mlstm(key, d_model, n_heads, head_dim):
    d_inner = n_heads * head_dim
    ks = jax.random.split(key, 6)
    return {
        "wqkv": _dense_init(ks[0], (d_model, 3, n_heads, head_dim), d_model),
        "wif": _dense_init(ks[1], (d_model, 2, n_heads), d_model),
        "wo": _dense_init(ks[2], (d_inner, d_model), d_inner),
        "ogate": _dense_init(ks[3], (d_model, d_inner), d_model),
    }


def mlstm_block(params, x, *, n_heads, head_dim, chunk=256, cache=None):
    b, s, d = x.shape
    qkv = jnp.einsum("bsd,dthk->btshk", cast_c(x), cast_c(params["wqkv"]),
                     preferred_element_type=jnp.float32).astype(x.dtype)
    q, k, v = qkv[:, 0], qkv[:, 1], qkv[:, 2]
    k = k / (head_dim ** 0.5)
    gates = jnp.einsum("bsd,dgh->bgsh", cast_c(x), cast_c(params["wif"]),
                       preferred_element_type=jnp.float32)
    i_gate = jnp.exp(-jax.nn.softplus(-gates[:, 0]))      # sigmoid, (b,s,h)
    log_f = -jax.nn.softplus(-gates[:, 1])                # log sigmoid

    if cache is None:
        y, final = chunked_linear_attention(q, k, v, log_f, i_gate,
                                            chunk=chunk)
        new_cache = None
    else:
        y, final = linear_attention_step(q, k, v, log_f, i_gate,
                                         cache["state"])
        new_cache = {"state": final}
    og = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", cast_c(x),
                                   cast_c(params["ogate"]),
                                   preferred_element_type=jnp.float32))
    y = y.reshape(b, s, n_heads * head_dim) * og
    out = jnp.einsum("bse,ed->bsd", cast_c(y.astype(x.dtype)),
                     cast_c(params["wo"]),
                     preferred_element_type=jnp.float32).astype(x.dtype)
    return out, new_cache


def init_slstm(key, d_model, n_heads):
    ks = jax.random.split(key, 2)
    return {
        # gates: i, f, z, o
        "wx": _dense_init(ks[0], (d_model, 4, d_model), d_model),
        "wh": _dense_init(ks[1], (d_model, 4, d_model), d_model) * 0.1,
    }


def slstm_block(params, x, *, cache=None):
    """Scalar-memory LSTM with exponential gating; lax.scan over time."""
    b, s, d = x.shape
    wx = jnp.einsum("bsd,dge->bsge", cast_c(x), cast_c(params["wx"]),
                    preferred_element_type=jnp.float32)

    if cache is None:
        h0 = jnp.zeros((b, d), jnp.float32)
        c0 = jnp.zeros((b, d), jnp.float32)
        n0 = jnp.ones((b, d), jnp.float32)
    else:
        h0, c0, n0 = cache["h"], cache["c"], cache["n"]

    wh = params["wh"].astype(jnp.float32)

    def step(carry, wx_t):
        h, c, n = carry
        g = wx_t + jnp.einsum("bd,dge->bge", h, wh)
        i = jnp.exp(jnp.clip(g[:, 0], -10.0, 10.0))
        f = jax.nn.sigmoid(g[:, 1])
        z = jnp.tanh(g[:, 2])
        o = jax.nn.sigmoid(g[:, 3])
        c = f * c + i * z
        n = f * n + i
        h = o * c / jnp.maximum(jnp.abs(n), 1.0)
        return (h, c, n), h

    (h, c, n), ys = jax.lax.scan(step, (h0, c0, n0),
                                 jnp.moveaxis(wx, 1, 0))
    y = jnp.moveaxis(ys, 0, 1).astype(x.dtype)
    new_cache = {"h": h, "c": c, "n": n} if cache is not None else None
    return y, new_cache
