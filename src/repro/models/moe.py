"""Fine-grained MoE with shared experts (DeepSeekMoE-style).

Capacity-based dispatch: top-k routing, per-expert capacity, scatter into
a capacity buffer, dense expert GEMMs, gather-combine weighted by router
gates; dropped tokens skip the routed path (shared experts always apply);
Switch-style auxiliary load-balance loss.

TWO dispatch layouts (H6, EXPERIMENTS.md S Perf):

* ``per_sequence=False`` (training default): one global (E, C, d) buffer.
  Best training-backward behaviour under GSPMD on both meshes.
* ``per_sequence=True`` (inference/prefill): every batch element owns a
  private (E, C_seq, d) buffer, positions from a per-sequence cumsum, all
  scatter indices batch-local.  On the multi-pod mesh this cut the
  forward-only deepseek prefill temps 53.7 -> 11.5 GB/device and the
  collective term 1.34 -> 0.36 s (the global cumsum serializes across DP
  shards).  Training with this layout regresses (GSPMD replicates the
  backward scatter), hence the split -- the same split production
  inference stacks make.

Expert weights carry E as the leading axis and are sharded over the model
axis (expert parallelism).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .layers import _dense_init, cast_c


def init_moe(key, d_model, d_ff_expert, n_routed, n_shared, top_k):
    ks = jax.random.split(key, 7)
    p = {
        "router": _dense_init(ks[0], (d_model, n_routed), d_model),
        "wi": _dense_init(ks[1], (n_routed, d_model, d_ff_expert), d_model),
        "wg": _dense_init(ks[2], (n_routed, d_model, d_ff_expert), d_model),
        "wo": _dense_init(ks[3], (n_routed, d_ff_expert, d_model),
                          d_ff_expert),
    }
    if n_shared:
        d_sh = d_ff_expert * n_shared
        p["shared_wi"] = _dense_init(ks[4], (d_model, d_sh), d_model)
        p["shared_wg"] = _dense_init(ks[5], (d_model, d_sh), d_model)
        p["shared_wo"] = _dense_init(ks[6], (d_sh, d_model), d_sh)
    return p


def _expert_ffn(params, buf3, out_dtype):
    """(E, C, d) capacity buffer -> expert SwiGLU -> (E, C, d)."""
    h = jnp.einsum("ecd,edf->ecf", cast_c(buf3), cast_c(params["wi"]),
                   preferred_element_type=jnp.float32)
    g = jnp.einsum("ecd,edf->ecf", cast_c(buf3), cast_c(params["wg"]),
                   preferred_element_type=jnp.float32)
    h = (jax.nn.silu(g) * h).astype(out_dtype)
    return jnp.einsum("ecf,efd->ecd", cast_c(h), cast_c(params["wo"]),
                      preferred_element_type=jnp.float32)


def _shared_path(params, xf, out_dtype):
    sh_h = jnp.einsum("td,df->tf", cast_c(xf), cast_c(params["shared_wi"]),
                      preferred_element_type=jnp.float32)
    sh_g = jnp.einsum("td,df->tf", cast_c(xf), cast_c(params["shared_wg"]),
                      preferred_element_type=jnp.float32)
    sh = (jax.nn.silu(sh_g) * sh_h).astype(out_dtype)
    return jnp.einsum("tf,fd->td", cast_c(sh), cast_c(params["shared_wo"]),
                      preferred_element_type=jnp.float32).astype(out_dtype)


def _aux_loss(experts, probs, e):
    density = jnp.mean(
        jax.nn.one_hot(experts[..., 0], e, dtype=jnp.float32),
        axis=tuple(range(experts.ndim - 1)))
    router_mean = jnp.mean(probs, axis=tuple(range(probs.ndim - 1)))
    return e * jnp.sum(density * router_mean)


def moe_block(params, x, *, top_k: int, capacity_factor: float = 1.25,
              per_sequence: bool = False, shard_axes=None):
    """x: (B, S, D). Returns (y, aux_loss).  shard_axes is accepted for
    API compatibility (constraints were tried and refuted -- H6)."""
    b, s, d = x.shape
    e = params["router"].shape[1]

    if per_sequence:
        return _moe_per_sequence(params, x, top_k=top_k,
                                 capacity_factor=capacity_factor)

    t = b * s
    xt = x.reshape(t, d)
    cap = int((top_k * t * capacity_factor) / e) + 1

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                        params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gates, experts = jax.lax.top_k(probs, top_k)           # (t, k)
    gates = gates / (gates.sum(-1, keepdims=True) + 1e-9)

    onehot = jax.nn.one_hot(experts, e, dtype=jnp.int32)   # (t, k, e)
    flat = onehot.reshape(t * top_k, e)
    pos = jnp.cumsum(flat, axis=0) * flat - 1              # (t*k, e)
    pos = pos.max(axis=-1).reshape(t, top_k)
    keep = pos < cap

    eidx = experts.reshape(-1)
    pidx = jnp.where(keep, pos, cap - 1).reshape(-1)
    wgt = jnp.where(keep, 1.0, 0.0).reshape(-1)

    buf = jnp.zeros((e, cap, d), xt.dtype)
    xk = jnp.repeat(xt[:, None, :], top_k, axis=1).reshape(-1, d)
    buf = buf.at[eidx, pidx].add(xk * wgt[:, None].astype(xt.dtype))
    # NOTE (H11, EXPERIMENTS.md S Perf): (E, C, d) has no batch dim, so
    # GSPMD replicates this GEMM across the DP domain in training --
    # forcing P(model, data, None) via with_sharding_constraint was tried
    # and refuted (the scatter then goes cross-device: collective term
    # exploded 6x).  The correct fix is an explicit all-to-all EP
    # dispatch (listed next lever); the replication cost is reported
    # honestly in the roofline table.

    out_buf = _expert_ffn(params, buf, x.dtype)
    gathered = out_buf[eidx, pidx]                         # (t*k, d)
    gathered = gathered * (gates.reshape(-1) * wgt)[:, None]
    y = gathered.reshape(t, top_k, d).sum(axis=1).astype(x.dtype)

    if "shared_wi" in params:
        y = y + _shared_path(params, xt, x.dtype)
    return y.reshape(b, s, d), _aux_loss(experts, probs, e)


def _moe_per_sequence(params, x, *, top_k: int, capacity_factor: float):
    """Inference dispatch: batch-local capacity buffers (see module doc)."""
    b, s, d = x.shape
    e = params["router"].shape[1]
    cap = int((top_k * s * capacity_factor) / e) + 1

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gates, experts = jax.lax.top_k(probs, top_k)           # (b, s, k)
    gates = gates / (gates.sum(-1, keepdims=True) + 1e-9)

    onehot = jax.nn.one_hot(experts, e, dtype=jnp.int32)
    flat = onehot.reshape(b, s * top_k, e)
    pos = jnp.cumsum(flat, axis=1) * flat - 1              # per sequence
    pos = pos.max(axis=-1).reshape(b, s, top_k)
    keep = pos < cap

    eidx = experts.reshape(b, -1)
    pidx = jnp.where(keep, pos, cap - 1).reshape(b, -1)
    wgt = jnp.where(keep, 1.0, 0.0).reshape(b, -1)
    bidx = jnp.broadcast_to(jnp.arange(b)[:, None], eidx.shape)

    xk = jnp.repeat(x[:, :, None, :], top_k, axis=2).reshape(b, -1, d)
    buf = jnp.zeros((b, e, cap, d), x.dtype)
    buf = buf.at[bidx, eidx, pidx].add(xk * wgt[..., None].astype(x.dtype))

    buf3 = buf.transpose(1, 0, 2, 3).reshape(e, b * cap, d)
    out3 = _expert_ffn(params, buf3, x.dtype)
    out_buf = out3.reshape(e, b, cap, d).transpose(1, 0, 2, 3)

    gathered = out_buf[bidx, eidx, pidx]
    gathered = gathered * (gates.reshape(b, -1) * wgt)[..., None]
    y = gathered.reshape(b, s, top_k, d).sum(axis=2).astype(x.dtype)

    if "shared_wi" in params:
        y = y.reshape(b * s, d) + _shared_path(params, x.reshape(b * s, d),
                                               x.dtype)
        y = y.reshape(b, s, d)
    return y, _aux_loss(experts, probs, e)
