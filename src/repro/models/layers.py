"""Core transformer layers: norms, RoPE, GQA / MLA attention, gated MLP.

Functional style: every module is an ``init_*`` returning a param pytree and
an ``apply`` taking (params, activations).  Weight layouts are chosen for
TP sharding (heads and ffn-hidden as leading shardable axes); see
train/sharding.py for the partitioning rules.

Compute dtype is bf16 with f32 accumulation (preferred_element_type); params
are stored f32 and cast on use.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def _dense_init(key, shape, in_axis_size):
    scale = 1.0 / jnp.sqrt(jnp.float32(in_axis_size))
    return (jax.random.normal(key, shape, jnp.float32) * scale)


def cast_c(x):
    """compute-dtype cast"""
    return x.astype(jnp.bfloat16)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rms_norm(params, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * params["scale"]
    return y.astype(x.dtype)


def init_layernorm(d):
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


def layer_norm(params, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps) * params["scale"] + params["bias"]
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE (half-split / NeoX convention; ``rotary_frac`` supports chatglm's
# 2d-RoPE = rotation of only the first half of head_dim)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim_rot: int, theta: float = 10000.0):
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim_rot, 2,
                                      dtype=jnp.float32) / head_dim_rot))
    return inv  # (head_dim_rot/2,)


def apply_rope(x, positions, rotary_frac: float = 1.0,
               theta: float = 10000.0):
    """x: (..., S, H, D). positions: broadcastable (..., S)."""
    d = x.shape[-1]
    d_rot = int(d * rotary_frac)
    d_rot -= d_rot % 2
    if d_rot == 0:
        return x
    inv = rope_freqs(d_rot, theta)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., S, d_rot/2)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    xr, xp = x[..., :d_rot], x[..., d_rot:]
    x1, x2 = xr[..., : d_rot // 2], xr[..., d_rot // 2:]
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    return jnp.concatenate([r1.astype(x.dtype), r2.astype(x.dtype), xp],
                           axis=-1)


# ---------------------------------------------------------------------------
# attention core
# ---------------------------------------------------------------------------

def sdpa(q, k, v, *, causal: bool, q_offset=0, sliding_window: int = 0,
         scale: Optional[float] = None, kpos=None):
    """q: (B, Sq, H, D); k/v: (B, Sk, G, D) with H % G == 0 (GQA).

    ``q_offset`` is the absolute position of q[0] (decode: cache length).
    ``kpos``: optional (Sk,) absolute positions of the keys -- used by the
    ring-buffer windowed cache (H3, EXPERIMENTS.md S Perf), where slot j
    holds a rotating absolute position; negative = empty slot.
    """
    b, sq, h, d = q.shape
    _, sk, g, _ = k.shape
    rep = h // g
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    qg = q.reshape(b, sq, g, rep, d)
    logits = jnp.einsum("bqgrd,bkgd->bgrqk", cast_c(qg), cast_c(k),
                        preferred_element_type=jnp.float32) * scale
    qpos = q_offset + jnp.arange(sq)[:, None]
    if kpos is None:
        kpos = jnp.arange(sk)
    kpos = kpos[None, :]
    mask = kpos >= 0
    if causal:
        mask = mask & (kpos <= qpos)
    if sliding_window:
        mask = mask & (kpos > qpos - sliding_window)
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bgrqk,bkgd->bqgrd", cast_c(probs), cast_c(v),
                     preferred_element_type=jnp.float32)
    return out.reshape(b, sq, h, d).astype(q.dtype)


def sdpa_chunked(q, k, v, *, causal: bool = True, q_offset=0,
                 sliding_window: int = 0, scale: Optional[float] = None,
                 q_chunk: int = 2048, kv_chunk: int = 2048):
    """Flash-style chunked attention: online softmax over KV blocks.

    Never materializes the (Sq, Sk) logits -- peak live memory is one
    (q_chunk, kv_chunk) tile per head group.  Used automatically by
    gqa_attention for long sequences (H5, EXPERIMENTS.md S Perf: the fix
    for prefill_32k cells whose full-softmax logits exceeded HBM).
    Numerically equivalent to sdpa (same f32 accumulation; online
    rescaling), validated in tests/test_models.py.
    """
    b, sq, h, d = q.shape
    _, sk, g, _ = k.shape
    dv = v.shape[-1]  # may differ from d (MLA: k_eff wider than v)
    rep = h // g
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, sk)
    assert sq % q_chunk == 0 and sk % kv_chunk == 0
    nq, nk = sq // q_chunk, sk // kv_chunk

    qg = q.reshape(b, nq, q_chunk, g, rep, d)
    kc = k.reshape(b, nk, kv_chunk, g, d)
    vc = v.reshape(b, nk, kv_chunk, g, dv)

    def q_block(qi, q_blk):
        q_pos = q_offset + qi * q_chunk + jnp.arange(q_chunk)[:, None]

        def kv_step(carry, inp):
            m_run, l_run, acc = carry
            ki, k_blk, v_blk = inp
            logits = jnp.einsum("bqgrd,bkgd->bgrqk", cast_c(q_blk),
                                cast_c(k_blk),
                                preferred_element_type=jnp.float32) * scale
            k_pos = ki * kv_chunk + jnp.arange(kv_chunk)[None, :]
            mask = jnp.ones((q_chunk, kv_chunk), bool)
            if causal:
                mask = mask & (k_pos <= q_pos)
            if sliding_window:
                mask = mask & (k_pos > q_pos - sliding_window)
            logits = jnp.where(mask, logits, -1e30)
            m_new = jnp.maximum(m_run, logits.max(axis=-1))
            alpha = jnp.exp(m_run - m_new)
            p = jnp.exp(logits - m_new[..., None])
            l_new = l_run * alpha + p.sum(axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bgrqk,bkgd->bgrqd", p.astype(jnp.bfloat16), cast_c(v_blk),
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc), None

        m0 = jnp.full((b, g, rep, q_chunk), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, g, rep, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, g, rep, q_chunk, dv), jnp.float32)
        (m_f, l_f, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.arange(nk), jnp.moveaxis(kc, 1, 0),
             jnp.moveaxis(vc, 1, 0)))
        out = acc / jnp.maximum(l_f, 1e-30)[..., None]
        return out  # (b, g, rep, q_chunk, d)

    outs = jax.lax.map(
        jax.checkpoint(lambda inp: q_block(inp[0], inp[1])),
        (jnp.arange(nq), jnp.moveaxis(qg, 1, 0)))
    # (nq, b, g, rep, q_chunk, d) -> (b, sq, h, d)
    out = jnp.moveaxis(outs, 0, 3).reshape(b, g, rep, sq, dv)
    out = jnp.moveaxis(out.reshape(b, h, sq, dv), 1, 2)
    return out.astype(q.dtype)


CHUNKED_ATTN_THRESHOLD = 8192  # use online-softmax attention at/above this
# (tried 4096 -- refuted: at 4k the chunking scan introduces all-to-alls
# and q-block saves that outweigh the S^2 saving; see EXPERIMENTS.md H7)


# ---------------------------------------------------------------------------
# GQA attention block
# ---------------------------------------------------------------------------

def init_gqa(key, d_model, n_heads, n_kv, head_dim, bias: bool = False):
    ks = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(ks[0], (d_model, n_heads, head_dim), d_model),
        "wk": _dense_init(ks[1], (d_model, n_kv, head_dim), d_model),
        "wv": _dense_init(ks[2], (d_model, n_kv, head_dim), d_model),
        "wo": _dense_init(ks[3], (n_heads, head_dim, d_model),
                          n_heads * head_dim),
    }
    if bias:
        p["bq"] = jnp.zeros((n_heads, head_dim), jnp.float32)
        p["bk"] = jnp.zeros((n_kv, head_dim), jnp.float32)
        p["bv"] = jnp.zeros((n_kv, head_dim), jnp.float32)
    return p


def gqa_attention(params, x, *, positions, causal=True, rotary_frac=1.0,
                  rope_theta=10000.0, sliding_window=0, cache=None,
                  ring=False):
    """cache: None (train/prefill) or dict(k, v, length) for decode.

    ``ring=True``: the cache seq dim is a ring buffer of size
    ``sliding_window`` -- slot = position % window; keys are roped at
    write time so slots carry absolute positions (H3, EXPERIMENTS.md).
    Returns (y, new_cache_or_None).
    """
    q = jnp.einsum("bsd,dhk->bshk", cast_c(x), cast_c(params["wq"]),
                   preferred_element_type=jnp.float32).astype(x.dtype)
    k = jnp.einsum("bsd,dhk->bshk", cast_c(x), cast_c(params["wk"]),
                   preferred_element_type=jnp.float32).astype(x.dtype)
    v = jnp.einsum("bsd,dhk->bshk", cast_c(x), cast_c(params["wv"]),
                   preferred_element_type=jnp.float32).astype(x.dtype)
    if "bq" in params:
        q = q + params["bq"].astype(q.dtype)
        k = k + params["bk"].astype(k.dtype)
        v = v + params["bv"].astype(v.dtype)
    q = apply_rope(q, positions, rotary_frac, rope_theta)
    k = apply_rope(k, positions, rotary_frac, rope_theta)

    new_cache = None
    q_offset = 0
    kpos = None
    if cache is not None:
        # decode: write this step's k/v at cache['length'] (or its ring slot)
        idx = cache["length"]
        w = cache["k"].shape[1]
        slot = idx % w if ring else idx
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"],
                                                 k.astype(cache["k"].dtype),
                                                 slot, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"],
                                                 v.astype(cache["v"].dtype),
                                                 slot, axis=1)
        k, v = ck, cv
        q_offset = idx
        if ring:
            # slot j holds absolute position idx - ((idx - j) mod w);
            # not-yet-written slots come out negative => masked
            j = jnp.arange(w)
            kpos = idx - ((idx - j) % w)
        new_cache = {"k": ck, "v": cv, "length": idx + q.shape[1]}
    if cache is None and q.shape[1] >= CHUNKED_ATTN_THRESHOLD:
        # long-sequence train/prefill: online-softmax chunked attention
        # (never materializes the S x S logits -- H5)
        y = sdpa_chunked(q, k, v, causal=causal,
                         sliding_window=sliding_window)
    else:
        y = sdpa(q, k, v, causal=causal, q_offset=q_offset,
                 sliding_window=sliding_window, kpos=kpos)
    out = jnp.einsum("bshk,hkd->bsd", cast_c(y), cast_c(params["wo"]),
                     preferred_element_type=jnp.float32).astype(x.dtype)
    return out, new_cache


# ---------------------------------------------------------------------------
# MLA attention (DeepSeek-V2): low-rank KV compression; the decode cache
# holds only (c_kv, k_rope) -- the technique's memory win.
# ---------------------------------------------------------------------------

def init_mla(key, d_model, n_heads, kv_lora, qk_nope=128, qk_rope=64,
             v_dim=128):
    ks = jax.random.split(key, 6)
    return {
        "wq": _dense_init(ks[0], (d_model, n_heads, qk_nope + qk_rope),
                          d_model),
        "wdkv": _dense_init(ks[1], (d_model, kv_lora), d_model),
        "wkr": _dense_init(ks[2], (d_model, qk_rope), d_model),
        "wuk": _dense_init(ks[3], (kv_lora, n_heads, qk_nope), kv_lora),
        "wuv": _dense_init(ks[4], (kv_lora, n_heads, v_dim), kv_lora),
        "wo": _dense_init(ks[5], (n_heads, v_dim, d_model), n_heads * v_dim),
    }


def mla_attention(params, x, *, positions, qk_nope=128, qk_rope=64,
                  rope_theta=10000.0, cache=None):
    q = jnp.einsum("bsd,dhk->bshk", cast_c(x), cast_c(params["wq"]),
                   preferred_element_type=jnp.float32).astype(x.dtype)
    qn, qr = q[..., :qk_nope], q[..., qk_nope:]
    qr = apply_rope(qr, positions, 1.0, rope_theta)

    ckv = jnp.einsum("bsd,dr->bsr", cast_c(x), cast_c(params["wdkv"]),
                     preferred_element_type=jnp.float32).astype(x.dtype)
    kr = jnp.einsum("bsd,dk->bsk", cast_c(x), cast_c(params["wkr"]),
                    preferred_element_type=jnp.float32).astype(x.dtype)
    kr = apply_rope(kr[:, :, None, :], positions, 1.0,
                    rope_theta)[:, :, 0, :]

    q_offset = 0
    new_cache = None
    if cache is not None:
        idx = cache["length"]
        ckv = jax.lax.dynamic_update_slice_in_dim(
            cache["ckv"], ckv.astype(cache["ckv"].dtype), idx, axis=1)
        kr = jax.lax.dynamic_update_slice_in_dim(
            cache["kr"], kr.astype(cache["kr"].dtype), idx, axis=1)
        q_offset = idx
        new_cache = {"ckv": ckv, "kr": kr, "length": idx + x.shape[1]}

    # expand compressed cache to per-head keys/values
    kn = jnp.einsum("bsr,rhk->bshk", cast_c(ckv), cast_c(params["wuk"]),
                    preferred_element_type=jnp.float32).astype(x.dtype)
    v = jnp.einsum("bsr,rhk->bshk", cast_c(ckv), cast_c(params["wuv"]),
                   preferred_element_type=jnp.float32).astype(x.dtype)

    b, sq, h, _ = q.shape
    sk = kn.shape[1]
    scale = 1.0 / ((qk_nope + qk_rope) ** 0.5)
    if cache is None and sq >= CHUNKED_ATTN_THRESHOLD:
        # H5 for MLA: the two-term logits (nope + rope) fold into ONE
        # effective dot -- q_eff = [qn, qr], k_eff = [kn, kr per head] --
        # so the flash-style chunked path applies unchanged.
        q_eff = jnp.concatenate([qn, qr], axis=-1)
        kr_h = jnp.broadcast_to(kr[:, :, None, :],
                                (b, sk, h, kr.shape[-1])).astype(kn.dtype)
        k_eff = jnp.concatenate([kn, kr_h], axis=-1)
        y = sdpa_chunked(q_eff, k_eff, v, causal=True,
                         scale=scale).astype(x.dtype)
    else:
        logits = (jnp.einsum("bqhn,bkhn->bhqk", cast_c(qn), cast_c(kn),
                             preferred_element_type=jnp.float32)
                  + jnp.einsum("bqhr,bkr->bhqk", cast_c(qr), cast_c(kr),
                               preferred_element_type=jnp.float32)) * scale
        qpos = q_offset + jnp.arange(sq)[:, None]
        kpos = jnp.arange(sk)[None, :]
        logits = jnp.where(kpos <= qpos, logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        y = jnp.einsum("bhqk,bkhd->bqhd", cast_c(probs), cast_c(v),
                       preferred_element_type=jnp.float32).astype(x.dtype)
    out = jnp.einsum("bshk,hkd->bsd", cast_c(y), cast_c(params["wo"]),
                     preferred_element_type=jnp.float32).astype(x.dtype)
    return out, new_cache


# ---------------------------------------------------------------------------
# gated MLP (SwiGLU) / plain MLP
# ---------------------------------------------------------------------------

def init_mlp(key, d_model, d_ff, gated: bool = True):
    ks = jax.random.split(key, 3)
    p = {"wi": _dense_init(ks[0], (d_model, d_ff), d_model),
         "wo": _dense_init(ks[1], (d_ff, d_model), d_ff)}
    if gated:
        p["wg"] = _dense_init(ks[2], (d_model, d_ff), d_model)
    return p


def mlp(params, x, act=jax.nn.silu):
    h = jnp.einsum("bsd,df->bsf", cast_c(x), cast_c(params["wi"]),
                   preferred_element_type=jnp.float32)
    if "wg" in params:
        g = jnp.einsum("bsd,df->bsf", cast_c(x), cast_c(params["wg"]),
                       preferred_element_type=jnp.float32)
        h = act(g) * h
    else:
        h = act(h)
    return jnp.einsum("bsf,fd->bsd", cast_c(h.astype(x.dtype)),
                      cast_c(params["wo"]),
                      preferred_element_type=jnp.float32).astype(x.dtype)


# ---------------------------------------------------------------------------
# embedding / unembedding
# ---------------------------------------------------------------------------

def init_embed(key, vocab, d_model):
    return {"table": jax.random.normal(key, (vocab, d_model),
                                       jnp.float32) * 0.02}


def embed(params, tokens):
    # cast BEFORE the gather: the table is vocab-sharded, so GSPMD
    # all-gathers it at the lookup -- in bf16 that transfer halves, and
    # the same bf16 copy is reused by unembed (H2.3, EXPERIMENTS.md S Perf)
    return jnp.take(cast_c(params["table"]), tokens, axis=0)


def unembed(params, x):
    return jnp.einsum("bsd,vd->bsv", cast_c(x), cast_c(params["table"]),
                      preferred_element_type=jnp.float32)
