"""``python -m repro run`` -- the canonical launcher (DESIGN.md S10).

One command drives every execution mode from a single serializable
``RunSpec``: pass a spec JSON file, or build one from flags.  The run's
record JSON and checkpoint both embed the canonical serialized spec, so
any result is replayable from one blob:

    # declaratively, from a spec document
    python -m repro run spec.json --record results/

    # or from flags (prints/records the equivalent spec)
    python -m repro run --n 64 --m 64 --engine multispin \\
        --temperature 2.27 --seed 7 --n-measure 100 --measure-every 2

    # validate + print the dispatch plan, no device work
    python -m repro run spec.json --dry-run

    # resume a checkpoint (single, ensemble, or sharded -- the spec
    # inside the file picks the runner)
    python -m repro run --restore ckpt.npz --sweeps 500
"""
from __future__ import annotations

import argparse
import json
import sys

import numpy as np


def _build_spec(args) -> "RunSpec":
    from repro.api import (BatchSpec, EngineSpec, LatticeSpec, MeshSpec,
                           RunSpec, SweepSpec)
    if args.spec:
        with open(args.spec) as f:
            spec = RunSpec.from_json(f.read())
        return spec
    params = {}
    if args.tc_block is not None:
        params["tc_block"] = args.tc_block
    if args.p_ferro is not None:
        params["p_ferro"] = args.p_ferro
    sweep = None
    if args.n_measure:
        sweep = SweepSpec(thermalize=args.thermalize,
                          measure_every=args.measure_every,
                          n_measure=args.n_measure,
                          fields=tuple(args.fields.split(",")))
    batch = None
    if args.temps:
        temps = tuple(float(t) for t in args.temps.split(","))
        seeds = tuple(int(s) for s in args.seeds.split(",")) \
            if args.seeds else None
        batch = BatchSpec(temperatures=temps, seeds=seeds,
                          grid=args.grid)
    mesh = None
    if args.mesh:
        shape = tuple(int(d) for d in args.mesh.split("x"))
        names = tuple(args.mesh_axes.split(",")) if args.mesh_axes \
            else tuple(f"ax{i}" for i in range(len(shape)))
        mesh = MeshSpec(shape=shape, axis_names=names)
    return RunSpec(
        lattice=LatticeSpec(n=args.n, m=args.m or args.n,
                            init_p_up=args.init_p_up),
        engine=EngineSpec(name=args.engine, params=params),
        temperature=args.temperature, seed=args.seed,
        sweep=sweep, batch=batch, mesh=mesh)


def _summarize(traj: dict) -> dict:
    """Scalar summary of a measured trajectory (per-field mean of the
    final half -- a cheap steady-state estimate for the run log)."""
    out = {}
    for k, v in traj.items():
        tail = np.asarray(v)[len(v) // 2:]
        out[f"{k}_mean"] = float(np.mean(tail))
        out[f"abs_{k}_mean"] = float(np.mean(np.abs(tail)))
    return out


def _finish_trace(args, spec) -> None:
    if not args.trace:
        return
    import repro.telemetry as tel
    path = tel.export(args.trace,
                      meta={"engine": spec.engine.name,
                            "mode": spec.mode,
                            "lattice": [spec.lattice.n, spec.lattice.m],
                            "spec_json": spec.to_json()})
    print(f"# wrote trace {path} "
          f"(inspect: python -m repro.telemetry summarize {path})",
          file=sys.stderr)


def _cmd_supervise(args, spec) -> int:
    """The ``--supervise DIR`` path: preemption-safe supervised run
    with periodic checkpoints and auto-resume (DESIGN.md S13).  Exit 0
    on completion, 3 when preempted (progress checkpointed -- rerun
    the same command to resume)."""
    from repro.resilience import Supervisor, faults
    faults.install_from_env()  # CI chaos: REPRO_FAULTS JSON plan
    if not args.sweeps:
        print("--supervise needs --sweeps N (the run target)",
              file=sys.stderr)
        return 2
    sup = Supervisor(spec, args.supervise,
                     every_sweeps=args.ckpt_every_sweeps,
                     every_seconds=args.ckpt_every_seconds,
                     chunk=args.chunk, keep=args.keep)
    if sup.resumed_from is not None:
        print(f"# resumed from step {sup.resumed_from} "
              f"in {args.supervise}")
    res = sup.run(args.sweeps)
    print(f"supervised run {res.status} at sweep {res.step_count}/"
          f"{args.sweeps}; checkpoints written: "
          f"{res.checkpoints_written}")
    print(f"final_state_digest={res.digest}")
    _finish_trace(args, spec)
    return 0 if res.completed else 3


def cmd_run(args) -> int:
    from repro.api import Session, describe

    if args.trace:
        import repro.telemetry as tel
        tel.enable()
    session = None
    if args.restore and not args.dry_run:
        session = Session.restore(args.restore)  # ONE checkpoint read
        spec = session.spec
    elif args.restore:
        from repro.api.session import load_spec
        spec = load_spec(args.restore)           # spec entry only
    else:
        spec = _build_spec(args)

    if args.out_spec:
        with open(args.out_spec, "w") as f:
            f.write(spec.to_json(indent=1) + "\n")
        print(f"# wrote spec {args.out_spec}")

    plan = describe(spec)
    if args.dry_run:
        print(json.dumps(plan, indent=1, sort_keys=True))
        print(f"# dry run OK: mode={plan['mode']} "
              f"engine={plan['engine']} "
              f"lattice={plan['lattice'][0]}x{plan['lattice'][1]} "
              f"batch={plan['batch_size']}", file=sys.stderr)
        _finish_trace(args, spec)
        return 0

    if args.supervise:
        return _cmd_supervise(args, spec)

    if session is None:
        session = Session.open(spec)
    rows = []
    if spec.sweep is not None:
        import time
        t0 = time.perf_counter()
        traj = session.measure()
        dt = time.perf_counter() - t0
        summary = _summarize(traj)
        rows.append(("measure", dt * 1e6, summary))
        print(f"measured {spec.sweep.n_measure} samples "
              f"({spec.sweep.total_sweeps} sweeps) in {dt:.2f}s: " +
              " ".join(f"{k}={v:.4f}" for k, v in summary.items()))
    if args.sweeps:
        import time
        t0 = time.perf_counter()
        session.run(args.sweeps)
        mag = session.magnetization()  # blocks: honest timing boundary
        dt = time.perf_counter() - t0
        rows.append(("run", dt * 1e6,
                     {"sweeps": args.sweeps,
                      "mean_abs_m": float(np.mean(np.abs(mag)))}))
        print(f"ran {args.sweeps} sweeps in {dt:.2f}s; |m| = "
              f"{np.mean(np.abs(mag)):.4f}")
    if not rows:
        print("nothing to do: spec has no sweep plan and --sweeps is 0 "
              "(use --dry-run to just validate)", file=sys.stderr)
        _finish_trace(args, spec)
        return 2

    if args.save:
        session.save(args.save)
        print(f"# wrote checkpoint {args.save} "
              f"(step {session.step_count})")
    if args.record is not None:
        import time

        import jax

        from repro.analysis.recorder import RunRecorder
        from repro.perf.schema import validate_record
        rec = RunRecorder(meta={"spec": spec.to_dict(),
                                "mode": session.mode,
                                "step_count": session.step_count,
                                "stamp": time.strftime("%Y%m%d_%H%M%S"),
                                "backend": jax.default_backend(),
                                "device_count": jax.device_count()})
        for name, us, derived in rows:
            rec.record(name, us, spec=spec.to_json(), **derived)
        # CLI records obey the same perf-record schema as the bench
        # harness, so they diff/gate/trend interchangeably
        validate_record({"meta": rec.meta, "rows": rec.rows})
        path = rec.write_json(args.record)
        print(f"# wrote record {path}")
    _finish_trace(args, spec)
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro",
        description="Unified RunSpec launcher for the Ising study")
    sub = ap.add_subparsers(dest="cmd", required=True)
    run = sub.add_parser(
        "run", help="execute (or --dry-run validate) a RunSpec",
        formatter_class=argparse.ArgumentDefaultsHelpFormatter)
    run.add_argument("spec", nargs="?", default="",
                     help="RunSpec JSON file (flags below are ignored "
                          "for spec construction when given)")
    run.add_argument("--dry-run", action="store_true",
                     help="parse + validate + print the dispatch plan; "
                          "no device work")
    # lattice / engine construction flags
    run.add_argument("--n", type=int, default=64)
    run.add_argument("--m", type=int, default=0,
                     help="lattice cols (default: --n)")
    run.add_argument("--init-p-up", type=float, default=0.5)
    run.add_argument("--engine", default="multispin")
    run.add_argument("--temperature", type=float, default=2.0)
    run.add_argument("--seed", type=int, default=1234)
    run.add_argument("--tc-block", type=int, default=None)
    run.add_argument("--p-ferro", type=float, default=None)
    # measurement schedule
    run.add_argument("--thermalize", type=int, default=0)
    run.add_argument("--measure-every", type=int, default=1)
    run.add_argument("--n-measure", type=int, default=0,
                     help="samples to record (0: plain --sweeps run)")
    run.add_argument("--fields", default="m,e")
    # ensemble batch
    run.add_argument("--temps", default="",
                     help="comma list -> BatchSpec (ensemble mode)")
    run.add_argument("--seeds", default="",
                     help="comma list of member seeds")
    run.add_argument("--grid", action="store_true",
                     help="temps x seeds cross product")
    # device mesh
    run.add_argument("--mesh", default="",
                     help="device mesh shape, e.g. 2x4 (sharded mode)")
    run.add_argument("--mesh-axes", default="",
                     help="comma list of mesh axis names")
    # execution / outputs
    run.add_argument("--sweeps", type=int, default=0,
                     help="plain sweeps to run (besides any sweep plan)")
    run.add_argument("--save", default="", help="checkpoint path to write")
    run.add_argument("--restore", default="",
                     help="checkpoint to resume (overrides spec/flags)")
    # supervised (fault-tolerant) execution
    run.add_argument("--supervise", default="", metavar="DIR",
                     help="run under the resilience supervisor: "
                          "periodic verified checkpoints into DIR, "
                          "SIGTERM/SIGINT-safe, auto-resume from the "
                          "newest valid step (exit 3 = preempted, "
                          "rerun to resume)")
    run.add_argument("--ckpt-every-sweeps", type=int, default=0,
                     help="supervisor checkpoint cadence in sweeps "
                          "(0: off)")
    run.add_argument("--ckpt-every-seconds", type=float, default=0.0,
                     help="supervisor checkpoint cadence in wall-clock "
                          "seconds (0: off)")
    run.add_argument("--chunk", type=int, default=64,
                     help="supervisor sweep-chunk between control "
                          "points (fixed grid: part of the bit-exact-"
                          "resume contract for key-based engines)")
    run.add_argument("--keep", type=int, default=3,
                     help="checkpoint steps the supervisor retains")
    run.add_argument("--out-spec", default="",
                     help="write the canonical spec JSON here")
    run.add_argument("--record", nargs="?", const=".", default=None,
                     metavar="DIR_OR_PATH",
                     help="write a RunRecorder JSON embedding the spec")
    run.add_argument("--trace", default="", metavar="PATH",
                     help="enable span tracing; write the Chrome trace "
                          "(.json, Perfetto-loadable) or .jsonl stream "
                          "+ metrics snapshot here")
    run.set_defaults(fn=cmd_run)

    from repro.serve.__main__ import add_serve_args, run_server
    srv = sub.add_parser(
        "serve", help="run the fault-tolerant sweep-farm server "
                      "(exit 0 done / 3 drained-preempted)",
        formatter_class=argparse.ArgumentDefaultsHelpFormatter)
    add_serve_args(srv)
    srv.set_defaults(fn=run_server)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    raise SystemExit(main())
