"""int8 error-feedback gradient compression for DP all-reduce.

Each DP step quantizes (grad + error_carry) to int8 with a per-tensor
scale, all-reduces the int8 payload (8x less ICI traffic than f32 -- the
collective-roofline lever), dequantizes, and carries the quantization
residual to the next step (error feedback keeps SGD/Adam convergence; see
tests/test_compress.py for the convergence check).

``make_compressed_sync(mesh)`` returns a shard_map'd gradient synchronizer
usable as ``grad_sync`` in make_train_step when the train step itself is
shard_map'd over DP; in the default pjit path XLA owns the all-reduce, so
this module is exercised through its own shard_map path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def quantize(x):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-30) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def compress_leaf(g, err):
    """One leaf: returns (int8 payload, scale, new_error)."""
    x = g.astype(jnp.float32) + err
    q, scale = quantize(x)
    new_err = x - dequantize(q, scale)
    return q, scale, new_err


def make_compressed_psum(axis_names):
    """Inside shard_map: all-reduce a grad pytree in int8 with error
    feedback.  Returns fn((grads, err_state)) -> (synced, new_err)."""
    n = None  # resolved at trace time via psum of 1

    def sync(grads, err_state):
        def leaf(g, e):
            q, scale, new_e = compress_leaf(g, e)
            # int8 payload summed in int32 (no overflow below 2^23 ranks),
            # scales averaged -- each rank contributes q_i * s_i ~= g_i
            tot = jax.lax.psum(q.astype(jnp.int32) * 1, axis_names)
            s = jax.lax.psum(scale, axis_names)
            count = jax.lax.psum(1, axis_names)
            return (tot.astype(jnp.float32) * (s / count) / count,
                    new_e)
        synced = jax.tree.map(lambda g, e: leaf(g, e)[0], grads, err_state)
        new_err = jax.tree.map(lambda g, e: leaf(g, e)[1], grads, err_state)
        return synced, new_err

    return sync


def init_error_state(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def make_dp_compressed_sync(mesh, dp_axes):
    """shard_map'd standalone synchronizer for testing / DP-only loops:
    (per-device grads pytree, err) -> (mean grads, new err)."""
    spec = P()  # grads replicated within a shard for the test path

    def body(grads, err):
        sync = make_compressed_psum(dp_axes)
        return sync(grads, err)

    return body
