from .optim import OptConfig, init as opt_init, update as opt_update  # noqa: F401
from .step import (cross_entropy, make_loss_fn, make_prefill_step,  # noqa: F401
                   make_serve_step, make_train_step)
