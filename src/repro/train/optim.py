"""AdamW with global-norm clipping and a warmup-cosine schedule.

Hand-rolled (no optax in this environment): state is {mu, nu, count};
master weights and moments are f32; updates are pure tree_maps so the
optimizer state inherits the param shardings (FSDP shards moments too).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def schedule(cfg: OptConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup)
                    / jnp.maximum(cfg.total_steps - cfg.warmup, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def init(params) -> dict:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    return {"mu": zeros,
            "nu": jax.tree.map(jnp.zeros_like, zeros),
            "count": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2)
                        for l in leaves))


def update(cfg: OptConfig, grads, params, state):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    count = state["count"] + 1
    lr = schedule(cfg, count)
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    mu = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g,
                      state["mu"], grads)
    nu = jax.tree.map(lambda v, g: cfg.b2 * v + (1 - cfg.b2) * g * g,
                      state["nu"], grads)

    def step_fn(p, m, v):
        mhat = m / b1c
        vhat = v / b2c
        upd = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * upd).astype(p.dtype)

    new_params = jax.tree.map(step_fn, params, mu, nu)
    return new_params, {"mu": mu, "nu": nu, "count": count}, \
        {"grad_norm": gnorm, "lr": lr}
