"""train_step / serve_step builders with full sharding annotations.

``make_train_step`` returns a jit-able ``(params, opt_state, batch) ->
(params, opt_state, metrics)`` with in/out shardings derived from
train/sharding.py; ``make_serve_step`` returns the KV-cached greedy decode
step.  Both are what launch/dryrun.py lowers for every (arch x shape x
mesh) cell and what launch/train.py executes.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import decode_step, forward
from . import optim
from .sharding import activation_spec


def cross_entropy(logits, labels):
    """Stable CE in f32; logits (B,S,V), labels (B,S) int32.

    The gold logit is extracted with an iota-mask reduction instead of
    take_along_axis: a gather along a vocab-sharded axis makes GSPMD
    all-gather the full logits (~1.5 GB/step for 92k vocab), while the
    masked reduce partitions cleanly and only psums a (B,S) scalar field
    (H2.2, EXPERIMENTS.md S Perf)."""
    logits = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(logits.max(axis=-1, keepdims=True))
    shifted = logits - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1))
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                          logits.ndim - 1)
    gold_mask = vocab_iota == labels[..., None]
    gold = jnp.sum(jnp.where(gold_mask, shifted, 0.0), axis=-1)
    return (lse - gold).mean()


def make_loss_fn(cfg: ArchConfig, *, remat: bool = True,
                 sliding_window: int = 0, aux_weight: float = 0.01,
                 mesh=None, sp: bool = False):
    def loss_fn(params, batch):
        logits, aux = forward(cfg, params, batch, remat=remat,
                              sliding_window=sliding_window)
        if mesh is not None:
            logits = jax.lax.with_sharding_constraint(
                logits, jax.sharding.NamedSharding(
                    mesh, activation_spec(mesh, sp=False)))
        loss = cross_entropy(logits, batch["labels"])
        return loss + aux_weight * aux, (loss, aux)
    return loss_fn


def make_train_step(cfg: ArchConfig, opt_cfg: optim.OptConfig, *,
                    remat: bool = True, sliding_window: int = 0,
                    mesh=None, sp: bool = False, grad_sync=None,
                    microbatches: int = 1, loss_fn=None):
    """grad_sync: optional fn(grads) for custom (e.g. compressed) DP sync;
    default None lets pjit/XLA insert the gradient all-reduce.

    microbatches > 1: gradient accumulation -- the batch is split along
    its leading axis and scanned, so live activation memory scales with
    the microbatch (H9, EXPERIMENTS.md S Perf: the HBM-fit lever for the
    large train_4k cells).  Equal-sized microbatches of a mean loss make
    the accumulated gradient bit-comparable to the full-batch one
    (tested in tests/test_train.py).
    """
    if loss_fn is None:
        loss_fn = make_loss_fn(cfg, remat=remat,
                               sliding_window=sliding_window,
                               mesh=mesh, sp=sp)
    gfn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, batch):
        if microbatches > 1:
            mb_batch = jax.tree.map(
                lambda a: a.reshape(microbatches,
                                    a.shape[0] // microbatches,
                                    *a.shape[1:]), batch)

            def micro(carry, mb):
                g_acc, tot_a, loss_a, aux_a = carry
                (tot, (loss, aux)), g = gfn(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, tot_a + tot, loss_a + loss,
                        aux_a + aux), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            (grads, tot, loss, aux), _ = jax.lax.scan(
                micro, (g0, 0.0, 0.0, 0.0), mb_batch)
            inv = 1.0 / microbatches
            grads = jax.tree.map(lambda g: g * inv, grads)
            tot, loss, aux = tot * inv, loss * inv, aux * inv
        else:
            (tot, (loss, aux)), grads = gfn(params, batch)
        if grad_sync is not None:
            grads = grad_sync(grads)
        new_params, new_opt, om = optim.update(opt_cfg, grads, params,
                                               opt_state)
        metrics = {"loss": loss, "aux": aux, "total": tot, **om}
        return new_params, new_opt, metrics

    return train_step


def make_prefill_step(cfg: ArchConfig, *, sliding_window: int = 0):
    """Forward-only prefill (the prefill_32k shape): batch -> logits."""
    def prefill(params, batch):
        logits, _ = forward(cfg, params, batch, remat=False,
                            sliding_window=sliding_window)
        return logits
    return prefill


def make_serve_step(cfg: ArchConfig, *, sliding_window: int = 0,
                    temperature: float = 0.0):
    """One decode iteration: (params, cache, tokens (B,1)) ->
    (next_tokens (B,1), new_cache)."""
    def serve_step(params, cache, tokens):
        logits, new_cache = decode_step(cfg, params, cache, tokens,
                                        sliding_window=sliding_window)
        if temperature > 0.0:
            # deterministic skip-ahead sampling keyed on cache length
            key = jax.random.fold_in(jax.random.PRNGKey(0),
                                     cache["length"])
            nxt = jax.random.categorical(
                key, logits[:, -1] / temperature)[:, None]
        else:
            nxt = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        return nxt.astype(tokens.dtype), new_cache
    return serve_step
