"""Sharding rules: param/batch/cache pytrees -> PartitionSpecs.

Parallelism (DESIGN.md S5):
* data parallel over ``(pod, data)`` (all mesh axes but the last),
* tensor parallel over ``model`` (heads / ffn-hidden / vocab / experts),
* expert parallel: MoE expert axis on ``model``,
* sequence parallel: activation constraints between blocks (train step),
* optional FSDP: weight d_model axes additionally sharded over the DP axes.

Rules are name-based with a divisibility guard: an axis is only sharded
when its size divides the mesh axis product (e.g. whisper's 20 heads and
51866 vocab fall back to replicated on a 16-wide model axis).
"""
from __future__ import annotations

from typing import Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig


def mesh_axes(mesh) -> Tuple[Tuple[str, ...], Tuple[str, ...]]:
    """(dp_axes, tp_axes): all-but-last vs last mesh axis."""
    names = tuple(mesh.axis_names)
    return names[:-1], names[-1:]


def _size(mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
    return "/".join(parts)


# (substring, spec template) -- axis entries: "tp" / "dp" / None; the
# template is positional over the trailing dims of the (possibly stacked)
# weight; a leading layer-stack dim is always None.
_RULES = [
    ("embed/table", ("tp", "dp_fsdp")),
    # heads on tp; if the head count doesn't divide the model axis
    # (phi4: 24, whisper: 20), fall back to sharding head_dim (H7)
    ("attn/wq", ("dp_fsdp", "tp|alt", "alt")),
    ("attn/wk", ("dp_fsdp", "tp|alt", "alt")),
    ("attn/wv", ("dp_fsdp", "tp|alt", "alt")),
    ("attn/wo", ("tp|alt", "alt", "dp_fsdp")),
    ("attn/wdkv", ("dp_fsdp", None)),
    ("attn/wkr", ("dp_fsdp", None)),
    ("attn/wuk", (None, "tp", None)),
    ("attn/wuv", (None, "tp", None)),
    ("xattn/wq", ("dp_fsdp", "tp|alt", "alt")),
    ("xattn/wk", ("dp_fsdp", "tp|alt", "alt")),
    ("xattn/wv", ("dp_fsdp", "tp|alt", "alt")),
    ("xattn/wo", ("tp|alt", "alt", "dp_fsdp")),
    ("mlp/wi", ("dp_fsdp", "tp")),
    ("mlp/wg", ("dp_fsdp", "tp")),
    ("mlp/wo", ("tp", "dp_fsdp")),
    ("moe/router", (None, None)),
    ("moe/wi", ("tp", "dp_fsdp", None)),     # expert parallel
    ("moe/wg", ("tp", "dp_fsdp", None)),
    ("moe/wo", ("tp", "dp_fsdp", None)),
    ("moe/shared_wi", ("dp_fsdp", "tp")),
    ("moe/shared_wg", ("dp_fsdp", "tp")),
    ("moe/shared_wo", ("tp", "dp_fsdp")),
    ("mamba/in_proj", ("dp_fsdp", "tp")),
    ("mamba/out_proj", ("tp", "dp_fsdp")),
    ("cell/wqkv", ("dp_fsdp", None, None, "tp")),
    ("cell/ogate", ("dp_fsdp", "tp")),
    ("cell/wo", ("tp", "dp_fsdp")),
    ("cell/wx", ("dp_fsdp", None, "tp")),
    ("cell/wh", ("dp_fsdp", None, "tp")),
]


def param_spec(path_str: str, shape, mesh, *, fsdp: bool) -> P:
    dp_axes, tp_axes = mesh_axes(mesh)
    tp = _size(mesh, tp_axes)
    dp = _size(mesh, dp_axes)
    for pat, template in _RULES:
        if pat in path_str:
            nt = len(template)
            lead = len(shape) - nt
            if lead < 0:
                return P()
            entries = [None] * lead
            dims = shape[lead:]
            tp_entry = tp_axes if len(tp_axes) > 1 else tp_axes[0]
            # 'tp|alt' shards on tp when divisible; otherwise the 'alt'
            # position (head_dim) takes the model axis instead
            primary_ok = any(isinstance(r, str) and r.startswith("tp")
                             and d % tp == 0
                             for d, r in zip(dims, template))
            for dim, role in zip(dims, template):
                role = role or ""
                if role.startswith("tp") and dim % tp == 0:
                    entries.append(tp_entry)
                elif role == "alt" and not primary_ok and dim % tp == 0:
                    entries.append(tp_entry)
                elif role == "dp_fsdp" and fsdp and dim % dp == 0:
                    entries.append(dp_axes if len(dp_axes) > 1
                                   else dp_axes[0])
                else:
                    entries.append(None)
            return P(*entries)
    return P()  # norms, scalars, biases: replicated


def param_shardings(cfg: ArchConfig, params, mesh, *, fsdp: bool = False):
    """Pytree of NamedShardings matching ``params``."""
    def f(path, leaf):
        spec = param_spec(_path_str(path), leaf.shape, mesh, fsdp=fsdp)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(f, params)


def batch_specs(cfg: ArchConfig, mesh, *, global_batch: int):
    """PartitionSpecs for a training batch dict."""
    dp_axes, _ = mesh_axes(mesh)
    dp = _size(mesh, dp_axes)
    bspec = dp_axes if global_batch % dp == 0 else None
    b = bspec if bspec is None else (dp_axes if len(dp_axes) > 1
                                     else dp_axes[0])
    specs = {"tokens": P(b, None), "labels": P(b, None)}
    if cfg.family == "vlm":
        specs["patch_emb"] = P(b, None, None)
    if cfg.family == "audio":
        specs["frames"] = P(b, None, None)
    return specs


def cache_specs(cfg: ArchConfig, cache, mesh, *, batch: int):
    """PartitionSpecs for a decode cache pytree: batch on DP axes when it
    divides, heads/state channels on the model axis when they divide."""
    dp_axes, tp_axes = mesh_axes(mesh)
    dp = _size(mesh, dp_axes)
    tp = _size(mesh, tp_axes)
    bax = (dp_axes if len(dp_axes) > 1 else dp_axes[0]) \
        if batch % dp == 0 else None
    tax = tp_axes if len(tp_axes) > 1 else tp_axes[0]

    def f(leaf):
        shape = leaf.shape
        if leaf.ndim == 0:
            return P()
        entries = [None] * leaf.ndim
        # find the batch dim: first dim equal to batch (after optional
        # layer-stack leading dim)
        for i, d in enumerate(shape[:2]):
            if d == batch:
                entries[i] = bax
                bidx = i
                break
        else:
            bidx = -1
        # shard the first post-batch dim divisible by tp (heads/channels),
        # skipping sequence-length dims (they must stay whole for decode
        # writes) -- heuristically: dims >= 4096 are sequence dims.
        for i in range(bidx + 1, leaf.ndim):
            d = shape[i]
            if d >= 4096:
                continue
            if d % tp == 0 and d > 1 and entries[i] is None:
                entries[i] = tax
                break
        return P(*entries)

    return jax.tree_util.tree_map(f, cache)


def activation_spec(mesh, *, sp: bool = False) -> P:
    """(B, S, D) activation constraint between blocks (SP shards S)."""
    dp_axes, tp_axes = mesh_axes(mesh)
    b = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    s = (tp_axes if len(tp_axes) > 1 else tp_axes[0]) if sp else None
    return P(b, s, None)
