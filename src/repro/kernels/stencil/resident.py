"""Pallas TPU kernel: VMEM-resident k-full-sweep stencil update (S9).

The per-half-sweep kernel (``stencil.py``) round-trips both compact
color planes through HBM twice per sweep.  This kernel stages BOTH
planes into VMEM once (no grid: one program owns the whole lattice --
the planner in ``kernels/resident.py`` guarantees the working set
fits), runs ``n_sweeps`` full sweeps -- black then white half-sweeps --
in an in-kernel ``lax.fori_loop``, and writes both planes back once.
Philox offsets advance in-kernel per (sweep, color) via
``core.rng.half_sweep_offset``, the same counter layout every host-side
sweep loop uses, so the output is bit-for-bit ``n_sweeps`` applications
of the per-half-sweep oracle (``basic_philox`` -- tested in
tests/test_resident.py) and checkpoints/restarts keep their stream.

Neighbor shifts are slice-concat (pad+slice form, H1.4) and the
neighbor sums stay int8 (|sum| <= 4, H1.5), matching
``core.metropolis.neighbor_sums``.  Plane inputs are aliased to the
outputs (``input_output_aliases``), so together with the donated jit
wrappers (H1.8) the planes never hold two HBM copies.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import rng as crng


def _half_sweep(target, op, inv_temp, is_black: bool, k0, k1, offset,
                gidx=None):
    """One color half-sweep on whole VMEM-resident planes.

    Identical math (and float op order) to ``stencil.py``'s blocked
    kernel / ``core.metropolis.update_color_philox``: int8 neighbor
    sums, global (row, col) Philox keying, ``exp(-2 beta nn s)`` accept.

    ``gidx`` overrides the Philox site keying with a precomputed uint32
    global-index plane -- the sharded resident tier (``repro.dist``)
    passes the TRUE global positions of its halo-extended shard, so the
    draws match this kernel's own iota keying on the full lattice.
    """
    up = jnp.concatenate([op[-1:, :], op[:-1, :]], axis=0)
    down = jnp.concatenate([op[1:, :], op[:1, :]], axis=0)
    plus = jnp.concatenate([op[:, 1:], op[:, :1]], axis=1)
    minus = jnp.concatenate([op[:, -1:], op[:, :-1]], axis=1)
    parity = jax.lax.broadcasted_iota(jnp.int32, op.shape, 0) % 2
    if is_black:
        side = jnp.where(parity == 1, plus, minus)
    else:
        side = jnp.where(parity == 1, minus, plus)
    nn = up + down + op + side  # int8 stays int8 (H1.5)

    if gidx is None:
        h = op.shape[1]
        rows = jax.lax.broadcasted_iota(jnp.int32, op.shape, 0)
        cols = jax.lax.broadcasted_iota(jnp.int32, op.shape, 1)
        gidx = (rows * h + cols).astype(jnp.uint32)
    zero = jnp.zeros_like(gidx)
    bits = crng.philox4x32(offset, zero, gidx, zero, k0, k1)[0]
    u = crng.u32_to_uniform(bits)
    acc = jnp.exp(-2.0 * inv_temp * nn.astype(jnp.float32)
                  * target.astype(jnp.float32))
    return jnp.where(u < acc, -target, target).astype(target.dtype)


def _kernel(beta_ref, seeds_ref, black_ref, white_ref, black_out,
            white_out, *, n_sweeps: int):
    inv_temp = beta_ref[0]
    k0 = seeds_ref[0]
    k1 = seeds_ref[1]
    start = seeds_ref[2]

    def body(i, carry):
        b, w = carry
        b = _half_sweep(b, w, inv_temp, True, k0, k1,
                        crng.half_sweep_offset(start, i, 0))
        w = _half_sweep(w, b, inv_temp, False, k0, k1,
                        crng.half_sweep_offset(start, i, 1))
        return (b, w)

    b, w = jax.lax.fori_loop(0, n_sweeps, body,
                             (black_ref[...], white_ref[...]))
    black_out[...] = b
    white_out[...] = w


def stencil_sweeps_resident(black, white, inv_temp, *, n_sweeps: int,
                            seed=0, start_offset=0,
                            interpret: bool = False):
    """``n_sweeps`` full sweeps in ONE dispatch, planes VMEM-resident.

    Bit-exact vs ``n_sweeps`` iterations of the per-half-sweep oracle
    (``core.metropolis.run_sweeps_philox``) at the same
    ``start_offset``; ``seed`` may be a python int (full 64-bit key) or
    a traced uint32 (ensemble vmap), exactly like the blocked kernel.
    """
    assert n_sweeps >= 1, n_sweeps
    beta = jnp.array([inv_temp], jnp.float32)
    k0, k1 = crng.seed_keys(seed)
    seeds = jnp.stack([k0, k1, jnp.asarray(start_offset, jnp.uint32)])

    plane = pl.BlockSpec(memory_space=pltpu.VMEM)
    return pl.pallas_call(
        functools.partial(_kernel, n_sweeps=n_sweeps),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),   # beta
            pl.BlockSpec(memory_space=pltpu.SMEM),   # (k0, k1, offset)
            plane,                                   # black (resident)
            plane,                                   # white (resident)
        ],
        out_specs=(plane, plane),
        out_shape=(jax.ShapeDtypeStruct(black.shape, black.dtype),
                   jax.ShapeDtypeStruct(white.shape, white.dtype)),
        input_output_aliases={2: 0, 3: 1},
        interpret=interpret,
    )(beta, seeds, black, white)
