"""Pure-jnp oracle for the stencil kernel (delegates to the core engine)."""
from __future__ import annotations

from repro.core import metropolis as metro


def stencil_update_ref(target, op_plane, inv_temp, *, is_black: bool,
                       uniforms=None, seed: int = 0, offset=0):
    if uniforms is not None:
        return metro.update_color(target, op_plane, uniforms, inv_temp,
                                  is_black)
    return metro.update_color_philox(target, op_plane, inv_temp, is_black,
                                     seed, offset)
