"""Pallas TPU kernel: fused checkerboard Metropolis stencil update.

TPU adaptation of the paper's basic CUDA kernel (Fig. 2): instead of one
thread per spin, the grid iterates over row blocks of the compact color
plane; each step stages the target block and the THREE relevant source
blocks (row-block i-1, i, i+1 -- periodic wrap via a modulo index_map)
into VMEM and performs the whole neighbor-sum + accept + flip on the VPU.
Blocks span the full row width so the side-neighbor wrap is a VMEM-local
roll; row blocks are even-height so checkerboard parity is block-uniform.

With in-kernel Philox (``uniforms=None``) this fuses what the paper's
basic implementation does in two passes (cuRAND host-API array population,
then update) into one -- DESIGN.md S6.2.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import rng as crng

DEFAULT_BLOCK_ROWS = 256


def _side(op, rows_parity, is_black):
    # column wrap as slice-concat (pad+slice form, H1.4): fusible
    # producers instead of jnp.roll's gather lowering
    plus = jnp.concatenate([op[:, 1:], op[:, :1]], axis=1)
    minus = jnp.concatenate([op[:, -1:], op[:, :-1]], axis=1)
    if is_black:
        return jnp.where(rows_parity == 1, plus, minus)
    return jnp.where(rows_parity == 1, minus, plus)


def _kernel(beta_ref, seeds_ref, target_ref, op_m1_ref, op_0_ref, op_p1_ref,
            out_ref, *, is_black: bool, block_rows: int, use_philox: bool,
            uniforms_ref=None):
    inv_temp = beta_ref[0]
    # neighbor sums stay in the plane dtype (int8: |sum| <= 4, H1.5) --
    # no int32 widening of the working set; the accept converts to
    # float32 exactly where the int32 path did, so flips are bit-identical
    op = op_0_ref[...]
    up_row = op_m1_ref[...][-1:, :]
    down_row = op_p1_ref[...][:1, :]
    up = jnp.concatenate([up_row, op[:-1, :]], axis=0)
    down = jnp.concatenate([op[1:, :], down_row], axis=0)
    parity = (jax.lax.broadcasted_iota(jnp.int32, op.shape, 0)
              % 2)  # block height is even => local parity == global parity
    nn = up + down + op + _side(op, parity, is_black)

    t = target_ref[...]
    if use_philox:
        k0 = seeds_ref[0]
        k1 = seeds_ref[1]
        offset = seeds_ref[2]
        i = pl.program_id(0)
        h = op.shape[1]
        rows = i * block_rows + jax.lax.broadcasted_iota(
            jnp.int32, op.shape, 0)
        cols = jax.lax.broadcasted_iota(jnp.int32, op.shape, 1)
        gidx = (rows * h + cols).astype(jnp.uint32)
        zero = jnp.zeros_like(gidx)
        bits = crng.philox4x32(offset, zero, gidx, zero, k0, k1)[0]
        u = crng.u32_to_uniform(bits)
    else:
        u = uniforms_ref[...]
    acc = jnp.exp(-2.0 * inv_temp * nn.astype(jnp.float32)
                  * t.astype(jnp.float32))
    out_ref[...] = jnp.where(u < acc, -t, t).astype(out_ref.dtype)


def stencil_update(target, op_plane, inv_temp, *, is_black: bool,
                   uniforms=None, seed: int = 0, offset=0,
                   block_rows: int = DEFAULT_BLOCK_ROWS,
                   interpret: bool = False):
    """One color half-sweep. If ``uniforms`` is None, draws Philox in-kernel.

    The Philox stream is keyed on the *global* (row, col) index, matching
    ``repro.core.metropolis.update_color_philox`` bit-for-bit.
    """
    n, h = target.shape
    block_rows = min(block_rows, n)
    assert n % block_rows == 0 and block_rows % 2 == 0
    nb = n // block_rows
    use_philox = uniforms is None

    beta = jnp.array([inv_temp], jnp.float32)
    # seed may be a python int or a traced uint32 scalar (ensemble vmap);
    # both Philox key lanes ride to SMEM so 64-bit seeds match the
    # basic_philox oracle bit-for-bit
    k0, k1 = crng.seed_keys(seed)
    seeds = jnp.stack([k0, k1, jnp.asarray(offset, jnp.uint32)])

    row_spec = pl.BlockSpec((block_rows, h), lambda i: (i, 0))
    specs = [
        pl.BlockSpec(memory_space=pltpu.SMEM),            # beta
        pl.BlockSpec(memory_space=pltpu.SMEM),            # seed/offset
        row_spec,                                          # target
        pl.BlockSpec((block_rows, h), lambda i: ((i - 1) % nb, 0)),
        row_spec,
        pl.BlockSpec((block_rows, h), lambda i: ((i + 1) % nb, 0)),
    ]
    args = [beta, seeds, target, op_plane, op_plane, op_plane]
    kern = functools.partial(_kernel, is_black=is_black,
                             block_rows=block_rows, use_philox=use_philox)
    if not use_philox:
        def kern_u(b, s, t, m1, c0, p1, u, o):
            _kernel(b, s, t, m1, c0, p1, o, is_black=is_black,
                    block_rows=block_rows, use_philox=False, uniforms_ref=u)
        kern = kern_u
        specs.append(row_spec)
        args.append(uniforms)

    return pl.pallas_call(
        kern,
        grid=(nb,),
        in_specs=specs,
        out_specs=row_spec,
        out_shape=jax.ShapeDtypeStruct(target.shape, target.dtype),
        interpret=interpret,
    )(*args)
