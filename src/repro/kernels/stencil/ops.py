"""Jitted wrappers for the stencil kernel: full sweeps on one device."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import rng as crng

from .stencil import stencil_update


@functools.partial(jax.jit, static_argnames=("n_sweeps", "seed",
                                             "block_rows", "interpret"),
                   donate_argnums=(0, 1))
def run_sweeps_stencil(black, white, inv_temp, n_sweeps: int, seed: int = 0,
                       start_offset=0, block_rows: int = 256,
                       interpret: bool = False):
    """n_sweeps full sweeps with in-kernel Philox (fused single-pass).

    The plane buffers are donated (H1.8): callers rebind ``b, w = ...``.
    """
    start_offset = jnp.uint32(start_offset)

    def body(i, carry):
        b, w = carry
        b = stencil_update(b, w, inv_temp, is_black=True, seed=seed,
                           offset=crng.half_sweep_offset(start_offset, i, 0),
                           block_rows=block_rows, interpret=interpret)
        w = stencil_update(w, b, inv_temp, is_black=False, seed=seed,
                           offset=crng.half_sweep_offset(start_offset, i, 1),
                           block_rows=block_rows, interpret=interpret)
        return (b, w)

    return jax.lax.fori_loop(0, n_sweeps, body, (black, white))
