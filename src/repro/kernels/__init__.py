"""Pallas TPU kernels for the paper's compute hot spots; each subpackage
has <name>.py (pl.pallas_call + BlockSpec), ops.py (jit'd wrapper), and
ref.py (pure-jnp oracle)."""
