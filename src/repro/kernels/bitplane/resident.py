"""Pallas TPU kernel: VMEM-resident k-full-sweep bitplane update (S9).

Same resident-tier contract as the stencil/multispin resident kernels
-- both uint32 bit planes (32 replica lattices deep, DESIGN.md S8)
staged into VMEM once, ``n_sweeps`` full sweeps in an in-kernel
``lax.fori_loop``, Philox offsets advanced per (sweep, color) by
``core.rng.half_sweep_offset``, one write-back.  Per half-sweep the
body reuses the oracle's helpers verbatim: carry-save neighbor counts
(``bit_count_neighbors``), ONE shared draw per site (counter =
(offset, 0, site//4, 0), lane = site%4 -- identical (group, lane) math
to ``core.bitplane.site_randoms``), and the bit-parallel 10-class
threshold accept (``flip_word_from_classes``) with the thresholds in
SMEM -- so bit-exactness vs ``n_sweeps`` iterations of
``run_sweeps_bitplane`` is by construction (tests/test_resident.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import bitplane as bpc
from repro.core import rng as crng


def _half_sweep(target, op, is_black: bool, thr, k0, k1, offset,
                gidx=None, lane=None):
    """One bitplane half-sweep of all 32 replicas, planes resident.

    ``gidx``/``lane`` override the shared-draw keying with precomputed
    uint32 global (site // 4, site % 4) planes -- the sharded resident
    tier (``repro.dist``) uses them because its halo-extended shard
    columns are neither 0-based nor 4-aligned, so the draw is made
    per site with a lane select (same (group, lane) math as
    ``core.bitplane.site_randoms``, 4x the Philox work, same bits)."""
    up = jnp.concatenate([op[-1:, :], op[:-1, :]], axis=0)
    down = jnp.concatenate([op[1:, :], op[:1, :]], axis=0)
    nxt = jnp.concatenate([op[:, 1:], op[:, :1]], axis=1)
    prv = jnp.concatenate([op[:, -1:], op[:, :-1]], axis=1)
    parity = (jax.lax.broadcasted_iota(jnp.uint32, op.shape, 0)
              % jnp.uint32(2))
    if is_black:
        side = jnp.where(parity == 1, nxt, prv)
    else:
        side = jnp.where(parity == 1, prv, nxt)
    counts = bpc.bit_count_neighbors(up, down, op, side)

    if gidx is None:
        n, w = op.shape
        gshape = (n, w // 4)
        rows = jax.lax.broadcasted_iota(jnp.int32, gshape, 0)
        cols = jax.lax.broadcasted_iota(jnp.int32, gshape, 1)
        g = (rows * (w // 4) + cols).astype(jnp.uint32)
        zero = jnp.zeros_like(g)
        lanes = crng.philox4x32(offset, zero, g, zero, k0, k1)
        draws = jnp.stack(lanes, axis=-1).reshape(n, w)
    else:
        zero = jnp.zeros_like(gidx)
        l0, l1, l2, l3 = crng.philox4x32(offset, zero, gidx, zero,
                                         k0, k1)
        draws = jnp.where(lane == 0, l0,
                          jnp.where(lane == 1, l1,
                                    jnp.where(lane == 2, l2, l3)))
    return target ^ bpc.flip_word_from_classes(target, counts, draws, thr)


def _kernel(seeds_ref, thr_ref, black_ref, white_ref, black_out,
            white_out, *, n_sweeps: int):
    k0 = seeds_ref[0]
    k1 = seeds_ref[1]
    start = seeds_ref[2]
    thr = [thr_ref[c] for c in range(10)]  # SMEM scalar reads, no gather

    def body(i, carry):
        b, w = carry
        b = _half_sweep(b, w, True, thr, k0, k1,
                        crng.half_sweep_offset(start, i, 0))
        w = _half_sweep(w, b, False, thr, k0, k1,
                        crng.half_sweep_offset(start, i, 1))
        return (b, w)

    b, w = jax.lax.fori_loop(0, n_sweeps, body,
                             (black_ref[...], white_ref[...]))
    black_out[...] = b
    white_out[...] = w


def bitplane_sweeps_resident(black_words, white_words, inv_temp, *,
                             n_sweeps: int, seed=0, start_offset=0,
                             interpret: bool = False, thresholds=None):
    """``n_sweeps`` bitplane full sweeps in ONE dispatch, planes resident.

    Bit-exact vs ``core.bitplane.run_sweeps_bitplane`` at the same
    ``start_offset``; advances all 32 replica chains.
    """
    assert n_sweeps >= 1, n_sweeps
    n, w = black_words.shape
    assert w % 4 == 0, "bitplane planes need a multiple-of-4 width"
    if thresholds is None:
        thresholds = bpc.ms.acceptance_thresholds(inv_temp)
    k0, k1 = crng.seed_keys(seed)
    seeds = jnp.stack([jnp.asarray(k0, jnp.uint32),
                       jnp.asarray(k1, jnp.uint32),
                       jnp.asarray(start_offset, jnp.uint32)])

    plane = pl.BlockSpec(memory_space=pltpu.VMEM)
    return pl.pallas_call(
        functools.partial(_kernel, n_sweeps=n_sweeps),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),   # (k0, k1, offset)
            pl.BlockSpec(memory_space=pltpu.SMEM),   # acceptance thresholds
            plane,                                   # black bits (resident)
            plane,                                   # white bits (resident)
        ],
        out_specs=(plane, plane),
        out_shape=(jax.ShapeDtypeStruct(black_words.shape,
                                        black_words.dtype),
                   jax.ShapeDtypeStruct(white_words.shape,
                                        white_words.dtype)),
        input_output_aliases={2: 0, 3: 1},
        interpret=interpret,
    )(seeds, thresholds, black_words, white_words)
