"""Pure-jnp oracle for the bitplane kernel (delegates to the core engine).

The core engine (repro.core.bitplane) keys its shared-per-site Philox
stream on (site // 4, site % 4) and the half-sweep offset exactly as the
kernel does, so the match is bit-exact, not merely allclose.
"""
from __future__ import annotations

from repro.core import bitplane as bp


def bitplane_update_ref(target_words, op_words, inv_temp, *,
                        is_black: bool, seed: int = 0, offset=0):
    return bp.update_color_bitplane(target_words, op_words, inv_temp,
                                    is_black, seed, offset)
