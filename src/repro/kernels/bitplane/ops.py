"""Jitted wrappers for the bitplane kernel: full sweeps on one device."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import rng as crng

from .bitplane import bitplane_update


@functools.partial(jax.jit, static_argnames=("n_sweeps", "seed",
                                             "block_rows", "interpret"),
                   donate_argnums=(0, 1))
def run_sweeps_bitplane_kernel(black_words, white_words, inv_temp,
                               n_sweeps: int, seed: int = 0, start_offset=0,
                               block_rows: int = 256,
                               interpret: bool = False):
    from repro.core import multispin as ms
    start_offset = jnp.uint32(start_offset)
    thresholds = ms.acceptance_thresholds(inv_temp)  # hoisted (H1.6)

    def body(i, carry):
        b, w = carry
        b = bitplane_update(b, w, inv_temp, is_black=True, seed=seed,
                            offset=crng.half_sweep_offset(start_offset,
                                                          i, 0),
                            block_rows=block_rows,
                            interpret=interpret, thresholds=thresholds)
        w = bitplane_update(w, b, inv_temp, is_black=False, seed=seed,
                            offset=crng.half_sweep_offset(start_offset,
                                                          i, 1),
                            block_rows=block_rows,
                            interpret=interpret, thresholds=thresholds)
        return (b, w)

    return jax.lax.fori_loop(0, n_sweeps, body,
                             (black_words, white_words))
