"""Pallas TPU kernel: bitplane multi-spin Metropolis update (DESIGN.md S8).

32 replica lattices live as 1-bit planes of uint32 VPU lanes; per grid
step the kernel stages a row block of the target plane plus three source
row blocks (i-1, i, i+1 with periodic modulo index_maps -- the same VMEM
staging as the stencil/multispin kernels), builds the 3-bit neighbor
counts with the carry-save adder, draws ONE shared Philox uint32 per
site in-kernel, and forms the flip word with the bit-parallel 10-class
threshold accept.  The 10 uint32 thresholds arrive in SMEM, precomputed
once per sweep call (H1.6); per-class reads are scalar, so no gather.

The pure-jnp oracle is ``repro.core.bitplane`` itself (``ref.py``
delegates there); the kernel reuses its ``bit_count_neighbors`` /
``flip_word_from_classes`` helpers verbatim, so bit-exactness at any
block size is by construction (tested in tests/test_bitplane.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import bitplane as bpc

DEFAULT_BLOCK_ROWS = 256


def _kernel(seeds_ref, thr_ref, target_ref, op_m1_ref, op_0_ref,
            op_p1_ref, out_ref, *, is_black: bool, block_rows: int):
    op = op_0_ref[...]
    up_row = op_m1_ref[...][-1:, :]
    down_row = op_p1_ref[...][:1, :]
    up = jnp.concatenate([up_row, op[:-1, :]], axis=0)
    down = jnp.concatenate([op[1:, :], down_row], axis=0)

    # row-parity side tap (block_rows is even, so parity is block-local)
    nxt = jnp.roll(op, -1, axis=1)
    prv = jnp.roll(op, 1, axis=1)
    parity = jax.lax.broadcasted_iota(jnp.uint32, op.shape, 0) % np.uint32(2)
    if is_black:
        side = jnp.where(parity == 1, nxt, prv)
    else:
        side = jnp.where(parity == 1, prv, nxt)
    counts = bpc.bit_count_neighbors(up, down, op, side)

    # one shared draw per site: counter = (offset, 0, site//4, 0), lane =
    # site%4 -- identical (group, lane) math to core.bitplane.site_randoms
    k0 = seeds_ref[0]
    k1 = seeds_ref[1]
    offset = seeds_ref[2]
    w = op.shape[1]
    i = pl.program_id(0)
    gshape = (block_rows, w // 4)
    rows = (i * block_rows
            + jax.lax.broadcasted_iota(jnp.int32, gshape, 0))
    cols = jax.lax.broadcasted_iota(jnp.int32, gshape, 1)
    g = (rows * (w // 4) + cols).astype(jnp.uint32)
    zero = jnp.zeros_like(g)
    lanes = bpc.crng.philox4x32(offset, zero, g, zero, k0, k1)
    draws = jnp.stack(lanes, axis=-1).reshape(block_rows, w)

    target = target_ref[...]
    thr = [thr_ref[c] for c in range(10)]  # SMEM scalar reads, no gather
    out_ref[...] = target ^ bpc.flip_word_from_classes(target, counts,
                                                       draws, thr)


def bitplane_update(target_words, op_words, inv_temp, *, is_black: bool,
                    seed: int = 0, offset=0,
                    block_rows: int = DEFAULT_BLOCK_ROWS,
                    interpret: bool = False, thresholds=None):
    """One bitplane color half-sweep; bit-exact vs the core.bitplane oracle."""
    n, w = target_words.shape
    assert w % 4 == 0, "bitplane planes need a multiple-of-4 width"
    block_rows = min(block_rows, n)
    assert n % block_rows == 0 and block_rows % 2 == 0
    nb = n // block_rows

    if thresholds is None:
        thresholds = bpc.ms.acceptance_thresholds(inv_temp)
    # seed_keys handles python ints (full 64-bit split) and traced uint32
    # seeds (ensemble vmap) alike, exactly as the oracle does
    k0, k1 = bpc.crng.seed_keys(seed)
    seeds = jnp.stack([jnp.asarray(k0, jnp.uint32),
                       jnp.asarray(k1, jnp.uint32),
                       jnp.asarray(offset, jnp.uint32)])

    row_spec = pl.BlockSpec((block_rows, w), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_kernel, is_black=is_black, block_rows=block_rows),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),   # (k0, k1, offset)
            pl.BlockSpec(memory_space=pltpu.SMEM),   # acceptance thresholds
            row_spec,
            pl.BlockSpec((block_rows, w), lambda i: ((i - 1) % nb, 0)),
            row_spec,
            pl.BlockSpec((block_rows, w), lambda i: ((i + 1) % nb, 0)),
        ],
        out_specs=row_spec,
        out_shape=jax.ShapeDtypeStruct(target_words.shape,
                                       target_words.dtype),
        interpret=interpret,
    )(seeds, thresholds, target_words, op_words, op_words, op_words)
