"""Pure-jnp oracle for the multispin kernel (delegates to the core engine).

The core engine (repro.core.multispin) keys its Philox stream on the global
word index and half-sweep offset exactly as the kernel does, so the match
is bit-exact, not merely allclose.
"""
from __future__ import annotations

from repro.core import multispin as ms


def multispin_update_ref(target_words, op_words, inv_temp, *,
                         is_black: bool, seed: int = 0, offset=0):
    return ms.update_color_packed(target_words, op_words, inv_temp,
                                  is_black, seed, offset)
