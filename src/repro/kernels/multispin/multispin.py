"""Pallas TPU kernel: multi-spin-coded Metropolis update (paper S3.3).

The TPU adaptation of the paper's flagship engine: 0/1 spins packed 4 bits
each into uint32 VPU lanes (8/word vs the paper's 16-per-uint64 -- the VPU
datapath is 32-bit), neighbor sums in THREE packed adds per word, Philox
drawn in-kernel (no random array traffic, cuRAND-style skip-ahead), and a
10-entry threshold LUT replacing per-spin ``exp`` (DESIGN.md S6.3).

Grid: row blocks of the packed word plane at full width, with periodic
neighbors supplied by modulo index_maps (i-1, i, i+1) -- the VMEM staging
that plays the role of the paper's shared-memory tile.  Per grid step the
VMEM working set is 4 row blocks + LUT; block_rows trades VMEM footprint
against grid overhead (swept in benchmarks/).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import rng as crng
from repro.core import lattice as lat

DEFAULT_BLOCK_ROWS = 256
_NIB = lat.NIBBLE_BITS


def _kernel(seeds_ref, thr_ref, target_ref, op_m1_ref, op_0_ref,
            op_p1_ref, out_ref, *, is_black: bool, block_rows: int):
    op = op_0_ref[...]
    up_row = op_m1_ref[...][-1:, :]
    down_row = op_p1_ref[...][:1, :]
    up = jnp.concatenate([up_row, op[:-1, :]], axis=0)
    down = jnp.concatenate([op[1:, :], down_row], axis=0)

    # side word: splice the one boundary nibble (paper Fig. 3)
    nxt = jnp.roll(op, -1, axis=1)
    prv = jnp.roll(op, 1, axis=1)
    plus = (op >> np.uint32(_NIB)) | (nxt << np.uint32(32 - _NIB))
    minus = (op << np.uint32(_NIB)) | (prv >> np.uint32(32 - _NIB))
    parity = jax.lax.broadcasted_iota(jnp.uint32, op.shape, 0) % np.uint32(2)
    if is_black:
        side = jnp.where(parity == 1, plus, minus)
    else:
        side = jnp.where(parity == 1, minus, plus)
    nn_words = up + down + op + side          # 3 packed adds / 8 spins

    target = target_ref[...]
    seed = seeds_ref[0]
    offset = seeds_ref[1]
    i = pl.program_id(0)
    w = op.shape[1]
    rows = (i * block_rows
            + jax.lax.broadcasted_iota(jnp.int32, op.shape, 0))
    cols = jax.lax.broadcasted_iota(jnp.int32, op.shape, 1)
    widx = (rows * w + cols).astype(jnp.uint32)
    zero = jnp.zeros_like(widx)
    lo = crng.philox4x32(np.uint32(2) * offset, zero, widx, zero,
                         seed, jnp.uint32(0))
    hi = crng.philox4x32(np.uint32(2) * offset + np.uint32(1), zero, widx,
                         zero, seed, jnp.uint32(0))
    draws = lo + hi  # 8 uint32 per word

    # integer-threshold accept (H1.6): the 10 uint32 thresholds live in
    # SMEM; the per-nibble lookup is a select chain over scalar reads
    # (Pallas kernels cannot vector-gather from SMEM) -- same uint32s as
    # the oracle's jnp.take, so bit-exactness is preserved
    thr = [thr_ref[c] for c in range(10)]
    flip_word = jnp.zeros_like(target)
    for nib in range(lat.SPINS_PER_WORD):
        sh = np.uint32(nib * _NIB)
        s = (target >> sh) & np.uint32(1)
        nn = (nn_words >> sh) & np.uint32(0xF)
        idx = s * np.uint32(5) + nn
        t = jnp.zeros_like(idx)
        for c in range(10):
            t = jnp.where(idx == np.uint32(c), thr[c], t)
        flip = (draws[nib] < t).astype(jnp.uint32)
        flip_word = flip_word | (flip << sh)
    out_ref[...] = target ^ flip_word


def multispin_update(target_words, op_words, inv_temp, *, is_black: bool,
                     seed: int = 0, offset=0,
                     block_rows: int = DEFAULT_BLOCK_ROWS,
                     interpret: bool = False, thresholds=None):
    """One packed color half-sweep; bit-exact vs core.multispin oracle.

    ``thresholds`` takes a precomputed ``acceptance_thresholds(inv_temp)``
    so sweep loops hoist the 10 exps out of their fori_loop (H1.6).
    """
    from repro.core import multispin as ms
    n, w = target_words.shape
    block_rows = min(block_rows, n)
    assert n % block_rows == 0 and block_rows % 2 == 0
    nb = n // block_rows

    if thresholds is None:
        thresholds = ms.acceptance_thresholds(inv_temp)
    # seed may be a python int or a traced uint32 scalar (ensemble vmap,
    # demoted-fallback dispatch); mask in python only when it IS python
    if isinstance(seed, (int, np.integer)):
        seed = seed & 0xFFFFFFFF
    seeds = jnp.stack([jnp.asarray(seed).astype(jnp.uint32),
                       jnp.asarray(offset).astype(jnp.uint32)])

    row_spec = pl.BlockSpec((block_rows, w), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_kernel, is_black=is_black, block_rows=block_rows),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),   # seed/offset
            pl.BlockSpec(memory_space=pltpu.SMEM),   # acceptance thresholds
            row_spec,
            pl.BlockSpec((block_rows, w), lambda i: ((i - 1) % nb, 0)),
            row_spec,
            pl.BlockSpec((block_rows, w), lambda i: ((i + 1) % nb, 0)),
        ],
        out_specs=row_spec,
        out_shape=jax.ShapeDtypeStruct(target_words.shape,
                                       target_words.dtype),
        interpret=interpret,
    )(seeds, thresholds, target_words, op_words, op_words, op_words)
