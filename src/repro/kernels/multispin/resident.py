"""Pallas TPU kernel: VMEM-resident k-full-sweep multispin update (S9).

Same resident-tier contract as ``kernels/stencil/resident.py`` -- both
packed word planes staged into VMEM once, ``n_sweeps`` full sweeps in an
in-kernel ``lax.fori_loop`` with Philox offsets advanced per (sweep,
color) by ``core.rng.half_sweep_offset``, one write-back -- applied to
the S2 nibble packing: 8 spins/uint32 word, three packed adds per
neighbor sum, two Philox4x32 calls per word (8 draws), and the H1.6
integer-threshold accept with the 10-entry table in SMEM (precomputed
once per call, structurally hoisted out of the in-kernel loop).

Bit-exact vs ``n_sweeps`` iterations of the ``core.multispin`` oracle
(``run_sweeps_packed``) -- the draw keys come from ``seed_keys`` exactly
as the oracle's ``word_randoms``, so full 64-bit python seeds match too.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import lattice as lat
from repro.core import rng as crng

_NIB = lat.NIBBLE_BITS


def _half_sweep(target, op, is_black: bool, thr, k0, k1, offset,
                widx=None):
    """One packed color half-sweep on whole VMEM-resident word planes.

    ``widx`` overrides the Philox word keying with a precomputed uint32
    global word-index plane (sharded resident tier, ``repro.dist``);
    ``None`` keys on local iota -- correct when the planes ARE the full
    lattice."""
    up = jnp.concatenate([op[-1:, :], op[:-1, :]], axis=0)
    down = jnp.concatenate([op[1:, :], op[:1, :]], axis=0)
    # side word: nibble funnel shift splicing the edge nibble of the
    # adjacent word (paper Fig. 3); column wrap as slice-concat (H1.4)
    nxt = jnp.concatenate([op[:, 1:], op[:, :1]], axis=1)
    prv = jnp.concatenate([op[:, -1:], op[:, :-1]], axis=1)
    plus = (op >> np.uint32(_NIB)) | (nxt << np.uint32(32 - _NIB))
    minus = (op << np.uint32(_NIB)) | (prv >> np.uint32(32 - _NIB))
    parity = jax.lax.broadcasted_iota(jnp.uint32, op.shape, 0) % np.uint32(2)
    if is_black:
        side = jnp.where(parity == 1, plus, minus)
    else:
        side = jnp.where(parity == 1, minus, plus)
    nn_words = up + down + op + side          # 3 packed adds / 8 spins

    if widx is None:
        w = op.shape[1]
        rows = jax.lax.broadcasted_iota(jnp.int32, op.shape, 0)
        cols = jax.lax.broadcasted_iota(jnp.int32, op.shape, 1)
        widx = (rows * w + cols).astype(jnp.uint32)
    zero = jnp.zeros_like(widx)
    lo = crng.philox4x32(np.uint32(2) * offset, zero, widx, zero, k0, k1)
    hi = crng.philox4x32(np.uint32(2) * offset + np.uint32(1), zero, widx,
                         zero, k0, k1)
    draws = lo + hi  # 8 uint32 per word

    # integer-threshold accept (H1.6): select chain over the 10 SMEM
    # scalars, same uint32s as the oracle's jnp.take -- bit-exact
    flip_word = jnp.zeros_like(target)
    for nib in range(lat.SPINS_PER_WORD):
        sh = np.uint32(nib * _NIB)
        s = (target >> sh) & np.uint32(1)
        nn = (nn_words >> sh) & np.uint32(0xF)
        idx = s * np.uint32(5) + nn
        t = jnp.zeros_like(idx)
        for c in range(10):
            t = jnp.where(idx == np.uint32(c), thr[c], t)
        flip = (draws[nib] < t).astype(jnp.uint32)
        flip_word = flip_word | (flip << sh)
    return target ^ flip_word


def _kernel(seeds_ref, thr_ref, black_ref, white_ref, black_out,
            white_out, *, n_sweeps: int):
    k0 = seeds_ref[0]
    k1 = seeds_ref[1]
    start = seeds_ref[2]
    thr = [thr_ref[c] for c in range(10)]  # SMEM scalar reads, no gather

    def body(i, carry):
        b, w = carry
        b = _half_sweep(b, w, True, thr, k0, k1,
                        crng.half_sweep_offset(start, i, 0))
        w = _half_sweep(w, b, False, thr, k0, k1,
                        crng.half_sweep_offset(start, i, 1))
        return (b, w)

    b, w = jax.lax.fori_loop(0, n_sweeps, body,
                             (black_ref[...], white_ref[...]))
    black_out[...] = b
    white_out[...] = w


def multispin_sweeps_resident(black_words, white_words, inv_temp, *,
                              n_sweeps: int, seed=0, start_offset=0,
                              interpret: bool = False, thresholds=None):
    """``n_sweeps`` packed full sweeps in ONE dispatch, words resident.

    Bit-exact vs ``core.multispin.run_sweeps_packed`` at the same
    ``start_offset``.  ``thresholds`` takes a precomputed
    ``acceptance_thresholds(inv_temp)``; ``None`` computes it here (once
    per dispatch either way -- it rides to SMEM outside the loop).
    """
    assert n_sweeps >= 1, n_sweeps
    from repro.core import multispin as ms
    if thresholds is None:
        thresholds = ms.acceptance_thresholds(inv_temp)
    k0, k1 = crng.seed_keys(seed)
    seeds = jnp.stack([k0, k1, jnp.asarray(start_offset, jnp.uint32)])

    plane = pl.BlockSpec(memory_space=pltpu.VMEM)
    return pl.pallas_call(
        functools.partial(_kernel, n_sweeps=n_sweeps),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),   # (k0, k1, offset)
            pl.BlockSpec(memory_space=pltpu.SMEM),   # acceptance thresholds
            plane,                                   # black words (resident)
            plane,                                   # white words (resident)
        ],
        out_specs=(plane, plane),
        out_shape=(jax.ShapeDtypeStruct(black_words.shape,
                                        black_words.dtype),
                   jax.ShapeDtypeStruct(white_words.shape,
                                        white_words.dtype)),
        input_output_aliases={2: 0, 3: 1},
        interpret=interpret,
    )(seeds, thresholds, black_words, white_words)
