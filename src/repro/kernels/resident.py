"""VMEM-budget planner for the resident-sweep kernel tier (DESIGN.md S9).

The per-half-sweep kernels (``kernels/{stencil,multispin,bitplane}``)
re-read and re-write both compact color planes through HBM twice per
sweep, so a ``measure_every``-sized block of ``k`` sweeps costs ``2k``
HBM round-trips of the whole working set.  When both planes FIT in
per-core VMEM, the resident kernels (``resident.py`` in each family
directory) instead stage the planes into VMEM once, run all ``k`` sweeps
in an in-kernel ``lax.fori_loop`` (Philox offsets advanced in-kernel per
(sweep, color) -- ``core.rng.half_sweep_offset``), and write the planes
back once: HBM traffic drops from O(k) plane round-trips to O(1).

This module is the single place that decides *whether* the planes fit.
``plan_resident(family, n, m)`` returns a :class:`ResidentPlan` when the
modeled VMEM working set is within :data:`VMEM_BUDGET_BYTES`, else
``None`` -- the engines (``core/engine.py``) compute the plan once at
construction and route ``sweep_fn`` through the resident kernel or fall
back to the per-half-sweep tier accordingly, so ``Simulation``,
``Ensemble`` and ``measure_scan`` pick the tier up with no caller
changes.

Working-set model (conservative, documented per family): the resident
state is both color planes plus the loop-carry copy XLA may keep live
across the ``fori_loop`` back-edge (4 plane-equivalents), plus the
per-half-sweep temporaries that peak simultaneously (neighbor taps,
counts/sums, draws, accept masks).  The multipliers below count those
temporaries in units of one color plane of the family's native dtype:

* ``stencil``   -- int8 planes; temps: 4 int8 taps/sums + draw and
  acceptance float32 planes (8 int8-plane-equivalents) -> 16x.
* ``multispin`` -- uint32 word planes; temps: taps + nn_words (4x) +
  the EIGHT per-nibble uint32 draw planes + flip/select chain (~2x)
  -> 18x.
* ``bitplane``  -- uint32 bit planes; temps: 3 taps + 3 count bitplanes
  + 1 shared draw plane + flip (8x) -> 12x.

The model is deliberately pessimistic: a plan that fits the model fits
the hardware with headroom for Mosaic's own allocation; lattices near
the boundary fall back to the (always-correct) per-half-sweep tier.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional

from repro.resilience import degrade
from repro.telemetry import TRACER

#: modeled per-core VMEM budget for the resident working set.  Cores have
#: ~16 MiB of VMEM (pallas_guide.md); half is left to the compiler for
#: spills, the SMEM-adjacent scalars, and double-buffered plane I/O.
VMEM_BUDGET_BYTES: int = 8 * 1024 * 1024

#: family -> (bytes per site of ONE compact color plane, working-set
#: multiplier in plane units).  Plane geometry is (n, m/2) sites for
#: stencil (int8) and bitplane (uint32 word per site); multispin packs 8
#: sites per uint32 word, so its plane is (n, m/16) words.
_FAMILIES: Dict[str, tuple] = {
    "stencil": (1.0, 16),     # int8 site planes
    "multispin": (0.5, 18),   # 4 bits/site in uint32 words
    "bitplane": (4.0, 12),    # uint32 word per site (32 replicas deep)
}


@dataclasses.dataclass(frozen=True)
class ResidentPlan:
    """A positive fit decision: this (family, lattice) runs resident."""

    family: str
    n: int
    m: int
    plane_bytes: int
    working_set_bytes: int
    budget_bytes: int


def plane_bytes(family: str, n: int, m: int) -> int:
    """Bytes of ONE compact color plane in the family's native packing."""
    per_site, _ = _FAMILIES[family]
    return int(n * (m // 2) * per_site)


def working_set_bytes(family: str, n: int, m: int) -> int:
    """Modeled peak VMEM bytes of the resident kernel (module docstring)."""
    _, mult = _FAMILIES[family]
    return plane_bytes(family, n, m) * mult


def plan_resident(family: str, n: int, m: int,
                  budget_bytes: Optional[int] = None
                  ) -> Optional[ResidentPlan]:
    """Fit decision for one (engine family, lattice) pair.

    Returns a :class:`ResidentPlan` when the modeled working set fits
    ``budget_bytes`` (default :data:`VMEM_BUDGET_BYTES`, read at call
    time so tests can move the fallback boundary), else ``None``.
    A (family, lattice) demoted by the dispatch-recovery layer
    (``resilience.degrade``, e.g. after a RESOURCE_EXHAUSTED launch)
    never fits again this process, whatever the model says.
    """
    if family not in _FAMILIES:
        raise ValueError(f"unknown resident family {family!r}; "
                         f"known: {sorted(_FAMILIES)}")
    budget = VMEM_BUDGET_BYTES if budget_bytes is None else budget_bytes
    ws = working_set_bytes(family, n, m)
    if TRACER.enabled:
        TRACER.instant("planner.decide",
                       **decision_attrs(family, n, m,
                                        budget_bytes=budget))
    if ws > budget or degrade.demotion_reason(family, n, m) is not None:
        return None
    return ResidentPlan(family=family, n=n, m=m,
                        plane_bytes=plane_bytes(family, n, m),
                        working_set_bytes=ws, budget_bytes=budget)


def decision_attrs(family: str, n: int, m: int,
                   budget_bytes: Optional[int] = None) -> dict:
    """The planner's decision and its budget arithmetic as one flat
    JSON-scalar dict -- the SINGLE rendering shared by the ``--dry-run``
    plan (``repro.api.session.describe``), the ``planner.decide`` trace
    instant, and the engines' ``dispatch`` span attributes, so the three
    can never disagree about the tier.
    """
    if family not in _FAMILIES:
        raise ValueError(f"unknown resident family {family!r}; "
                         f"known: {sorted(_FAMILIES)}")
    budget = VMEM_BUDGET_BYTES if budget_bytes is None else budget_bytes
    ws = working_set_bytes(family, n, m)
    attrs = {"family": family, "fits_vmem": ws <= budget,
             "plane_bytes": plane_bytes(family, n, m),
             "working_set_bytes": ws, "budget_bytes": budget}
    demoted = degrade.demotion_reason(family, n, m)
    if demoted is not None:
        attrs["demoted"] = True
        attrs["reason"] = (f"demoted to per-half-sweep fallback tier: "
                           f"{demoted}")
    elif ws > budget:
        attrs["reason"] = (f"working set {ws} B exceeds VMEM budget "
                           f"{budget} B: per-half-sweep fallback tier")
    return attrs


def max_square_lattice(family: str,
                       budget_bytes: Optional[int] = None) -> int:
    """Largest even square side n with working_set(n, n) <= budget --
    the fallback boundary, for docs/tests (DESIGN.md S9 table)."""
    budget = VMEM_BUDGET_BYTES if budget_bytes is None else budget_bytes
    per_site, mult = _FAMILIES[family]
    # working_set(n, n) = n * (n/2) * per_site * mult
    n = int(math.isqrt(int(2 * budget / (per_site * mult))))
    n -= n % 2
    while n > 0 and working_set_bytes(family, n, n) > budget:
        n -= 2
    return n
