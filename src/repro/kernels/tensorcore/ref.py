"""Pure-jnp oracle for the fused tensorcore kernel.

Computes complete neighbor sums with the core engine's global einsum +
boundary-correction path and replicates the kernel's Philox stream
(lane 0 -> first target plane, lane 1 -> second), so the comparison is
exact, not merely allclose.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import rng as crng
from repro.core.tensorcore import neighbor_sums_tc


def tensorcore_update_ref(planes: dict, color: str, inv_temp, *,
                          seed: int = 0, offset=0, block: int = 128) -> dict:
    is_black = color == "black"
    t1k, t2k = ("00", "11") if is_black else ("10", "01")
    nn = neighbor_sums_tc(planes, block)

    h, w = planes[t1k].shape
    gidx = jnp.arange(h * w, dtype=jnp.uint32).reshape(h, w)
    zero = jnp.zeros_like(gidx)
    r = crng.philox4x32(jnp.uint32(offset), zero, gidx, zero,
                        jnp.uint32(seed & 0xFFFFFFFF), jnp.uint32(0))
    u1 = crng.u32_to_uniform(r[0])
    u2 = crng.u32_to_uniform(r[1])

    out = dict(planes)
    for key, u in ((t1k, u1), (t2k, u2)):
        t = planes[key].astype(jnp.float32)
        acc = jnp.exp(-2.0 * inv_temp * nn[key] * t)
        out[key] = jnp.where(u < acc, -t, t).astype(planes[key].dtype)
    return out
