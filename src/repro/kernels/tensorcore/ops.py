"""Jitted wrappers for the fused tensorcore kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import rng as crng

from .tensorcore import tensorcore_update


@functools.partial(jax.jit, static_argnames=("n_sweeps", "seed", "block",
                                             "interpret"))
def run_sweeps_tensorcore(planes, inv_temp, n_sweeps: int, seed: int = 0,
                          start_offset=0, block: int = 128,
                          interpret: bool = False):
    """n_sweeps full sweeps (black then white) of the fused MXU engine."""
    start_offset = jnp.uint32(start_offset)

    def body(i, p):
        p = tensorcore_update(p, "black", inv_temp, seed=seed,
                              offset=crng.half_sweep_offset(start_offset,
                                                            i, 0),
                              block=block, interpret=interpret)
        p = tensorcore_update(p, "white", inv_temp, seed=seed,
                              offset=crng.half_sweep_offset(start_offset,
                                                            i, 1),
                              block=block, interpret=interpret)
        return p

    return jax.lax.fori_loop(0, n_sweeps, body, planes)
