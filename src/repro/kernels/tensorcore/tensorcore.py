"""Pallas TPU kernel: FUSED tensor-core (MXU) Metropolis update.

The paper's tensor-core implementation (S3.2) runs three separate passes
per color -- batched GEMMs (cublasHgemmBatched), a boundary kernel, and an
update kernel -- and loses to the stencil because of the extra HBM
round-trips.  This kernel is the beyond-paper fix (DESIGN.md S6.1): one
grid step stages a 128x128 block pair of the target planes plus the six
neighbor source blocks into VMEM, runs both banded GEMMs on the MXU
(bf16 in, f32 accumulate -- the MXU-native layout), applies the boundary
corrections and the Metropolis accept, and writes the flipped spins.  One
HBM round-trip instead of three.

Block edges use modulo index_maps for periodic wrap.  Spins are stored
bf16 (the paper's half-precision choice); sums accumulate in f32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import rng as crng
from repro.core.tensorcore import make_kernel_matrix

DEFAULT_BLOCK = 128


def _philox_uniform_pair(seed, offset, gidx):
    """Two decorrelated uniforms per plane position (lanes 0/1)."""
    zero = jnp.zeros_like(gidx)
    r = crng.philox4x32(offset, zero, gidx, zero, seed, jnp.uint32(0))
    return crng.u32_to_uniform(r[0]), crng.u32_to_uniform(r[1])


def _accept(t, nn, u, inv_temp):
    tf = t.astype(jnp.float32)
    acc = jnp.exp(-2.0 * inv_temp * nn * tf)
    return jnp.where(u < acc, -tf, tf).astype(t.dtype)


def _kernel(beta_ref, seeds_ref, k_ref, t1_ref, t2_ref, a_c_ref, a_side_ref,
            a_vert_ref, b_c_ref, b_vert_ref, b_side_ref, out1_ref, out2_ref,
            *, is_black: bool, block: int, plane_w: int):
    inv_temp = beta_ref[0]
    k = k_ref[...]
    kt = k.T
    a = a_c_ref[...]
    b = b_c_ref[...]

    dot = functools.partial(jax.lax.dot,
                            preferred_element_type=jnp.float32)
    if is_black:
        # nn(s00) = s01 K + K^T s10 ; nn(s11) = s10 K^T + K s01
        nn1 = dot(a, k) + dot(kt, b)
        nn2 = dot(b, kt) + dot(k, a)
    else:
        # nn(s10) = s11 K + K s00 ; nn(s01) = s00 K^T + K^T s11
        nn1 = dot(a, k) + dot(k, b)
        nn2 = dot(b, kt) + dot(kt, a)

    rows = jax.lax.broadcasted_iota(jnp.int32, nn1.shape, 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, nn1.shape, 1)
    first_c = (cols == 0).astype(jnp.float32)
    last_c = (cols == block - 1).astype(jnp.float32)
    first_r = (rows == 0).astype(jnp.float32)
    last_r = (rows == block - 1).astype(jnp.float32)

    a_side = a_side_ref[...].astype(jnp.float32)   # block (i, j-1)
    a_vert = a_vert_ref[...].astype(jnp.float32)   # (i+1, j) black / (i-1, j) white
    b_vert = b_vert_ref[...].astype(jnp.float32)   # (i-1, j) black / (i+1, j) white
    b_side = b_side_ref[...].astype(jnp.float32)   # block (i, j+1)

    if is_black:
        nn1 = nn1 + first_c * a_side[:, -1:] + first_r * b_vert[-1:, :]
        nn2 = nn2 + last_c * b_side[:, :1] + last_r * a_vert[:1, :]
    else:
        nn1 = nn1 + first_c * a_side[:, -1:] + last_r * b_vert[:1, :]
        nn2 = nn2 + last_c * b_side[:, :1] + first_r * a_vert[-1:, :]

    seed = seeds_ref[0]
    offset = seeds_ref[1]
    i = pl.program_id(0)
    j = pl.program_id(1)
    gi = i * block + rows
    gj = j * block + cols
    gidx = (gi * plane_w + gj).astype(jnp.uint32)
    u1, u2 = _philox_uniform_pair(seed, offset, gidx)

    out1_ref[...] = _accept(t1_ref[...], nn1, u1, inv_temp)
    out2_ref[...] = _accept(t2_ref[...], nn2, u2, inv_temp)


def tensorcore_update(planes: dict, color: str, inv_temp, *, seed: int = 0,
                      offset=0, block: int = DEFAULT_BLOCK,
                      interpret: bool = False) -> dict:
    """Fused MXU half-sweep for one color. planes: {'00','01','10','11'} bf16."""
    is_black = color == "black"
    t1k, t2k = ("00", "11") if is_black else ("10", "01")
    ak, bk = ("01", "10") if is_black else ("11", "00")
    t1, t2, a, b = planes[t1k], planes[t2k], planes[ak], planes[bk]
    h, w = t1.shape
    assert h % block == 0 and w % block == 0
    nbi, nbj = h // block, w // block

    beta = jnp.array([inv_temp], jnp.float32)
    seeds = jnp.array([seed & 0xFFFFFFFF, offset], jnp.uint32)
    kmat = make_kernel_matrix(block)

    c = pl.BlockSpec((block, block), lambda i, j: (i, j))
    left = pl.BlockSpec((block, block), lambda i, j: (i, (j - 1) % nbj))
    right = pl.BlockSpec((block, block), lambda i, j: (i, (j + 1) % nbj))
    down = pl.BlockSpec((block, block), lambda i, j: ((i + 1) % nbi, j))
    up = pl.BlockSpec((block, block), lambda i, j: ((i - 1) % nbi, j))
    a_vert = down if is_black else up
    b_vert = up if is_black else down

    new1, new2 = pl.pallas_call(
        functools.partial(_kernel, is_black=is_black, block=block,
                          plane_w=w),
        grid=(nbi, nbj),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),   # beta
            pl.BlockSpec(memory_space=pltpu.SMEM),   # seed/offset
            pl.BlockSpec((block, block), lambda i, j: (0, 0)),  # K
            c, c,                                    # targets
            c, left, a_vert,                         # a plane blocks
            c, b_vert, right,                        # b plane blocks
        ],
        out_specs=(c, c),
        out_shape=(jax.ShapeDtypeStruct(t1.shape, t1.dtype),
                   jax.ShapeDtypeStruct(t2.shape, t2.dtype)),
        interpret=interpret,
    )(beta, seeds, kmat, t1, t2, a, a, a, b, b, b)

    out = dict(planes)
    out[t1k], out[t2k] = new1, new2
    return out
