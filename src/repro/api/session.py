"""Session: one façade that executes any :class:`~repro.api.spec.RunSpec`.

``Session.open(spec)`` inspects the spec shape and builds the matching
runner (DESIGN.md S10):

* single      -- the registry engine advanced in place (the legacy
                 ``Simulation`` logic lives here now);
* ensemble    -- every (temperature, seed) member advanced in ONE
                 vmapped, jit-compiled sweep (the legacy ``Ensemble``
                 logic lives here now);
* sharded     -- the ``repro.core.distributed`` step named by the
                 engine's ``dist_factory`` flag on a ``MeshSpec`` mesh.

All three share one checkpoint layout: an atomically-renamed ``.npz``
holding ``spec_json`` (the lossless serialized spec), ``step_count``,
and the engine's named state arrays (batched along axis 0 for
ensembles).  ``Session.restore(path)`` needs nothing but the file: the
spec inside it rebuilds the engine, the runner, and -- for counter-based
engines -- the exact Philox stream, so a restored run continues
bit-for-bit (fault-tolerance contract, tests/test_api.py).

``describe(spec)`` is the dry-run: the dispatch decision, capability
flags, resident-tier plan, and sweep totals as one dict, computed
without touching device state (``python -m repro run --dry-run``).
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

import repro.telemetry as tel
from repro.core.engine import ENGINES, make_engine
from repro.resilience import degrade

from .spec import RunSpec

#: default for ``Session.restore(mesh=...)``: keep the checkpoint's mesh
_KEEP = object()

#: ``Engine.dist_factory`` flag -> ``repro.core.distributed`` factory name
_DIST_FACTORIES = {
    "basic": "make_ising_step",
    "packed": "make_packed_ising_step",
    "bitplane": "make_bitplane_ising_step",
}


def _atomic_savez(path: str, **arrays) -> None:
    """Write-temp-then-rename .npz (the ``sim.save`` semantics): a killed
    writer never leaves a readable-but-partial checkpoint."""
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".",
                               suffix=".tmp")
    with os.fdopen(fd, "wb") as f:
        np.savez(f, **arrays)
    os.replace(tmp, path)


# ---------------------------------------------------------------------------
# runners: one per dispatch mode
# ---------------------------------------------------------------------------

class _SingleRunner:
    """One lattice, engine advanced in place (ex-``Simulation`` core)."""

    mode = "single"

    def __init__(self, spec: RunSpec, state=None, step_count: int = 0):
        self.spec = spec
        self.cfg = spec.sim_config()
        self.engine = make_engine(self.cfg)
        self.step_count = step_count
        self.state = self.engine.init_state(
            jax.random.PRNGKey(self.cfg.seed)) if state is None else state

    def run(self, n_sweeps: int):
        self.state = self.engine.sweeps(self.state, n_sweeps,
                                        self.step_count)
        self.step_count += n_sweeps
        return None

    def measure(self, plan) -> dict:
        from repro.analysis.measure import measure_scan
        self.state, traj, self.step_count = measure_scan(
            self.engine, self.state, plan, step_count=self.step_count)
        return traj

    def magnetization(self) -> float:
        return float(self.engine.magnetization(self.state))

    def energy(self) -> float:
        return float(self.engine.energy(self.state))

    def full_lattice(self):
        return self.engine.full_lattice(self.state)

    def state_arrays(self) -> dict:
        return self.engine.state_arrays(self.state)

    def load_arrays(self, arrays: dict) -> None:
        self.state = self.engine.from_arrays(arrays)


class _EnsembleRunner:
    """A (temperature, seed) batch advanced in ONE vmapped sweep
    (ex-``Ensemble`` core).

    Bit-exactness contract: member ``i`` follows exactly the trajectory
    of the single-mode spec with ``temperature=members[i][0],
    seed=members[i][1]`` (seeds are validated < 2**32 by ``BatchSpec``,
    so the uint32 cast below is lossless).
    """

    mode = "ensemble"

    def __init__(self, spec: RunSpec, state=None, step_count: int = 0):
        self.spec = spec
        self.cfg = spec.sim_config()
        self.engine = make_engine(self.cfg)
        self.step_count = step_count
        self._jit_cache = {}
        # jitted once per RUNNER, not per batch: jit caches on these fn
        # objects, so rebind() re-initializes a new member set without
        # retracing (the serve compiled-executable cache rides on this)
        self._init_states = jax.jit(jax.vmap(self.engine.init_state))
        self._magnetizations = jax.jit(jax.vmap(self.engine.magnetization))
        self._full_lattices = jax.jit(jax.vmap(self.engine.full_lattice))
        self._set_members(spec)
        if state is None:
            state = self._fresh_states()
        self.states = state

    def _set_members(self, spec: RunSpec) -> None:
        temps = spec.batch.member_temperatures
        seeds = spec.batch.member_seeds
        self.temperatures = np.asarray(temps, np.float32)
        # invert in python-float precision exactly like SimConfig.inv_temp
        # (1.0/float32(T) can land 1 ulp off float32(1.0/T), which would
        # eventually fork a member from its single-mode trajectory)
        self.inv_temps = jnp.asarray([1.0 / float(t) for t in temps],
                                     jnp.float32)
        self.seeds = jnp.asarray(np.asarray(seeds, np.int64) & 0xFFFFFFFF,
                                 jnp.uint32)
        self._member_seeds = tuple(int(s) for s in seeds)

    def _fresh_states(self):
        keys = jax.vmap(jax.random.PRNGKey)(
            jnp.asarray(np.asarray(self._member_seeds), jnp.int32))
        return self._init_states(keys)

    def rebind(self, spec: RunSpec) -> None:
        """Re-point this runner at a NEW (temperature, seed) batch of
        the SAME shape: same engine + params, same lattice, same batch
        size.  Keeps the engine and every jit cache -- because
        ``sweep_fn`` takes ``inv_temp``/``seed``/``start_offset`` as
        traced arguments, the compiled executables are member-agnostic
        and the rebound batch runs with zero recompilation.  This is
        the serve scheduler's compiled-executable cache primitive."""
        if spec.mode != "ensemble":
            raise ValueError(
                f"rebind needs an ensemble spec, got mode={spec.mode!r}")
        old, new = self.spec, spec
        same = (old.engine.to_dict() == new.engine.to_dict()
                and old.lattice.to_dict() == new.lattice.to_dict()
                and old.batch.size == new.batch.size)
        if not same:
            raise ValueError(
                f"rebind shape mismatch: cached runner is "
                f"{old.engine.name}/{old.lattice.n}x{old.lattice.m}/"
                f"B{old.batch.size}, spec wants "
                f"{new.engine.name}/{new.lattice.n}x{new.lattice.m}/"
                f"B{new.batch.size}")
        self.spec = spec
        self._set_members(spec)
        self.states = self._fresh_states()
        self.step_count = 0

    @property
    def size(self) -> int:
        return int(self.temperatures.size)

    def _compiled(self, n_sweeps: int):
        fn = self._jit_cache.get(n_sweeps)
        if fn is None:
            def one(state, inv_temp, seed, start_offset):
                state = self.engine.sweep_fn(state, inv_temp, seed,
                                             start_offset, n_sweeps)
                return state, self.engine.magnetization(state)

            fn = jax.jit(jax.vmap(one, in_axes=(0, 0, 0, None)))
            self._jit_cache[n_sweeps] = fn
        return fn

    def run(self, n_sweeps: int) -> np.ndarray:
        """Advance every member in one vmapped call; returns the (B,)
        per-member magnetizations (at fixed seeds this IS the
        magnetization-vs-temperature curve).

        Launched through ``resilience.degrade.run_dispatch``: a
        resident-tier demotion clears this runner's jit cache too
        (``on_demote``), so the retry re-traces ``sweep_fn`` on the
        fallback tier."""
        def attempt():
            fresh = n_sweeps not in self._jit_cache
            fn = self._compiled(n_sweeps)
            with self.engine._dispatch(
                    n_sweeps, batch=self.size,
                    compile="first" if fresh else "steady",
                    **self.engine.resident_attrs) as sp:
                states, mags = fn(
                    self.states, self.inv_temps, self.seeds,
                    jnp.uint32(2 * self.step_count))
                sp.fence(mags)
            return states, mags

        self.states, mags = degrade.run_dispatch(
            attempt, engine=self.engine,
            on_demote=self._jit_cache.clear)
        self.step_count += n_sweeps
        return np.asarray(mags)

    def measure(self, plan) -> dict:
        from repro.analysis.measure import measure_scan_batched
        self.states, traj, self.step_count = measure_scan_batched(
            self.engine, self.states, self.inv_temps, self.seeds, plan,
            step_count=self.step_count)
        return traj

    def magnetization(self) -> np.ndarray:
        """(B,) per-member magnetization of the current states."""
        return np.asarray(self._magnetizations(self.states))

    def full_lattice(self) -> np.ndarray:
        """(B, N, M) stacked +-1 lattices (measurement/debug view)."""
        return np.asarray(self._full_lattices(self.states))

    def state_arrays(self) -> dict:
        """Engine-named arrays with the batch axis leading -- the same
        names as a single checkpoint, one rank higher."""
        return {k: np.asarray(v) for k, v in
                self.engine.state_arrays(self.states).items()}

    def load_arrays(self, arrays: dict) -> None:
        # from_arrays is shape-agnostic per leaf, so batched arrays
        # rebuild the batched pytree directly
        self.states = self.engine.from_arrays(arrays)


#: ``Engine.dist_factory`` flag -> (plane cells per row given lattice
#: m, bytes per cell) -- the per-half-sweep tier's halo-traffic
#: geometry (``halo_bytes`` accounting; the sharded resident tier
#: carries its own in ``ShardPlan``)
_DIST_CELLS = {
    "basic": (lambda m: m // 2, 1),
    "packed": (lambda m: m // 16, 4),
    "bitplane": (lambda m: m // 2, 4),
}


class _ShardedRunner:
    """A ``MeshSpec`` mesh run: the sharded resident tier
    (``repro.dist``, DESIGN.md S15) when the shard planner fits the
    engine's resident family, else the per-half-sweep
    ``repro.core.distributed`` step named by ``dist_factory``.

    Randomness is global-position-keyed Philox on BOTH tiers, so the
    trajectory is bit-identical to the single-device engine on ANY
    device grid (tests/test_distributed.py, tests/test_dist.py); this
    runner only owns mesh construction, tier routing, sharding
    placement, and offset/halo bookkeeping.
    """

    mode = "sharded"

    def __init__(self, spec: RunSpec, state=None, step_count: int = 0):
        from repro.core import distributed as dist
        from repro.launch.mesh import make_mesh
        self.spec = spec
        self.cfg = spec.sim_config()
        self.engine = make_engine(self.cfg)
        ms = spec.mesh
        if ms.n_devices > jax.device_count():
            raise ValueError(
                f"MeshSpec{ms.shape} needs {ms.n_devices} devices; "
                f"{jax.device_count()} available")
        self.mesh = make_mesh(ms.shape, ms.axis_names)
        self._factory = getattr(dist,
                                _DIST_FACTORIES[self.engine.dist_factory])
        # the basic step takes its start offset in SWEEP units
        # (half_sweep_offset(0, sweep0 + i, c)); packed/bitplane and
        # the sharded resident tier take half-sweep units
        # (half_sweep_offset(sweep0, i, c))
        self._offset_scale = 1 if self.engine.dist_factory == "basic" \
            else 2
        # device grid under the default axis split (rows over all mesh
        # axes but the last, columns over the last)
        self._rows_devs = 1
        for d in ms.shape[:-1]:
            self._rows_devs *= d
        self._cols_devs = ms.shape[-1]
        self._dist_plan = None
        self._dist_attrs = {}
        if getattr(self.engine, "resident_family", None) is not None:
            from repro import dist as rdist
            fam = self.engine.resident_family
            self._dist_plan = rdist.plan_shard_resident(
                fam, self.cfg.n, self.cfg.m, self._rows_devs,
                self._cols_devs)
            self._dist_attrs = rdist.shard_decision_attrs(
                fam, self.cfg.n, self.cfg.m, self._rows_devs,
                self._cols_devs)
        self.step_count = step_count
        self._jit_cache = {}
        self._sharding = None  # set by the first step build
        if state is None:
            state = self.engine.init_state(
                jax.random.PRNGKey(self.cfg.seed))
        step, sh = self._step(1)  # build once: places state on the mesh
        self.state = tuple(jax.device_put(p, sh) for p in state)

    def _step(self, n_sweeps: int):
        got = self._jit_cache.get(n_sweeps)
        if got is None:
            if self._dist_plan is not None:
                from repro import dist as rdist
                got = rdist.make_resident_step(
                    self.mesh, self._dist_plan, seed=self.cfg.seed,
                    n_sweeps=n_sweeps)
            else:
                got = self._factory(self.mesh, n=self.cfg.n,
                                    m=self.cfg.m, seed=self.cfg.seed,
                                    n_sweeps=n_sweeps)
            self._jit_cache[n_sweeps] = got
            self._sharding = got[1]
        return got

    def _on_demote(self) -> None:
        """Resident-tier demotion (``degrade.run_dispatch``): drop to
        the per-half-sweep distributed step -- bit-exact by the shared
        global-position Philox keying -- and refresh the span attrs so
        traces show the fallback and its reason."""
        self._jit_cache.clear()
        if self._dist_plan is not None:
            from repro import dist as rdist
            self._dist_plan = None
            self._dist_attrs = rdist.shard_decision_attrs(
                self.engine.resident_family, self.cfg.n, self.cfg.m,
                self._rows_devs, self._cols_devs)

    def _record_halo(self, n_sweeps: int) -> int:
        """Account this dispatch's halo traffic into the telemetry
        counters; returns the exchange-event count (span attr + the
        S15 one-exchange-per-k-sweeps assertion in tests)."""
        if self._dist_plan is not None:
            ex = self._dist_plan.exchanges(n_sweeps)
            tel.record_halo_exchange(
                ex, ex * self._dist_plan.halo_bytes_per_exchange)
            return ex
        # per-half-sweep tier: one exchange event per half-sweep, four
        # 1-wide strips of the opposite-color plane per event
        width_of, cell = _DIST_CELLS[self.engine.dist_factory]
        n_loc = self.cfg.n // self._rows_devs
        w_loc = width_of(self.cfg.m) // self._cols_devs
        ex = 2 * n_sweeps
        per_event = (2 * n_loc + 2 * w_loc) * cell \
            * self._rows_devs * self._cols_devs
        tel.record_halo_exchange(ex, ex * per_event)
        return ex

    def run(self, n_sweeps: int):
        def attempt():
            fresh = n_sweeps not in self._jit_cache
            scale = 2 if self._dist_plan is not None \
                else self._offset_scale
            step, sh = self._step(n_sweeps)
            with self.engine._dispatch(
                    n_sweeps, compile="first" if fresh else "steady",
                    mesh=list(self.spec.mesh.shape),
                    **self._dist_attrs) as sp:
                state = step(*self.state,
                             jnp.float32(self.cfg.inv_temp),
                             jnp.uint32(scale * self.step_count))
                sp.set(halo_exchanges=self._record_halo(n_sweeps))
                sp.fence(state)
            return state

        self.state = degrade.run_dispatch(attempt, engine=self.engine,
                                          on_demote=self._on_demote)
        self.step_count += n_sweeps
        return None

    def measure(self, plan) -> dict:
        """Per-sample dispatch (no fused scan on the sharded path yet):
        thermalize, then ``n_measure`` (run; observe) rounds."""
        beta = jnp.float32(self.cfg.inv_temp)
        # validate the requested fields BEFORE any device sweeps (the
        # fused single/ensemble paths fail at trace time; match them)
        missing = set(plan.fields) - set(
            self.engine.observables(self.state, beta))
        if missing:
            raise ValueError(
                f"plan fields {sorted(missing)} not in engine "
                f"{self.engine.name!r} observables")
        if plan.thermalize:
            self.run(plan.thermalize)
        samples = []
        for _ in range(plan.n_measure):
            self.run(plan.sweeps_between)
            o = self.engine.observables(self.state, beta)
            samples.append({k: np.asarray(o[k], np.float32)
                            for k in plan.fields})
        return {k: np.stack([s[k] for s in samples])
                for k in plan.fields}

    def magnetization(self) -> float:
        return float(self.engine.magnetization(self.state))

    def energy(self) -> float:
        return float(self.engine.energy(self.state))

    def full_lattice(self):
        return self.engine.full_lattice(self.state)

    def state_arrays(self) -> dict:
        return {k: np.asarray(v) for k, v in
                self.engine.state_arrays(self.state).items()}

    def load_arrays(self, arrays: dict) -> None:
        state = self.engine.from_arrays(arrays)
        self.state = tuple(jax.device_put(p, self._sharding)
                           for p in state)


_RUNNERS = {"single": _SingleRunner, "ensemble": _EnsembleRunner,
            "sharded": _ShardedRunner}


# ---------------------------------------------------------------------------
# dry-run plan
# ---------------------------------------------------------------------------

def describe(spec: RunSpec) -> dict:
    """The validated dispatch plan as one dict -- no device work.

    This is what ``python -m repro run --dry-run`` prints: which runner
    the spec selects, the registry capability flags it was validated
    against, the resident-tier decision for the lattice, and the total
    sweep budget.
    """
    cls = ENGINES[spec.engine.name]
    resident = None
    dist_plan = None
    with tel.span("spec.validate", mode=spec.mode,
                  engine=spec.engine.name,
                  lattice=(spec.lattice.n, spec.lattice.m)):
        if getattr(cls, "resident_family", None) is not None:
            from repro.kernels.resident import decision_attrs
            # the ONE rendering of the planner decision: this dict is
            # the --dry-run output AND the planner.decide/dispatch span
            # attributes (satellite: dry-run and traces cannot disagree)
            resident = decision_attrs(cls.resident_family,
                                      spec.lattice.n, spec.lattice.m)
            tel.instant("planner.decide", **resident)
            if spec.mesh is not None:
                # sharded runs use the SHARD planner (S15): same
                # single-rendering contract as "resident" above
                from repro.dist import shard_decision_attrs
                rows_devs = 1
                for d in spec.mesh.shape[:-1]:
                    rows_devs *= d
                dist_plan = shard_decision_attrs(
                    cls.resident_family, spec.lattice.n,
                    spec.lattice.m, rows_devs, spec.mesh.shape[-1])
                tel.instant("planner.decide_shard", **dist_plan)
    out = {
        "mode": spec.mode,
        "engine": spec.engine.name,
        "engine_params": spec.engine.param_dict,
        "counter_based": cls.counter_based,
        "replicas": cls.replicas,
        "dist_factory": cls.dist_factory,
        "resident": resident,
        "dist": dist_plan,
        "lattice": [spec.lattice.n, spec.lattice.m],
        "init_p_up": spec.lattice.init_p_up,
        "batch_size": 1 if spec.batch is None else spec.batch.size,
        "mesh": None if spec.mesh is None else spec.mesh.to_dict(),
        "total_sweeps": None if spec.sweep is None
        else spec.sweep.total_sweeps,
        "spec": spec.to_dict(),
    }
    if spec.batch is not None:
        out["members"] = [list(p) for p in spec.batch.members]
    return out


# ---------------------------------------------------------------------------
# the façade
# ---------------------------------------------------------------------------

class Session:
    """Open a spec, run it, measure it, checkpoint it -- any mode.

    ``run``/``measure``/``magnetization``/``full_lattice`` return
    single-valued results in single/sharded mode and batch-axis results
    in ensemble mode (``run`` additionally returns the (B,) per-member
    magnetizations there: one fused dispatch yields the m(T) curve).
    """

    def __init__(self, spec: RunSpec, runner=None):
        self.spec = spec
        if runner is not None:
            self._runner = runner
        else:
            with tel.span("session.open", mode=spec.mode,
                          engine=spec.engine.name,
                          lattice=(spec.lattice.n, spec.lattice.m),
                          batch=1 if spec.batch is None
                          else spec.batch.size) as sp:
                self._runner = _RUNNERS[spec.mode](spec)
                sp.fence(self.state)

    @classmethod
    def open(cls, spec: RunSpec) -> "Session":
        return cls(spec)

    # -- delegated state ----------------------------------------------------
    @property
    def mode(self) -> str:
        return self._runner.mode

    @property
    def engine(self):
        return self._runner.engine

    @property
    def state(self):
        """The engine-native state pytree (batch axis leading in
        ensemble mode) -- the public window the examples/tests use
        instead of reaching into runner internals."""
        return self._runner.states if self.mode == "ensemble" \
            else self._runner.state

    @state.setter
    def state(self, v) -> None:
        if self.mode == "ensemble":
            self._runner.states = v
        else:
            self._runner.state = v

    @property
    def step_count(self) -> int:
        return self._runner.step_count

    @step_count.setter
    def step_count(self, v: int) -> None:
        self._runner.step_count = v

    # -- execution ----------------------------------------------------------
    def _flip_rate(self, n_sweeps: int, duration_ns) -> None:
        """Update the rolling flips/ns gauge from a fenced span close
        (only possible when tracing is on: otherwise there is no honest
        device-complete duration to divide by)."""
        if not duration_ns:
            return
        eng = self._runner.engine
        batch = self._runner.size if self.mode == "ensemble" else 1
        flips = n_sweeps * eng.cfg.n * eng.cfg.m * eng.replicas * batch
        tel.REGISTRY.gauge("rolling_flips_per_ns").set(
            flips / duration_ns)

    def run(self, n_sweeps: int):
        """Advance ``n_sweeps`` full lattice sweeps (every member, in
        ensemble mode).  Ensemble mode returns the (B,) per-member
        magnetizations of the fused sweep dispatch."""
        with tel.span("session.run", mode=self.mode,
                      engine=self.spec.engine.name, k=n_sweeps) as sp:
            out = self._runner.run(n_sweeps)
            sp.fence(self.state)
        self._flip_rate(n_sweeps, sp.duration_ns)
        return out

    def measure(self, plan=None) -> dict:
        """Run a measurement plan; defaults to ``spec.sweep``.

        Returns ``{field: (n_measure, ...) float32 ndarray}`` --
        trailing batch axis in ensemble mode, trailing replica axis for
        replicated engines.
        """
        if plan is None:
            if self.spec.sweep is None:
                raise ValueError(
                    "no plan: pass one or set RunSpec.sweep")
            plan = self.spec.sweep.plan()
        with tel.span("session.measure", mode=self.mode,
                      engine=self.spec.engine.name,
                      n_measure=plan.n_measure,
                      sweeps_between=plan.sweeps_between,
                      thermalize=plan.thermalize) as sp:
            traj = self._runner.measure(plan)
            sp.fence(self.state)
        self._flip_rate(plan.total_sweeps, sp.duration_ns)
        return traj

    def trajectory(self, n_measure: int, sweeps_between: int,
                   thermalize: int = 0) -> np.ndarray:
        """Magnetization samples via the fused scan (shape
        ``(n_measure,)``; + batch/replica axes per mode/engine)."""
        from repro.analysis.measure import MeasurementPlan
        plan = MeasurementPlan(n_measure, sweeps_between, thermalize,
                               fields=("m",))
        return self.measure(plan)["m"]

    def magnetization(self):
        return self._runner.magnetization()

    def energy(self):
        return self._runner.energy()

    def full_lattice(self):
        return self._runner.full_lattice()

    def plan(self) -> dict:
        """The dispatch plan of this session's spec (:func:`describe`)."""
        return describe(self.spec)

    # -- fault tolerance ----------------------------------------------------
    def state_digest(self, member: Optional[int] = None) -> str:
        """CRC32C hex digest of (step_count, every named state array):
        two sessions with equal digests hold bit-identical lattices at
        the same point of the trajectory.  The bit-exact-resume tests
        and the CI chaos job compare exactly this string.

        ``member`` (ensemble mode only) digests ONE member's slice of
        the batched state with the same framing a single-mode session
        uses -- by the ensemble bit-exactness contract the result
        equals the digest of the equivalent single run, which is how
        the serve layer proves a coalesced job matches a direct one."""
        from repro.resilience import integrity
        arrays = self._runner.state_arrays()
        if member is not None:
            if self.mode != "ensemble":
                raise ValueError(
                    f"member= digest needs ensemble mode, this session "
                    f"is {self.mode!r}")
            if not 0 <= member < self._runner.size:
                raise ValueError(
                    f"member {member} out of range for batch size "
                    f"{self._runner.size}")
            arrays = {k: np.asarray(v)[member]
                      for k, v in arrays.items()}
        crc = integrity.crc32c(
            f"step_count={self._runner.step_count}".encode())
        for k, v in sorted(arrays.items()):
            a = np.ascontiguousarray(np.asarray(v))
            crc = integrity.crc32c(
                f"{k}:{a.dtype}:{a.shape}:".encode(), crc)
            crc = integrity.crc32c(a.tobytes(), crc)
        return f"{crc:08x}"

    def save(self, path: str, extra: Optional[dict] = None) -> None:
        """Atomic checkpoint: serialized spec + step count + the
        engine's named state arrays (batched in ensemble mode).
        ``extra`` adds scalar/str fields (the legacy shims pass their
        pre-spec metadata through it)."""
        with tel.span("ckpt.save", path=path, mode=self.mode,
                      step_count=self._runner.step_count):
            arrays = {f"state_{k}": v
                      for k, v in self._runner.state_arrays().items()}
            _atomic_savez(path, spec_json=self.spec.to_json(),
                          step_count=self._runner.step_count,
                          **(extra or {}), **arrays)

    @classmethod
    def restore(cls, path: str, mesh=_KEEP) -> "Session":
        """Rebuild a session from a checkpoint alone: the embedded spec
        reconstructs engine + runner, the arrays restore the state, and
        counter-based engines continue the exact Philox stream.

        ``mesh`` overrides the checkpoint's ``MeshSpec`` (pass a
        ``MeshSpec`` to reshard, ``None`` to continue single-device).
        Legal because sharded trajectories are keyed on GLOBAL lattice
        positions (DESIGN.md S15 stream invariance): the device grid
        is an execution detail, not part of the trajectory's identity,
        so a checkpoint saved on one mesh continues bit-exactly on any
        other (tests/test_dist.py cross-mesh portability)."""
        import dataclasses as _dc
        with tel.span("ckpt.restore", path=path) as sp:
            spec, step_count, arrays, _ = _load_checkpoint(path)
            if mesh is not _KEEP and mesh != spec.mesh:
                spec = _dc.replace(spec, mesh=mesh)
            sp.set(mode=spec.mode, engine=spec.engine.name,
                   step_count=step_count)
            return cls._from_arrays(spec, arrays, step_count)

    @classmethod
    def _from_arrays(cls, spec: RunSpec, arrays: dict,
                     step_count: int) -> "Session":
        runner = _RUNNERS[spec.mode](spec, state=_SENTINEL,
                                     step_count=step_count)
        runner.load_arrays(arrays)
        return cls(spec, runner=runner)


#: placeholder state handed to runner __init__ so restore skips the
#: (potentially expensive) fresh init before load_arrays overwrites it
_SENTINEL = ()


def load_spec(path: str) -> RunSpec:
    """Read ONLY the embedded spec of a checkpoint -- the state arrays
    stay on disk (NpzFile decompresses lazily per entry), so a dry-run
    or spec inspection of a huge ensemble checkpoint costs nothing."""
    with np.load(path, allow_pickle=False) as z:
        if "spec_json" in z.files:
            return RunSpec.from_json(str(z["spec_json"]))
        if "config_json" in z.files:
            from repro.core.sim import SimConfig
            return RunSpec.from_sim_config(
                SimConfig(**json.loads(str(z["config_json"]))))
    raise ValueError(
        f"{path}: not a checkpoint in the registry layout (missing "
        "'spec_json'/'config_json'; pre-registry .npz files are not "
        "restorable by this release)")


def _load_checkpoint(path: str):
    """Read a unified checkpoint: (spec, step_count, state arrays,
    legacy config dict or None).  Accepts the PR-4-era single-simulation
    layout (``config_json`` only) by lifting the config into a spec."""
    with np.load(path, allow_pickle=False) as z:
        legacy = None
        if "config_json" in z.files:
            legacy = json.loads(str(z["config_json"]))
        if "spec_json" in z.files:
            spec = RunSpec.from_json(str(z["spec_json"]))
        elif legacy is not None:
            from repro.core.sim import SimConfig
            spec = RunSpec.from_sim_config(SimConfig(**legacy))
        else:
            raise ValueError(
                f"{path}: not a checkpoint in the registry layout "
                "(missing 'spec_json'/'config_json'; pre-registry .npz "
                "files are not restorable by this release)")
        step_count = int(z["step_count"])
        arrays = {k[len("state_"):]: z[k] for k in z.files
                  if k.startswith("state_")}
    return spec, step_count, arrays, legacy
