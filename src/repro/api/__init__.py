"""repro.api: the typed, serializable front door (DESIGN.md S10).

One frozen ``RunSpec`` tree describes any run -- single simulation,
vmapped ensemble, or sharded distributed step -- and one ``Session``
façade executes it.  The same serialized spec is the checkpoint
metadata, the ``RunRecorder`` meta, and the ``python -m repro run``
launch config.

This is the Ising-study API surface; the unrelated seed-era LLM stack
(``repro.configs``, ``repro.models``, ``repro.train``, ``repro.launch``
serve/train) is documented separately in README.md.
"""
from .session import Session, describe
from .spec import (BatchSpec, EngineSpec, LatticeSpec, MeshSpec, RunSpec,
                   SweepSpec)

__all__ = [
    "RunSpec", "LatticeSpec", "EngineSpec", "SweepSpec", "BatchSpec",
    "MeshSpec", "Session", "describe",
]
