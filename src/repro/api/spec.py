"""Typed, serializable run specification: one entry point for every run.

The repo grew three incompatible front doors -- ``SimConfig``/
``Simulation``, ``Ensemble``'s bespoke constructor, and raw
``distributed``/``kernels`` calls -- each re-plumbing temperature, seed,
and measurement plan by hand.  ``RunSpec`` is the single declarative
description (DESIGN.md S10): a frozen dataclass tree that

* validates against the engine registry's capability flags at
  *construction time* (unknown engine, non-counter-based engine in a
  batch, non-distributable engine on a mesh, bad engine params, lattice
  constraints) instead of deep inside a vmap trace;
* round-trips losslessly through ``to_json``/``from_json`` -- the same
  blob is the checkpoint metadata, the ``RunRecorder`` meta, and the
  ``python -m repro run`` launch config, so a run is reproducible from
  one JSON document;
* dispatches execution purely from its own shape:
  ``batch is None and mesh is None`` -> single simulation,
  ``batch`` set -> vmapped ensemble, ``mesh`` set -> sharded step.

The tree is intentionally minimal: ``LatticeSpec`` (geometry + init),
``EngineSpec`` (registry name + engine-specific params), ``SweepSpec``
(thermalize / measure-every / n-measure -> ``MeasurementPlan``),
``BatchSpec`` ((temperature, seed) members, zipped or gridded), and
``MeshSpec`` (device mesh for the distributed step).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, Mapping, Optional, Tuple, Union

SPEC_VERSION = 1

#: validators for the engine-specific params declared by
#: ``Engine.param_fields`` -- each maps a raw JSON value to the
#: normalized python value, raising ValueError on nonsense.
_PARAM_VALIDATORS = {
    "tc_block": lambda v: _positive_int(v, "tc_block"),
    "p_ferro": lambda v: _unit_float(v, "p_ferro"),
}


def _positive_int(v, name: str) -> int:
    if isinstance(v, bool) or not isinstance(v, int) or v <= 0:
        raise ValueError(f"{name} must be a positive int, got {v!r}")
    return v


def _unit_float(v, name: str) -> float:
    if isinstance(v, bool) or not isinstance(v, (int, float)) \
            or not 0.0 <= float(v) <= 1.0:
        raise ValueError(f"{name} must be a float in [0, 1], got {v!r}")
    return float(v)


def _engines():
    from repro.core.engine import ENGINES
    return ENGINES


def _check_keys(d: Mapping, allowed, what: str) -> None:
    """Reject unknown keys in a spec document: a typo'd key must fail
    loudly, not silently run a different run."""
    unknown = sorted(set(d) - set(allowed))
    if unknown:
        raise ValueError(f"{what}: unknown key(s) {unknown}; "
                         f"allowed: {sorted(allowed)}")


def _engine_cls(name: str):
    engines = _engines()
    if name not in engines:
        raise ValueError(f"unknown engine {name!r}; registered engines: "
                         f"{sorted(engines)}")
    return engines[name]


@dataclasses.dataclass(frozen=True)
class LatticeSpec:
    """Lattice geometry and initialization.

    ``init_p_up`` = 0.5 is a hot random start; 1.0 an ordered start
    (steady-state runs below Tc should order-start -- paper S5.3).
    """

    n: int = 512
    m: int = 512
    init_p_up: float = 0.5

    def __post_init__(self):
        if not (isinstance(self.n, int) and isinstance(self.m, int)) \
                or self.n <= 0 or self.m <= 0:
            raise ValueError(f"lattice dims must be positive ints, got "
                             f"({self.n!r}, {self.m!r})")
        if self.n % 2 or self.m % 2:
            raise ValueError(
                f"lattice dims must be even for the checkerboard "
                f"decomposition, got ({self.n}, {self.m})")
        if not 0.0 <= float(self.init_p_up) <= 1.0:
            raise ValueError(f"init_p_up must be in [0, 1], got "
                             f"{self.init_p_up!r}")
        object.__setattr__(self, "init_p_up", float(self.init_p_up))

    def to_dict(self) -> dict:
        return {"n": self.n, "m": self.m, "init_p_up": self.init_p_up}

    @classmethod
    def from_dict(cls, d: Mapping) -> "LatticeSpec":
        _check_keys(d, ("n", "m", "init_p_up"), "lattice spec")
        return cls(**dict(d))


@dataclasses.dataclass(frozen=True)
class EngineSpec:
    """Registry engine name + engine-specific params.

    ``params`` accepts a mapping at construction and is normalized to a
    sorted tuple of (key, value) pairs so the spec stays frozen and
    hashable; keys are validated against the engine class's
    ``param_fields`` declaration at construction time.
    """

    name: str = "multispin"
    params: Union[Mapping[str, Any], Tuple[Tuple[str, Any], ...]] = ()

    def __post_init__(self):
        cls = _engine_cls(self.name)
        raw = dict(self.params)
        unknown = sorted(set(raw) - set(cls.param_fields))
        if unknown:
            raise ValueError(
                f"engine {self.name!r} takes no params {unknown}; "
                f"declared param_fields: {list(cls.param_fields)}")
        norm = {k: _PARAM_VALIDATORS[k](v) if k in _PARAM_VALIDATORS
                else v for k, v in raw.items()}
        object.__setattr__(self, "params",
                           tuple(sorted(norm.items())))

    @property
    def param_dict(self) -> Dict[str, Any]:
        return dict(self.params)

    @property
    def cls(self):
        return _engine_cls(self.name)

    def to_dict(self) -> dict:
        return {"name": self.name, "params": self.param_dict}

    @classmethod
    def from_dict(cls, d: Mapping) -> "EngineSpec":
        _check_keys(d, ("name", "params"), "engine spec")
        return cls(name=d["name"], params=d.get("params", {}))


@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """Measurement schedule: ``thermalize`` equilibration sweeps, then
    ``n_measure`` samples ``measure_every`` sweeps apart, recording
    ``fields`` from the engine ``observables`` hook."""

    thermalize: int = 0
    measure_every: int = 1
    n_measure: int = 100
    fields: Tuple[str, ...] = ("m", "e")

    def __post_init__(self):
        if self.thermalize < 0 or self.measure_every <= 0 \
                or self.n_measure <= 0:
            raise ValueError(f"bad sweep schedule {self}")
        if not self.fields:
            raise ValueError("SweepSpec.fields needs at least one "
                             "observable field")
        object.__setattr__(self, "fields", tuple(self.fields))

    @property
    def total_sweeps(self) -> int:
        return self.thermalize + self.n_measure * self.measure_every

    def plan(self):
        """The fused-scan :class:`repro.analysis.MeasurementPlan`."""
        from repro.analysis.measure import MeasurementPlan
        return MeasurementPlan(self.n_measure, self.measure_every,
                               self.thermalize, self.fields)

    def to_dict(self) -> dict:
        return {"thermalize": self.thermalize,
                "measure_every": self.measure_every,
                "n_measure": self.n_measure,
                "fields": list(self.fields)}

    @classmethod
    def from_dict(cls, d: Mapping) -> "SweepSpec":
        _check_keys(d, ("thermalize", "measure_every", "n_measure",
                        "fields"), "sweep spec")
        d = dict(d)
        d["fields"] = tuple(d.get("fields", ("m", "e")))
        return cls(**d)


#: vmapped ensemble seeds become traced uint32 Philox keys (high lane
#: zero, DESIGN.md S4): a seed >= 2**32 cannot reproduce the 64-bit
#: single-``Simulation`` stream, so BatchSpec rejects it up front.
MAX_BATCH_SEED = 2 ** 32


@dataclasses.dataclass(frozen=True)
class BatchSpec:
    """The (temperature, seed) members of a vmapped ensemble.

    ``grid=False`` (default) zips ``temperatures`` with ``seeds``
    pairwise (seeds default to 0..B-1); ``grid=True`` takes the full
    temperature x seed cross product -- the phase-diagram-scan x
    replica-set grid of the TPU-cluster follow-up paper.
    """

    temperatures: Tuple[float, ...] = ()
    seeds: Optional[Tuple[int, ...]] = None
    grid: bool = False

    def __post_init__(self):
        temps = tuple(float(t) for t in self.temperatures)
        if not temps:
            raise ValueError("BatchSpec needs at least one temperature")
        if any(t <= 0 for t in temps):
            raise ValueError(f"temperatures must be positive: {temps}")
        object.__setattr__(self, "temperatures", temps)
        seeds = self.seeds
        if seeds is not None:
            seeds = tuple(int(s) for s in seeds)
            bad = [s for s in seeds if not 0 <= s < MAX_BATCH_SEED]
            if bad:
                raise ValueError(
                    f"ensemble seeds must be in [0, 2**32) -- the "
                    f"vmapped Philox key is a traced uint32 lane, so "
                    f"larger seeds cannot match the 64-bit "
                    f"single-simulation stream (DESIGN.md S4); got "
                    f"{bad}")
            if not self.grid and len(seeds) != len(temps):
                raise ValueError(
                    f"zipped batch needs len(seeds) == "
                    f"len(temperatures); got {len(seeds)} vs "
                    f"{len(temps)} (use grid=True for a cross product)")
            if self.grid and not seeds:
                raise ValueError("grid batch needs at least one seed")
        object.__setattr__(self, "seeds", seeds)

    @property
    def members(self) -> Tuple[Tuple[float, int], ...]:
        """Expanded (temperature, seed) pairs, batch-axis order."""
        if self.grid:
            seeds = self.seeds or (0,)
            return tuple((t, s) for t in self.temperatures for s in seeds)
        seeds = self.seeds if self.seeds is not None \
            else tuple(range(len(self.temperatures)))
        return tuple(zip(self.temperatures, seeds))

    @property
    def size(self) -> int:
        return len(self.members)

    @property
    def member_temperatures(self) -> Tuple[float, ...]:
        return tuple(t for t, _ in self.members)

    @property
    def member_seeds(self) -> Tuple[int, ...]:
        return tuple(s for _, s in self.members)

    def to_dict(self) -> dict:
        return {"temperatures": list(self.temperatures),
                "seeds": None if self.seeds is None else list(self.seeds),
                "grid": self.grid}

    @classmethod
    def from_dict(cls, d: Mapping) -> "BatchSpec":
        _check_keys(d, ("temperatures", "seeds", "grid"), "batch spec")
        return cls(temperatures=tuple(d["temperatures"]),
                   seeds=None if d.get("seeds") is None
                   else tuple(d["seeds"]),
                   grid=bool(d.get("grid", False)))


@dataclasses.dataclass(frozen=True)
class MeshSpec:
    """Device mesh for the sharded (``repro.core.distributed``) step.

    The pencil decomposition shards plane rows over every axis but the
    last and plane columns over the last axis, so a mesh needs at least
    two axes (use a trailing size-1 axis for pure slab sharding).
    """

    shape: Tuple[int, ...] = (1, 1)
    axis_names: Tuple[str, ...] = ("data", "model")

    def __post_init__(self):
        shape = tuple(int(d) for d in self.shape)
        names = tuple(str(a) for a in self.axis_names)
        if len(shape) < 2 or any(d <= 0 for d in shape):
            raise ValueError(
                f"mesh shape needs >= 2 positive dims (rows ring + "
                f"columns ring; use a trailing 1 for slab sharding), "
                f"got {shape}")
        if len(names) != len(shape):
            raise ValueError(f"mesh needs one axis name per dim: "
                             f"{shape} vs {names}")
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate mesh axis names: {names}")
        object.__setattr__(self, "shape", shape)
        object.__setattr__(self, "axis_names", names)

    @property
    def n_devices(self) -> int:
        out = 1
        for d in self.shape:
            out *= d
        return out

    def to_dict(self) -> dict:
        return {"shape": list(self.shape),
                "axis_names": list(self.axis_names)}

    @classmethod
    def from_dict(cls, d: Mapping) -> "MeshSpec":
        _check_keys(d, ("shape", "axis_names"), "mesh spec")
        return cls(shape=tuple(d["shape"]),
                   axis_names=tuple(d["axis_names"]))


@dataclasses.dataclass(frozen=True)
class RunSpec:
    """The complete, serializable description of one run.

    Dispatch is a pure function of the tree shape (DESIGN.md S10):

    ========  ========  =====================================
    batch     mesh      execution
    ========  ========  =====================================
    None      None      single ``Simulation``-equivalent run
    set       None      one vmapped ensemble over the members
    None      set       sharded ``distributed`` step
    ========  ========  =====================================

    ``temperature``/``seed`` drive single and sharded runs; an ensemble
    takes its members from ``batch`` instead (the scalar fields then
    describe member 0, which is also what the internal engine config
    carries).
    """

    lattice: LatticeSpec = dataclasses.field(default_factory=LatticeSpec)
    engine: EngineSpec = dataclasses.field(default_factory=EngineSpec)
    temperature: float = 2.0
    seed: int = 1234
    sweep: Optional[SweepSpec] = None
    batch: Optional[BatchSpec] = None
    mesh: Optional[MeshSpec] = None

    def __post_init__(self):
        cls = self.engine.cls
        if float(self.temperature) <= 0:
            raise ValueError(f"temperature must be positive, got "
                             f"{self.temperature!r}")
        object.__setattr__(self, "temperature", float(self.temperature))
        if not 0 <= int(self.seed) < 2 ** 64:
            raise ValueError(f"seed must be a uint64, got {self.seed!r}")
        object.__setattr__(self, "seed", int(self.seed))
        if self.batch is not None and self.mesh is not None:
            raise ValueError(
                "batch + mesh in one RunSpec is not supported yet: "
                "run the ensemble per mesh shard or drop one of them")
        if self.batch is not None and not cls.counter_based:
            raise ValueError(
                f"engine {self.engine.name!r} is not counter-based; a "
                f"batched ensemble needs a Philox engine whose sweep_fn "
                f"is a pure function of (seed, offset) -- see DESIGN.md "
                f"S3/S4")
        if self.mesh is not None and cls.dist_factory is None:
            have = sorted(n for n, c in _engines().items()
                          if c.dist_factory is not None)
            raise ValueError(
                f"engine {self.engine.name!r} has no distributed step "
                f"(dist_factory is None); mesh-capable engines: {have}")
        cls.validate_lattice(self.lattice.n, self.lattice.m)

    # -- derived views ------------------------------------------------------
    @property
    def mode(self) -> str:
        if self.batch is not None:
            return "ensemble"
        if self.mesh is not None:
            return "sharded"
        return "single"

    def sim_config(self):
        """The equivalent :class:`repro.core.sim.SimConfig` (engine
        construction config; for ensembles: member 0's scalars)."""
        from repro.core.sim import SimConfig
        temp, seed = self.temperature, self.seed
        if self.batch is not None:
            temp, seed = self.batch.members[0]
        return SimConfig(n=self.lattice.n, m=self.lattice.m,
                         temperature=temp, seed=seed,
                         engine=self.engine.name,
                         init_p_up=self.lattice.init_p_up,
                         **self.engine.param_dict)

    @classmethod
    def from_sim_config(cls, cfg, sweep: Optional[SweepSpec] = None,
                        batch: Optional[BatchSpec] = None,
                        mesh: Optional[MeshSpec] = None) -> "RunSpec":
        """Lift a legacy ``SimConfig`` into a spec.  Only the params the
        engine declares (``param_fields``) are carried; the other legacy
        config knobs are engine-irrelevant defaults."""
        fields = _engine_cls(cfg.engine).param_fields
        params = {k: getattr(cfg, k) for k in fields}
        return cls(lattice=LatticeSpec(n=cfg.n, m=cfg.m,
                                       init_p_up=cfg.init_p_up),
                   engine=EngineSpec(name=cfg.engine, params=params),
                   temperature=cfg.temperature, seed=cfg.seed,
                   sweep=sweep, batch=batch, mesh=mesh)

    # -- serialization ------------------------------------------------------
    def to_dict(self) -> dict:
        return {
            "version": SPEC_VERSION,
            "lattice": self.lattice.to_dict(),
            "engine": self.engine.to_dict(),
            "temperature": self.temperature,
            "seed": self.seed,
            "sweep": None if self.sweep is None else self.sweep.to_dict(),
            "batch": None if self.batch is None else self.batch.to_dict(),
            "mesh": None if self.mesh is None else self.mesh.to_dict(),
        }

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    @classmethod
    def from_dict(cls, d: Mapping) -> "RunSpec":
        _check_keys(d, ("version", "lattice", "engine", "temperature",
                        "seed", "sweep", "batch", "mesh"), "run spec")
        version = d.get("version", SPEC_VERSION)
        if version > SPEC_VERSION:
            raise ValueError(f"spec version {version} is newer than this "
                             f"release understands ({SPEC_VERSION})")
        return cls(
            lattice=LatticeSpec.from_dict(d.get("lattice", {})),
            engine=EngineSpec.from_dict(d["engine"])
            if "engine" in d else EngineSpec(),
            temperature=d.get("temperature", 2.0),
            seed=d.get("seed", 1234),
            sweep=None if d.get("sweep") is None
            else SweepSpec.from_dict(d["sweep"]),
            batch=None if d.get("batch") is None
            else BatchSpec.from_dict(d["batch"]),
            mesh=None if d.get("mesh") is None
            else MeshSpec.from_dict(d["mesh"]),
        )

    @classmethod
    def from_json(cls, s: str) -> "RunSpec":
        return cls.from_dict(json.loads(s))
