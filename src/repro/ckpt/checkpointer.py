"""Fault-tolerant checkpointing: atomic, async, verified, reshardable.

Layout: ``<dir>/step_<N>/arrays.npz`` + ``manifest.json`` + ``DONE``
marker (the marker commits the checkpoint -- a killed writer never
leaves a readable-but-partial step).  The manifest
(``repro.resilience.integrity``) carries per-file and per-array CRC32C
records written *before* DONE, so the atomic-rename commit covers it:
restore verifies the bytes it reads, quarantines corrupt/truncated
steps (``quarantine_step_<N>`` rename + ``ckpt.quarantine`` counter),
and falls back to the newest step that validates (DESIGN.md S13).

``save_async`` snapshots to host then writes on a worker thread so the
sweep loop is not blocked; a worker failure is stored and re-raised on
the next ``save``/``save_async``/``wait``/``close`` call instead of
dying silently on a daemon thread.  Restore returns host numpy trees;
the caller ``device_put``s with the *current* mesh's shardings, which
is what makes restarts elastic: a checkpoint written on 256 chips
restores onto 512 or 64 unchanged.

Every load-path guard raises a typed :class:`CheckpointError` (or the
:class:`CheckpointIntegrityError` subclass) naming the offending
step/key/shape -- a bare ``assert`` vanishes under ``python -O`` and
would let a corrupt restore proceed.
"""
from __future__ import annotations

import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

import repro.telemetry as tel
from repro.resilience import integrity

#: steps renamed out of the way by :meth:`Checkpointer.quarantine`
QUARANTINE_PREFIX = "quarantine_"

#: module-held reference survives REGISTRY.reset()
QUARANTINES = tel.REGISTRY.counter("ckpt.quarantine")


class CheckpointError(RuntimeError):
    """A checkpoint cannot be saved/restored (missing step, shape
    mismatch against the restore template, no valid step left)."""


class CheckpointIntegrityError(CheckpointError):
    """A checkpoint step exists but its bytes fail verification; the
    message carries the per-file/per-array problem list."""

    def __init__(self, step_dir: str, problems: List[str]):
        self.step_dir = step_dir
        self.problems = list(problems)
        lines = "".join(f"\n  - {p}" for p in problems)
        super().__init__(f"checkpoint {step_dir} failed "
                         f"verification:{lines}")


def _flatten(tree) -> dict:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        out[key] = np.asarray(leaf)
    return out


def _unflatten_into(tree, arrays: dict):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    leaves = []
    for path, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        if key not in arrays:
            raise CheckpointError(
                f"checkpoint is missing array {key!r} required by the "
                f"restore template (has: {sorted(arrays)})")
        a = arrays[key]
        if a.shape != tuple(leaf.shape):
            raise CheckpointError(
                f"checkpoint array {key!r} has shape {tuple(a.shape)}, "
                f"restore template expects {tuple(leaf.shape)}")
        leaves.append(a)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._worker: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- write --------------------------------------------------------------
    def save(self, step: int, tree, spec_json: Optional[str] = None) -> str:
        """``spec_json`` (a serialized ``repro.api.RunSpec``) is written
        as a ``spec.json`` sidecar inside the step dir, committed by the
        same DONE marker -- the unified run-provenance blob
        (DESIGN.md S10); read it back with :meth:`read_spec`."""
        self._raise_pending()
        host = _flatten(tree)
        return self._write(step, host, spec_json)

    def save_async(self, step: int, tree,
                   spec_json: Optional[str] = None) -> None:
        """Snapshot to host now; write on a background thread.  A
        failure on the worker is re-raised by the NEXT call into this
        checkpointer (store-and-rethrow), never swallowed."""
        self._raise_pending()
        host = _flatten(tree)  # device->host copy happens here
        self._join()
        self._raise_pending()
        self._worker = threading.Thread(target=self._write_guarded,
                                        args=(step, host, spec_json),
                                        daemon=True)
        self._worker.start()

    def wait(self) -> None:
        """Block until the in-flight async write (if any) finishes;
        re-raise its failure here if it died."""
        self._join()
        self._raise_pending()

    def close(self) -> None:
        """Flush and surface any pending async-writer failure."""
        self.wait()

    def _join(self):
        if self._worker is not None:
            self._worker.join()
            self._worker = None

    def _raise_pending(self):
        if self._error is not None:
            exc, self._error = self._error, None
            raise CheckpointError(
                f"async checkpoint write failed: "
                f"{type(exc).__name__}: {exc}") from exc

    def _write_guarded(self, step, host, spec_json):
        try:
            self._write(step, host, spec_json)
        except BaseException as exc:  # surfaced on the next call
            self._error = exc

    def _write(self, step: int, host: dict,
               spec_json: Optional[str] = None) -> str:
        # runs on the save_async worker thread: the span lands on its
        # own tid in the trace, visualizing the I/O-compute overlap
        with tel.span("ckpt.write", step=step, dir=self.dir,
                      n_arrays=len(host)):
            path = self._step_dir(step)
            tmp = path + ".tmp"
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            np.savez(os.path.join(tmp, integrity.ARRAYS_NAME), **host)
            if spec_json is not None:
                with open(os.path.join(tmp, integrity.SPEC_NAME),
                          "w") as f:
                    f.write(spec_json)
            # manifest before DONE: the marker commits payload AND sums
            integrity.write_manifest(
                tmp, integrity.build_manifest(step, host, tmp))
            with open(os.path.join(tmp, integrity.DONE_NAME), "w") as f:
                f.write(str(step))
            if os.path.exists(path):
                shutil.rmtree(path)
            os.replace(tmp, path)
            self._gc()
        return path

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # -- discovery / validation ---------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:010d}")

    def all_steps(self):
        """Committed steps (DONE marker present), oldest first; no
        byte-level validation -- see :meth:`valid_steps`."""
        out = []
        for d in sorted(os.listdir(self.dir)):
            if d.startswith("step_") and os.path.exists(
                    os.path.join(self.dir, d, integrity.DONE_NAME)):
                out.append(int(d.split("_")[1]))
        return out

    def validate_step(self, step: int) -> List[str]:
        """File-level problems of one step (empty list = valid)."""
        return integrity.validate_step_dir(self._step_dir(step),
                                           expect_step=step)

    def valid_steps(self):
        """Steps whose bytes verify, oldest first.  Walks every
        committed step; prefer :meth:`latest_step` (newest-first early
        exit) when only the restore candidate matters."""
        return [s for s in self.all_steps() if not self.validate_step(s)]

    def latest_step(self, validate: bool = True) -> Optional[int]:
        """Newest restorable step, or ``None``.

        With ``validate`` (the default) candidates are checked newest
        first and invalid ones -- torn writes, truncation, stale DONE,
        bit rot, steps pruned mid-walk -- are skipped, so discovery
        lands on the newest step that will actually restore.
        """
        steps = self.all_steps()
        if not validate:
            return steps[-1] if steps else None
        for s in reversed(steps):
            if not self.validate_step(s):
                return s
        return None

    def quarantine(self, step: int, problems: List[str]) -> Optional[str]:
        """Move a corrupt step out of the discovery namespace
        (``step_N`` -> ``quarantine_step_N``) so it is never considered
        again, keeping the bytes for post-mortem.  Returns the new path
        (``None`` when the step vanished first -- a GC prune race)."""
        src = self._step_dir(step)
        dst = os.path.join(self.dir,
                           QUARANTINE_PREFIX + os.path.basename(src))
        try:
            if os.path.exists(dst):
                shutil.rmtree(dst)
            os.replace(src, dst)
        except (FileNotFoundError, NotADirectoryError):
            return None
        QUARANTINES.inc()
        tel.instant("ckpt.quarantine", step=step, dir=self.dir,
                    problems=problems)
        return dst

    # -- read ---------------------------------------------------------------
    def read_spec(self, step: Optional[int] = None) -> Optional[str]:
        """The ``spec.json`` sidecar of ``step`` (default: newest valid),
        or ``None`` when the checkpoint was written without one."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise CheckpointError(
                f"no valid checkpoint found in {self.dir}")
        path = os.path.join(self._step_dir(step), integrity.SPEC_NAME)
        if not os.path.exists(path):
            return None
        with open(path) as f:
            return f.read()

    def load_arrays(self, step: Optional[int] = None,
                    quarantine: bool = True
                    ) -> Tuple[int, Dict[str, np.ndarray]]:
        """Load and VERIFY one step's arrays; ``(step, {key: array})``.

        With ``step=None`` the newest valid step is restored; corrupt
        candidates found on the way are quarantined (when ``quarantine``)
        and the walk falls back to the previous good one.  An explicit
        ``step`` that fails verification raises
        :class:`CheckpointIntegrityError` -- the caller asked for those
        exact bytes, silently substituting others would be worse.
        """
        explicit = step is not None
        candidates = [step] if explicit else \
            list(reversed(self.all_steps()))
        if not candidates:
            raise CheckpointError(f"no checkpoint found in {self.dir}")
        for s in candidates:
            step_dir = self._step_dir(s)
            problems = integrity.validate_step_dir(step_dir,
                                                   expect_step=s)
            if not problems:
                try:
                    with np.load(os.path.join(step_dir,
                                              integrity.ARRAYS_NAME),
                                 allow_pickle=False) as z:
                        arrays = {k: z[k] for k in z.files}
                    problems = integrity.verify_arrays(
                        arrays, integrity.load_manifest(step_dir))
                except (FileNotFoundError, NotADirectoryError) as e:
                    problems = [f"step vanished during load: {e}"]
                except Exception as e:
                    problems = [f"arrays fail to load: "
                                f"{type(e).__name__}: {e}"]
            if not problems:
                return s, arrays
            if explicit:
                raise CheckpointIntegrityError(step_dir, problems)
            if quarantine:
                self.quarantine(s, problems)
        raise CheckpointError(
            f"no valid checkpoint left in {self.dir}: every committed "
            f"step failed verification")

    def restore(self, template, step: Optional[int] = None,
                shardings=None) -> Tuple[int, Any]:
        """Restore into the structure of ``template``; if ``shardings``
        is given, device_put each leaf with it (elastic reshard).
        Verifies bytes against the step's manifest and falls back to
        the newest valid step (see :meth:`load_arrays`)."""
        step, arrays = self.load_arrays(step)
        tree = _unflatten_into(template, arrays)
        if shardings is not None:
            tree = jax.tree.map(
                lambda a, s: jax.device_put(a, s), tree, shardings)
        return step, tree
