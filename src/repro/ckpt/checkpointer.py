"""Fault-tolerant checkpointing: atomic, async, elastic-reshardable.

Layout: ``<dir>/step_<N>/arrays.npz`` + ``DONE`` marker (the marker commits
the checkpoint -- a killed writer never leaves a readable-but-partial
step).  ``save_async`` snapshots to host then writes on a worker thread so
the training loop is not blocked (overlap of I/O with compute).  Restore
returns host numpy trees; the caller ``device_put``s with the *current*
mesh's shardings, which is what makes restarts elastic: a checkpoint
written on 256 chips restores onto 512 or 64 unchanged.
"""
from __future__ import annotations

import os
import queue
import shutil
import threading
from typing import Any, Optional, Tuple

import jax
import numpy as np

import repro.telemetry as tel


def _flatten(tree) -> dict:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        out[key] = np.asarray(leaf)
    return out


def _unflatten_into(tree, arrays: dict):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    leaves = []
    for path, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        a = arrays[key]
        assert a.shape == tuple(leaf.shape), (key, a.shape, leaf.shape)
        leaves.append(a)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._q: "queue.Queue" = queue.Queue()
        self._worker: Optional[threading.Thread] = None

    # -- write --------------------------------------------------------------
    def save(self, step: int, tree, spec_json: Optional[str] = None) -> str:
        """``spec_json`` (a serialized ``repro.api.RunSpec``) is written
        as a ``spec.json`` sidecar inside the step dir, committed by the
        same DONE marker -- the unified run-provenance blob
        (DESIGN.md S10); read it back with :meth:`read_spec`."""
        host = _flatten(tree)
        return self._write(step, host, spec_json)

    def save_async(self, step: int, tree,
                   spec_json: Optional[str] = None) -> None:
        """Snapshot to host now; write on a background thread."""
        host = _flatten(tree)  # device->host copy happens here
        self._join()
        self._worker = threading.Thread(target=self._write,
                                        args=(step, host, spec_json),
                                        daemon=True)
        self._worker.start()

    def wait(self) -> None:
        self._join()

    def _join(self):
        if self._worker is not None:
            self._worker.join()
            self._worker = None

    def _write(self, step: int, host: dict,
               spec_json: Optional[str] = None) -> str:
        # runs on the save_async worker thread: the span lands on its
        # own tid in the trace, visualizing the I/O-compute overlap
        with tel.span("ckpt.write", step=step, dir=self.dir,
                      n_arrays=len(host)):
            path = os.path.join(self.dir, f"step_{step:010d}")
            tmp = path + ".tmp"
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            np.savez(os.path.join(tmp, "arrays.npz"), **host)
            if spec_json is not None:
                with open(os.path.join(tmp, "spec.json"), "w") as f:
                    f.write(spec_json)
            with open(os.path.join(tmp, "DONE"), "w") as f:
                f.write(str(step))
            if os.path.exists(path):
                shutil.rmtree(path)
            os.replace(tmp, path)
            self._gc()
        return path

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)

    # -- read ---------------------------------------------------------------
    def all_steps(self):
        out = []
        for d in sorted(os.listdir(self.dir)):
            if d.startswith("step_") and os.path.exists(
                    os.path.join(self.dir, d, "DONE")):
                out.append(int(d.split("_")[1]))
        return out

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def read_spec(self, step: Optional[int] = None) -> Optional[str]:
        """The ``spec.json`` sidecar of ``step`` (default: latest), or
        ``None`` when the checkpoint was written without one."""
        if step is None:
            step = self.latest_step()
        assert step is not None, "no checkpoint found"
        path = os.path.join(self.dir, f"step_{step:010d}", "spec.json")
        if not os.path.exists(path):
            return None
        with open(path) as f:
            return f.read()

    def restore(self, template, step: Optional[int] = None,
                shardings=None) -> Tuple[int, Any]:
        """Restore into the structure of ``template``; if ``shardings`` is
        given, device_put each leaf with it (elastic reshard)."""
        if step is None:
            step = self.latest_step()
        assert step is not None, "no checkpoint found"
        path = os.path.join(self.dir, f"step_{step:010d}", "arrays.npz")
        with np.load(path, allow_pickle=False) as z:
            arrays = {k: z[k] for k in z.files}
        tree = _unflatten_into(template, arrays)
        if shardings is not None:
            tree = jax.tree.map(
                lambda a, s: jax.device_put(a, s), tree, shardings)
        return step, tree
