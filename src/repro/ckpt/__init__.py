from .checkpointer import (Checkpointer, CheckpointError,  # noqa: F401
                           CheckpointIntegrityError)
