"""``repro.resilience`` -- fault tolerance for long unattended runs.

Four pieces (DESIGN.md S13), built for the record-scale follow-ups
(rack-scale multi-day runs, arXiv 2502.18624) where preemption, OOM,
and partial checkpoint writes are routine:

* :mod:`~repro.resilience.integrity` -- CRC32C checkpoint manifests
  and verify-on-restore;
* :mod:`~repro.resilience.faults` -- deterministic fault injection
  (crash topologies on disk, transient/OOM dispatch failures);
* :mod:`~repro.resilience.degrade` -- bounded retry/backoff and
  resident-tier demotion around every compiled-call launch;
* :class:`Supervisor` -- the run supervisor behind
  ``python -m repro run --supervise``: periodic checkpoints,
  SIGTERM/SIGINT-safe preemption, resume-from-newest-valid-step with
  a bit-exact-resume contract.

``Supervisor`` is loaded lazily (PEP 562): it imports
``repro.api.session`` which imports ``repro.core.engine``, and the
engine layer imports this package for the degrade path -- eager
loading would cycle.
"""
from __future__ import annotations

from . import degrade, faults, integrity
from .errors import (FaultPlanError, ResilienceError,
                     SimulatedResourceExhausted, SupervisorError,
                     TransientDispatchError)

__all__ = [
    "degrade", "faults", "integrity",
    "ResilienceError", "TransientDispatchError",
    "SimulatedResourceExhausted", "SupervisorError", "FaultPlanError",
    "Supervisor", "SupervisorResult",
]


def __getattr__(name: str):
    if name in ("Supervisor", "SupervisorResult"):
        from .supervisor import Supervisor, SupervisorResult
        return {"Supervisor": Supervisor,
                "SupervisorResult": SupervisorResult}[name]
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
