"""Run supervisor: periodic checkpoints, preemption, auto-resume.

The supervisor owns a :class:`~repro.api.session.Session` and advances
it toward a sweep target on a **fixed chunk grid**, checkpointing into
a :class:`~repro.ckpt.Checkpointer` directory (DESIGN.md S13):

* **resume** -- on start, discover the newest *valid* step (torn,
  truncated, and bit-rotted steps are skipped by the integrity layer),
  verify the stored spec matches the requested one, and rebuild the
  session from it (``resilience.resume`` counter + trace instant);
* **cadence** -- after each chunk, write a checkpoint when
  ``every_sweeps`` sweeps or ``every_seconds`` wall-clock have passed
  since the last one (both zero = no periodic checkpoints and ZERO
  hot-path overhead: the loop is ``session.run`` plus two integer
  compares);
* **preemption** -- SIGTERM/SIGINT set a flag (installed only on the
  main thread; signal-handler-safe: no I/O in the handler); the loop
  notices at the next chunk boundary, writes a final checkpoint, and
  returns ``status="preempted"`` instead of dying mid-write.

Bit-exact-resume contract: an interrupted-and-resumed supervised run
produces bit-identical state to an uninterrupted one *of the same
supervisor config*.  Counter-based engines (Philox streams addressed
by ``core.rng.half_sweep_offset``) are chunk-size-invariant outright;
key-based engines (basic/tensorcore/wolff/spinglass) fold the
cumulative step count once per ``sweeps`` call, so their stream
depends on the chunk boundaries -- the fixed grid
(``n = min(chunk, total - step_count)``, checkpoints only at chunk
boundaries) makes those boundaries identical whether or not the run
was interrupted, which is what the mode-matrix resume tests assert.
"""
from __future__ import annotations

import dataclasses
import signal
import threading
import time
from typing import Callable, List, Optional

import repro.telemetry as tel
from repro.ckpt import Checkpointer

from .errors import SupervisorError

#: module-held reference survives REGISTRY.reset()
RESUMES = tel.REGISTRY.counter("resilience.resume")

#: default sweep-chunk between supervisor control points
DEFAULT_CHUNK = 64


@dataclasses.dataclass
class SupervisorResult:
    """What one :meth:`Supervisor.run` call did."""

    status: str                      # "completed" | "preempted"
    step_count: int                  # sweeps advanced so far (total)
    digest: str                      # Session.state_digest() at return
    resumed_from: Optional[int]      # checkpoint step, None = fresh
    checkpoints_written: List[int]   # steps written THIS call
    stop_signal: Optional[int] = None

    @property
    def completed(self) -> bool:
        return self.status == "completed"


class Supervisor:
    """Drive a session to a sweep target with checkpoints and
    preemption safety; see the module docstring for the contract.

    ``on_chunk(supervisor)`` runs after every advanced chunk (before
    the cadence check) -- the deterministic interruption hook the
    resume tests use (call :meth:`request_stop`, raise a signal, ...).
    """

    def __init__(self, spec, directory: str, *,
                 every_sweeps: int = 0, every_seconds: float = 0.0,
                 chunk: int = DEFAULT_CHUNK, keep: int = 3,
                 install_signal_handlers: bool = True,
                 on_chunk: Optional[Callable[["Supervisor"], None]]
                 = None, session=None):
        """``session`` (optional) is an already-open
        :class:`~repro.api.session.Session` for ``spec`` used ONLY when
        no valid checkpoint exists in ``directory`` -- the serve layer
        passes a cache-rebound runner here so a warm batch skips both
        device init and recompilation.  A resumable checkpoint always
        wins (crash recovery must restore the persisted trajectory, not
        a fresh injected state); the injected session must be at step 0
        of the SAME spec."""
        if chunk <= 0:
            raise SupervisorError(f"chunk must be positive, got {chunk}")
        if every_sweeps < 0 or every_seconds < 0:
            raise SupervisorError(
                f"checkpoint cadence must be >= 0, got "
                f"every_sweeps={every_sweeps} "
                f"every_seconds={every_seconds}")
        if session is not None:
            if spec is None:
                raise SupervisorError(
                    "session= injection needs the matching spec too")
            if session.spec.to_dict() != spec.to_dict():
                raise SupervisorError(
                    f"injected session's spec does not match the "
                    f"supervised spec ({session.spec.to_dict()} != "
                    f"{spec.to_dict()})")
            if session.step_count != 0:
                raise SupervisorError(
                    f"injected session must be at step 0, is at "
                    f"{session.step_count}")
        self.ckpt = Checkpointer(directory, keep=keep)
        self.chunk = chunk
        self.every_sweeps = every_sweeps
        self.every_seconds = every_seconds
        self.install_signal_handlers = install_signal_handlers
        self.on_chunk = on_chunk
        self._injected = session
        self._stop = threading.Event()
        self._stop_signal: Optional[int] = None
        self.resumed_from: Optional[int] = None
        self.session = self._open(spec)

    @staticmethod
    def _spec_key(spec) -> dict:
        """The spec fields that define the TRAJECTORY: everything but
        the mesh.  Two specs equal under this key produce bit-identical
        state streams (sharded runs are global-position-keyed), so a
        supervised run may resume on a different device grid."""
        d = spec.to_dict()
        d.pop("mesh", None)
        return d

    # -- resume -------------------------------------------------------------
    def _open(self, spec):
        from repro.api.session import Session
        step = self.ckpt.latest_step()  # newest VALID step only
        if step is None:
            if self._injected is not None:
                return self._injected
            if spec is None:
                raise SupervisorError(
                    f"no spec given and no valid checkpoint to resume "
                    f"in {self.ckpt.dir}")
            return Session.open(spec)
        from repro.api.spec import RunSpec
        stored_json = self.ckpt.read_spec(step)
        if stored_json is None:
            raise SupervisorError(
                f"checkpoint step {step} in {self.ckpt.dir} has no "
                f"spec.json sidecar; cannot verify it matches this run")
        stored = RunSpec.from_json(stored_json)
        resume_spec = stored
        if spec is not None and stored.to_dict() != spec.to_dict():
            if self._spec_key(stored) != self._spec_key(spec):
                raise SupervisorError(
                    f"checkpoint step {step} in {self.ckpt.dir} was "
                    f"written by a different spec; refusing to resume "
                    f"a different run (stored {stored.to_dict()} != "
                    f"requested {spec.to_dict()})")
            # mesh-only difference: the device grid is an execution
            # detail, not part of the trajectory's identity (global-
            # position Philox keying, DESIGN.md S15) -- resume the
            # stored trajectory on the REQUESTED mesh (cross-mesh
            # checkpoint portability, tests/test_dist.py)
            resume_spec = spec
        # load_arrays re-validates and falls back if the step rotted
        # between discovery and here
        step, arrays = self.ckpt.load_arrays(step)
        RESUMES.inc()
        tel.instant("resilience.resume", step=step, dir=self.ckpt.dir)
        self.resumed_from = step
        return Session._from_arrays(resume_spec, arrays, step)

    # -- preemption ---------------------------------------------------------
    def request_stop(self, signum: Optional[int] = None) -> None:
        """Ask the run loop to checkpoint and return at the next chunk
        boundary (what the signal handlers call; also the test hook)."""
        self._stop_signal = signum
        self._stop.set()

    def _handler(self, signum, frame):
        self.request_stop(signum)

    def _install_handlers(self):
        if not self.install_signal_handlers:
            return {}
        if threading.current_thread() is not threading.main_thread():
            return {}  # signal.signal raises off the main thread
        prev = {}
        for sig in (signal.SIGTERM, signal.SIGINT):
            prev[sig] = signal.signal(sig, self._handler)
        return prev

    @staticmethod
    def _restore_handlers(prev):
        for sig, h in prev.items():
            signal.signal(sig, h)

    # -- checkpointing ------------------------------------------------------
    def checkpoint(self) -> int:
        """Write the session's state as a verified step NOW (manifest
        committed under DONE); returns the step number."""
        s = self.session
        step = s.step_count
        with tel.span("supervisor.checkpoint", step=step):
            self.ckpt.save(step, s._runner.state_arrays(),
                           spec_json=s.spec.to_json())
        return step

    # -- the run loop -------------------------------------------------------
    def run(self, total_sweeps: int) -> SupervisorResult:
        """Advance the session to ``total_sweeps`` (absolute, counted
        from step 0 -- a resumed session has less left to do), writing
        cadence checkpoints, and return how it went.  A requested stop
        (signal or :meth:`request_stop`) checkpoints and returns
        ``status="preempted"`` instead of raising."""
        s = self.session
        if s.step_count > total_sweeps:
            raise SupervisorError(
                f"checkpoint is at sweep {s.step_count}, past the "
                f"requested total {total_sweeps}")
        written: List[int] = []
        prev_handlers = self._install_handlers()
        last_ckpt_step = s.step_count
        last_ckpt_time = time.monotonic()
        preempted = False
        try:
            with tel.span("supervisor.run", total=total_sweeps,
                          start=s.step_count,
                          resumed_from=self.resumed_from,
                          chunk=self.chunk):
                while s.step_count < total_sweeps:
                    if self._stop.is_set():
                        preempted = True
                        break
                    # FIXED chunk grid: boundaries depend only on the
                    # config, never on where a past run was interrupted
                    n = min(self.chunk, total_sweeps - s.step_count)
                    s.run(n)
                    if self.on_chunk is not None:
                        self.on_chunk(self)
                    if self._cadence_due(s.step_count, last_ckpt_step,
                                         last_ckpt_time):
                        written.append(self.checkpoint())
                        last_ckpt_step = s.step_count
                        last_ckpt_time = time.monotonic()
                if self._stop.is_set():
                    preempted = s.step_count < total_sweeps
                # final checkpoint: preemption always persists progress;
                # completion persists the final state unless it is
                # already on disk
                if s.step_count != last_ckpt_step or not written:
                    if preempted or self._checkpointing_enabled() \
                            or self.ckpt.all_steps():
                        written.append(self.checkpoint())
                self.ckpt.wait()
        finally:
            self._restore_handlers(prev_handlers)
        return SupervisorResult(
            status="preempted" if preempted else "completed",
            step_count=s.step_count, digest=s.state_digest(),
            resumed_from=self.resumed_from,
            checkpoints_written=written,
            stop_signal=self._stop_signal)

    def _checkpointing_enabled(self) -> bool:
        return bool(self.every_sweeps or self.every_seconds)

    def _cadence_due(self, step: int, last_step: int,
                     last_time: float) -> bool:
        if self.every_sweeps and step - last_step >= self.every_sweeps:
            return True
        if self.every_seconds \
                and time.monotonic() - last_time >= self.every_seconds:
            return True
        return False
