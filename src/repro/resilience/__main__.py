"""``python -m repro.resilience`` -- checkpoint chaos & triage CLI.

Two subcommands, built for the CI chaos job and for post-mortems:

    # corrupt a step in a known, deterministic way (default: newest)
    python -m repro.resilience corrupt CKPT_DIR --mode flip-byte

    # validate every committed step; JSON report of the problems
    python -m repro.resilience validate CKPT_DIR

``corrupt`` applies one of the :data:`repro.resilience.faults.CORRUPTERS`
crash topologies to a real checkpoint directory; ``validate`` runs the
same integrity checks restore runs (exit 0 when at least one step is
restorable, 1 otherwise).
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.ckpt import Checkpointer

from . import faults


def cmd_corrupt(args) -> int:
    ck = Checkpointer(args.dir)
    step = args.step
    if step is None:
        # newest committed step, VALID or not: corrupting an already-
        # broken step would silently test nothing
        steps = ck.all_steps()
        if not steps:
            print(f"no committed steps in {args.dir}", file=sys.stderr)
            return 1
        step = steps[-1]
    path = faults.CORRUPTERS[args.mode](args.dir, step)
    print(f"# corrupted step {step} ({args.mode}): {path}")
    return 0


def cmd_validate(args) -> int:
    ck = Checkpointer(args.dir)
    report = {"dir": args.dir, "steps": {}}
    for s in ck.all_steps():
        problems = ck.validate_step(s)
        report["steps"][str(s)] = {"valid": not problems,
                                   "problems": problems}
    latest = ck.latest_step()
    report["latest_valid_step"] = latest
    print(json.dumps(report, indent=1, sort_keys=True))
    return 0 if latest is not None else 1


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.resilience",
        description="checkpoint fault injection and validation")
    sub = ap.add_subparsers(dest="cmd", required=True)

    cor = sub.add_parser("corrupt",
                         help="apply a crash topology to one step")
    cor.add_argument("dir", help="Checkpointer directory")
    cor.add_argument("--step", type=int, default=None,
                     help="step to corrupt (default: newest committed)")
    cor.add_argument("--mode", default="flip-byte",
                     choices=sorted(faults.CORRUPTERS))
    cor.set_defaults(fn=cmd_corrupt)

    val = sub.add_parser("validate",
                         help="integrity-check every committed step")
    val.add_argument("dir", help="Checkpointer directory")
    val.set_defaults(fn=cmd_validate)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    raise SystemExit(main())
