"""Checkpoint integrity: CRC32C manifests committed under DONE.

A checkpoint step that *looks* complete (its ``DONE`` marker exists)
can still be unreadable: a torn write the marker outlived, a truncated
``arrays.npz``, a flipped byte from a bad disk or transfer.  The
original layout trusted those bytes blindly -- restore crashed deep in
``np.load`` or, worse, silently resumed from corrupt state.  This
module gives every step a ``manifest.json`` written *before* the DONE
marker (so the atomic-rename commit covers it too):

* per **file** (``arrays.npz``, ``spec.json``): byte length + CRC32C,
  the cheap whole-file truncation/corruption check run at discovery
  time (``Checkpointer.latest_step``/``load_arrays``);
* per **array** (each npz key): CRC32C over the raw array bytes plus
  shape and dtype, verified after deserialization so a restore can name
  exactly which array went bad.

CRC32C (Castagnoli, the checksum of GCS/Parquet/iSCSI) is implemented
here as a dependency-free slicing-by-8 table walk -- this container has
no ``crc32c``/``google_crc32c`` wheel to lean on, and ``zlib.crc32``
is a different polynomial.  Throughput is measured in EXPERIMENTS.md
(S Resilience); the cost is paid once per checkpoint write/restore,
never on the sweep hot path.

Verification is *reporting*, not raising: ``validate_step_dir`` and
``verify_arrays`` return a list of human-readable problems (empty =
valid) so callers can decide between skip, quarantine, and raise.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

import numpy as np

#: manifest schema version; bump on layout changes
MANIFEST_FORMAT = 1
MANIFEST_NAME = "manifest.json"
DONE_NAME = "DONE"
ARRAYS_NAME = "arrays.npz"
SPEC_NAME = "spec.json"

_POLY = 0x82F63B78  # CRC-32C (Castagnoli), reflected


def _make_tables():
    t0 = []
    for i in range(256):
        c = i
        for _ in range(8):
            c = (c >> 1) ^ (_POLY if c & 1 else 0)
        t0.append(c)
    tables = [t0]
    for _ in range(7):
        prev = tables[-1]
        tables.append([t0[prev[i] & 0xFF] ^ (prev[i] >> 8)
                       for i in range(256)])
    return tables


_T = _make_tables()


#: below this length the scalar slicing-by-8 walk beats numpy setup
_NUMPY_THRESHOLD = 2048


def crc32c(data, value: int = 0) -> int:
    """CRC32C of ``data`` (bytes-like); pass a previous ``value`` to
    checksum incrementally: ``crc32c(b, crc32c(a)) == crc32c(a + b)``.

    Large inputs take the vectorized ladder (:func:`_crc32c_numpy`,
    ~8x the scalar walk on this container -- EXPERIMENTS.md
    S Resilience); the scalar path remains the oracle the ladder is
    property-tested against.
    """
    if len(memoryview(data)) * memoryview(data).itemsize \
            >= _NUMPY_THRESHOLD:
        return _crc32c_numpy(data, value)
    return _crc32c_scalar(data, value)


def _crc32c_scalar(data, value: int = 0) -> int:
    crc = (value ^ 0xFFFFFFFF) & 0xFFFFFFFF
    mv = memoryview(data)
    if mv.ndim != 1 or mv.itemsize != 1:
        mv = mv.cast("B")
    t0, t1, t2, t3, t4, t5, t6, t7 = _T
    n = len(mv)
    i = 0
    # slicing-by-8: one table walk per 8 input bytes
    for i in range(0, n - 7, 8):
        crc ^= mv[i] | (mv[i + 1] << 8) | (mv[i + 2] << 16) \
            | (mv[i + 3] << 24)
        crc = (t7[crc & 0xFF] ^ t6[(crc >> 8) & 0xFF]
               ^ t5[(crc >> 16) & 0xFF] ^ t4[(crc >> 24) & 0xFF]
               ^ t3[mv[i + 4]] ^ t2[mv[i + 5]]
               ^ t1[mv[i + 6]] ^ t0[mv[i + 7]])
    for j in range(n - n % 8, n):
        crc = t0[(crc ^ mv[j]) & 0xFF] ^ (crc >> 8)
    return crc ^ 0xFFFFFFFF


# -- vectorized CRC ladder ---------------------------------------------------
#
# The byte-at-a-time recurrence crc' = (crc >> 8) ^ T[(crc ^ b) & 0xFF]
# splits, because T is a table of a GF(2)-LINEAR map on the low byte,
# into  crc' = L(crc) ^ T[b]  with  L(c) = (c >> 8) ^ T[c & 0xFF]  also
# linear.  Unrolling:  crc_n = L^n(init) ^ XOR_i L^(n-1-i)(T[b_i]).
# The XOR sum is an associative reduction -- combine(x, y) over a
# right half of length 2^k is L^(2^k)(x) ^ y -- so it evaluates as a
# log-depth numpy tree: one vectorized 4-table lookup per level, with
# the per-level operator L^(2^k) built once by self-composition and
# cached.  Front-padding with zero *bytes* is free (T[0] = 0 and the
# position weights count from the END), which keeps every level's
# element lengths equal.

_T0_NP = np.array(_T[0], dtype=np.uint32)


def _op_apply_np(op: np.ndarray, v: np.ndarray) -> np.ndarray:
    """Apply a linear op (4 x 256 uint32 byte tables) elementwise."""
    return (op[0][v & 0xFF] ^ op[1][(v >> 8) & 0xFF]
            ^ op[2][(v >> 16) & 0xFF] ^ op[3][v >> 24])


def _make_l1() -> np.ndarray:
    q = np.arange(256, dtype=np.uint32)
    op = np.zeros((4, 256), np.uint32)
    op[0] = _T0_NP                      # L(q)       = T[q]
    for p in range(1, 4):               # L(q << 8p) = q << 8(p-1)
        op[p] = q << (8 * (p - 1))
    return op


#: _LEVELS[k] = byte tables of L^(2^k); grown on demand, process-cached
_LEVELS = [_make_l1()]


def _level(k: int) -> np.ndarray:
    while len(_LEVELS) <= k:
        prev = _LEVELS[-1]
        _LEVELS.append(np.stack([_op_apply_np(prev, prev[p])
                                 for p in range(4)]))
    return _LEVELS[k]


def _crc32c_numpy(data, value: int = 0) -> int:
    d = np.frombuffer(memoryview(data).cast("B"), dtype=np.uint8)
    n = d.size
    if n == 0:
        return value
    e = _T0_NP[d]
    size = 1 << (n - 1).bit_length()
    if size != n:  # zero-pad at the FRONT: weights count from the end
        e = np.concatenate([np.zeros(size - n, np.uint32), e])
    k = 0
    while e.size > 1:
        e = _op_apply_np(_level(k), e[0::2]) ^ e[1::2]
        k += 1
    red = int(e[0])
    # init-register contribution L^n(init), by binary exponentiation
    state = (value ^ 0xFFFFFFFF) & 0xFFFFFFFF
    k, nn = 0, n
    while nn:
        if nn & 1:
            op = _level(k)
            state = int(op[0][state & 0xFF] ^ op[1][(state >> 8) & 0xFF]
                        ^ op[2][(state >> 16) & 0xFF]
                        ^ op[3][state >> 24])
        nn >>= 1
        k += 1
    return (state ^ red) ^ 0xFFFFFFFF


def crc32c_hex(data, value: int = 0) -> str:
    return f"{crc32c(data, value):08x}"


def file_crc32c(path: str, chunk_bytes: int = 1 << 20):
    """``(crc32c, nbytes)`` of a file, streamed in ``chunk_bytes``."""
    crc, n = 0, 0
    with open(path, "rb") as f:
        while True:
            chunk = f.read(chunk_bytes)
            if not chunk:
                break
            crc = crc32c(chunk, crc)
            n += len(chunk)
    return crc, n


def _array_record(a: np.ndarray) -> dict:
    a = np.ascontiguousarray(a)
    return {"crc32c": crc32c_hex(a.tobytes()),
            "nbytes": int(a.nbytes),
            "shape": list(a.shape),
            "dtype": str(a.dtype)}


def build_manifest(step: int, host: Dict[str, np.ndarray],
                   step_dir: str) -> dict:
    """The integrity manifest of one step: per-array CRCs from the
    in-memory host snapshot (the exact bytes ``np.savez`` serialized),
    per-file CRCs from the bytes on disk in ``step_dir``."""
    files = {}
    for name in (ARRAYS_NAME, SPEC_NAME):
        path = os.path.join(step_dir, name)
        if os.path.exists(path):
            crc, nbytes = file_crc32c(path)
            files[name] = {"crc32c": f"{crc:08x}", "nbytes": nbytes}
    return {"format": MANIFEST_FORMAT,
            "algo": "crc32c",
            "step": int(step),
            "files": files,
            "arrays": {k: _array_record(np.asarray(v))
                       for k, v in host.items()}}


def write_manifest(step_dir: str, manifest: dict) -> str:
    path = os.path.join(step_dir, MANIFEST_NAME)
    with open(path, "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    return path


def load_manifest(step_dir: str) -> Optional[dict]:
    """The parsed manifest, or ``None`` when the step predates the
    integrity format (legacy steps stay restorable)."""
    path = os.path.join(step_dir, MANIFEST_NAME)
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def validate_step_dir(step_dir: str,
                      expect_step: Optional[int] = None) -> List[str]:
    """File-level validation of one step directory; returns the list of
    problems (empty = valid).  This is the discovery-time check: cheap
    enough to run on every candidate while walking backwards for the
    newest restorable step, yet strong enough to catch every crash
    topology -- torn write (no DONE), stale DONE (missing arrays),
    truncation, and bit corruption (file CRC mismatch).

    A vanished directory (``keep``-pruning racing the validation) is
    reported as a problem, never an exception: the caller just moves on
    to the next candidate.
    """
    problems: List[str] = []
    try:
        if not os.path.isdir(step_dir):
            return [f"step dir missing: {step_dir}"]
        if not os.path.exists(os.path.join(step_dir, DONE_NAME)):
            return ["no DONE marker (uncommitted/torn write)"]
        arrays_path = os.path.join(step_dir, ARRAYS_NAME)
        if not os.path.exists(arrays_path):
            return [f"DONE present but {ARRAYS_NAME} missing "
                    f"(stale marker)"]
        try:
            manifest = load_manifest(step_dir)
        except (ValueError, OSError) as e:
            return [f"unreadable {MANIFEST_NAME}: {e}"]
        if manifest is None:
            # legacy (pre-integrity) step: the zip container's own
            # per-entry CRC32 is the only line of defense -- read every
            # entry so truncation/corruption surfaces here, not mid-restore
            try:
                with np.load(arrays_path, allow_pickle=False) as z:
                    for k in z.files:
                        z[k]
            except Exception as e:
                problems.append(f"legacy step fails to load: "
                                f"{type(e).__name__}: {e}")
            return problems
        if expect_step is not None \
                and manifest.get("step") != expect_step:
            problems.append(f"manifest step {manifest.get('step')!r} != "
                            f"directory step {expect_step}")
        for name, rec in manifest.get("files", {}).items():
            path = os.path.join(step_dir, name)
            if not os.path.exists(path):
                problems.append(f"{name}: in manifest but missing on disk")
                continue
            size = os.path.getsize(path)
            if size != rec["nbytes"]:
                problems.append(f"{name}: {size} bytes on disk, manifest "
                                f"says {rec['nbytes']} (truncated?)")
                continue
            crc, _ = file_crc32c(path)
            if f"{crc:08x}" != rec["crc32c"]:
                problems.append(f"{name}: CRC32C {crc:08x} != manifest "
                                f"{rec['crc32c']} (corrupt)")
    except (FileNotFoundError, NotADirectoryError) as e:
        # the directory (or a file inside it) vanished mid-validation:
        # a GC prune raced us -- this candidate is simply gone
        problems.append(f"step vanished during validation: {e}")
    return problems


def verify_arrays(arrays: Dict[str, np.ndarray],
                  manifest: Optional[dict]) -> List[str]:
    """Per-array verification of a deserialized checkpoint against its
    manifest: key set, shape, dtype, and CRC32C of the raw bytes.  The
    problem strings NAME the offending array -- a corrupt restore must
    say which key went bad, not just that something did."""
    if manifest is None:
        return []  # legacy step: nothing recorded to verify against
    problems: List[str] = []
    recorded = manifest.get("arrays", {})
    missing = sorted(set(recorded) - set(arrays))
    extra = sorted(set(arrays) - set(recorded))
    if missing:
        problems.append(f"arrays missing vs manifest: {missing}")
    if extra:
        problems.append(f"arrays not in manifest: {extra}")
    for k in sorted(set(recorded) & set(arrays)):
        a = np.ascontiguousarray(np.asarray(arrays[k]))
        rec = recorded[k]
        if list(a.shape) != rec["shape"] or str(a.dtype) != rec["dtype"]:
            problems.append(
                f"array {k!r}: shape/dtype {a.shape}/{a.dtype} != "
                f"manifest {tuple(rec['shape'])}/{rec['dtype']}")
            continue
        got = crc32c_hex(a.tobytes())
        if got != rec["crc32c"]:
            problems.append(f"array {k!r}: CRC32C {got} != manifest "
                            f"{rec['crc32c']} (corrupt)")
    return problems
