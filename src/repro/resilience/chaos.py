"""Chaos drill: kill, corrupt, resume, compare digests (DESIGN.md S13).

The end-to-end fault-tolerance gate CI runs on every push, usable
locally as well:

    python -m repro.resilience.chaos --workdir /tmp/chaos

Four acts, all through the real ``python -m repro run --supervise``
CLI in subprocesses:

1. an uninterrupted **reference** run; its ``final_state_digest=``
   line is the ground truth;
2. a **chaos** run SIGTERM-killed as soon as its first checkpoint
   commits (the preemption path);
3. the newest committed checkpoint is **corrupted** with
   ``python -m repro.resilience corrupt`` (flip-byte), so the resume
   must quarantine it and fall back;
4. the run is **resumed** under an injected transient dispatch fault
   (``REPRO_FAULTS``), exercising the retry path, and must finish with
   a digest bit-identical to the reference.

Exit 0 iff the recovered digest matches.  The kill deliberately races
a fast run: when the run completes before the signal lands (or the
signal lands before the CLI installs its handler), the drill still
corrupts + resumes -- the digest contract is the same either way.

``--temps`` (comma list) runs the same four acts in vmapped-ensemble
mode: the batch's checkpoints carry batched state arrays, and the
resume must restore every member bit-exactly (the CI chaos job drills
both paths).

``--mesh RxC`` runs acts 2-4 SHARDED while the reference stays
single-device: the final digest equality then also proves the sharded
tier's stream invariance (DESIGN.md S15).  ``--resume-mesh RxC``
additionally resumes act 4 on a DIFFERENT device grid than the one the
killed run checkpointed under -- the cross-mesh checkpoint-portability
drill (the supervisor accepts a mesh-only spec difference).  The drill
widens ``XLA_FLAGS`` host-device forcing itself when the requested
meshes need more devices than the environment provides.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import shutil
import signal
import subprocess
import sys
import time


def _cli(args, ckpt_dir: str, mesh: str = "") -> list:
    cmd = [sys.executable, "-m", "repro", "run",
           "--n", str(args.n), "--engine", args.engine,
           "--temperature", str(args.temperature),
           "--seed", str(args.seed),
           "--supervise", ckpt_dir, "--sweeps", str(args.sweeps),
           "--ckpt-every-sweeps", str(args.every),
           "--chunk", str(args.chunk), "--keep", "4"]
    if mesh:
        cmd += ["--mesh", mesh]
    if args.temps:
        # ensemble mode: the drill then covers the vmapped-batch
        # supervised path (batched checkpoint arrays, batched resume)
        cmd += ["--temps", args.temps]
        if args.seeds:
            cmd += ["--seeds", args.seeds]
    return cmd


def _digest(out: str) -> str:
    for line in out.splitlines():
        if line.startswith("final_state_digest="):
            return line.split("=", 1)[1].strip()
    raise SystemExit(f"no final_state_digest line in output:\n{out}")


def _committed_steps(ckpt_dir: str) -> list:
    return glob.glob(os.path.join(ckpt_dir, "step_*", "DONE"))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.resilience.chaos",
        description="supervised-run chaos drill (kill/corrupt/resume)")
    ap.add_argument("--workdir", default="results/chaos")
    ap.add_argument("--n", type=int, default=64)
    ap.add_argument("--engine", default="multispin")
    ap.add_argument("--temperature", type=float, default=2.27)
    ap.add_argument("--seed", type=int, default=11)
    ap.add_argument("--temps", default="",
                    help="comma list of member temperatures: run the "
                         "drill in vmapped-ensemble mode (the batched "
                         "supervised path) instead of single-lattice")
    ap.add_argument("--seeds", default="",
                    help="comma list of ensemble member seeds "
                         "(with --temps; default 0..B-1)")
    ap.add_argument("--mesh", default="",
                    help="device-mesh shape (e.g. 2x2): run the chaos "
                         "acts SHARDED; the reference stays single-"
                         "device, so the digest match also proves "
                         "sharded stream invariance (DESIGN.md S15)")
    ap.add_argument("--resume-mesh", default="",
                    help="mesh shape for the act-4 resume only (with "
                         "--mesh): the cross-mesh checkpoint-"
                         "portability drill")
    ap.add_argument("--sweeps", type=int, default=2048)
    ap.add_argument("--chunk", type=int, default=8)
    ap.add_argument("--every", type=int, default=64,
                    help="checkpoint cadence in sweeps")
    ap.add_argument("--timeout", type=float, default=600.0,
                    help="per-subprocess wall-clock budget (s)")
    args = ap.parse_args(argv)

    env = dict(os.environ)
    env.pop("REPRO_FAULTS", None)  # the reference must run clean
    need = 1
    for m in (args.mesh, args.resume_mesh):
        if m:
            d = 1
            for tok in m.split("x"):
                d *= int(tok)
            need = max(need, d)
    if need > 1 and "xla_force_host_platform_device_count" \
            not in env.get("XLA_FLAGS", ""):
        # the subprocesses must see enough host devices for the mesh
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                            f" --xla_force_host_platform_device_count="
                            f"{need}").strip()
    ref_dir = os.path.join(args.workdir, "ref")
    chaos_dir = os.path.join(args.workdir, "chaos")
    for d in (ref_dir, chaos_dir):
        shutil.rmtree(d, ignore_errors=True)
    os.makedirs(args.workdir, exist_ok=True)

    print("# [1/4] reference run (uninterrupted)", flush=True)
    ref = subprocess.run(_cli(args, ref_dir), env=env, text=True,
                         capture_output=True, timeout=args.timeout)
    print(ref.stdout, end="", flush=True)
    if ref.returncode != 0:
        print(ref.stderr, file=sys.stderr)
        return 1
    want = _digest(ref.stdout)

    print("# [2/4] chaos run: SIGTERM after the first committed step",
          flush=True)
    proc = subprocess.Popen(_cli(args, chaos_dir, mesh=args.mesh),
                            env=env, text=True,
                            stdout=subprocess.PIPE,
                            stderr=subprocess.STDOUT)
    deadline = time.monotonic() + args.timeout
    while (proc.poll() is None and time.monotonic() < deadline
           and not _committed_steps(chaos_dir)):
        time.sleep(0.01)
    if proc.poll() is None:
        proc.send_signal(signal.SIGTERM)
    out, _ = proc.communicate(timeout=args.timeout)
    print(out, end="", flush=True)
    # 3 = preempted-and-checkpointed (the intended path); 0 = the run
    # finished before the signal landed; -SIGTERM = the signal landed
    # in the startup window before the CLI installed its handler --
    # every one of them leaves a directory the drill can continue from
    if proc.returncode not in (0, 3, -signal.SIGTERM):
        print(f"unexpected chaos-run exit {proc.returncode}",
              file=sys.stderr)
        return 1
    print(f"# chaos run exit {proc.returncode}", flush=True)

    print("# [3/4] corrupting newest committed checkpoint (flip-byte)",
          flush=True)
    if _committed_steps(chaos_dir):
        subprocess.run([sys.executable, "-m", "repro.resilience",
                        "corrupt", chaos_dir], env=env, check=True,
                       timeout=args.timeout)
    else:
        print("# no committed checkpoint survived the kill -- the "
              "resume below is a fresh (still bit-exact) run")

    print("# [4/4] resume under an injected transient dispatch fault",
          flush=True)
    env["REPRO_FAULTS"] = json.dumps({"transient_dispatches": 1})
    res = subprocess.run(_cli(args, chaos_dir,
                              mesh=args.resume_mesh or args.mesh),
                         env=env, text=True,
                         capture_output=True, timeout=args.timeout)
    print(res.stdout, end="", flush=True)
    if res.returncode != 0:
        print(res.stderr, file=sys.stderr)
        return 1
    got = _digest(res.stdout)
    if got != want:
        print(f"FAIL: recovered digest {got} != reference {want}",
              file=sys.stderr)
        return 1
    print(f"chaos drill OK: digest {got} bit-identical after "
          f"kill + corruption + injected fault")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
