"""Typed exceptions of the resilience subsystem (DESIGN.md S13).

The dispatch-recovery layer (``repro.resilience.degrade``) classifies
failures by *recoverability*, not by origin: a transient fault is worth
retrying with backoff, a resident-tier resource exhaustion is worth a
one-time demotion to the per-half-sweep fallback tier, and anything
else propagates.  The fault-injection harness
(``repro.resilience.faults``) raises exactly these types so injected
and real failures travel the same recovery paths.
"""
from __future__ import annotations


class ResilienceError(RuntimeError):
    """Base class of the resilience subsystem's own failures."""


class TransientDispatchError(ResilienceError):
    """A dispatch failure worth retrying: the operation itself is fine,
    the attempt hit a transient condition (queue full, device busy,
    injected chaos).  Classified transient by
    :func:`repro.resilience.degrade.is_transient`."""


class SimulatedResourceExhausted(ResilienceError):
    """Injected stand-in for an XLA ``RESOURCE_EXHAUSTED`` failure (the
    VMEM/OOM class a resident kernel can hit on real hardware).  The
    message carries the literal ``RESOURCE_EXHAUSTED`` token so the
    classifier treats real and simulated failures identically."""

    def __init__(self, detail: str = "simulated VMEM exhaustion"):
        super().__init__(f"RESOURCE_EXHAUSTED: {detail} (injected by "
                         f"repro.resilience.faults)")


class SupervisorError(ResilienceError):
    """A supervised run cannot proceed (no spec and no checkpoint, spec
    mismatch against the checkpoint being resumed, ...)."""


class FaultPlanError(ResilienceError):
    """A fault plan cannot be parsed (malformed JSON, not an object,
    unknown fault kind, negative count).  Carries the offending text so
    a bad ``REPRO_FAULTS`` value is diagnosable from the message alone
    -- a chaos job that silently runs WITHOUT its injected faults would
    pass vacuously."""

    def __init__(self, detail: str, text: str = ""):
        self.text = text
        suffix = f" (offending text: {text!r})" if text else ""
        super().__init__(f"bad fault plan: {detail}{suffix}")
