"""Deterministic fault injection: chaos you can write a regression for.

Two halves, matching the two failure surfaces of a long unattended run
(DESIGN.md S13):

* **Dispatch faults** -- a process-global :class:`FaultPlan` consulted
  by the recovery wrapper (``repro.resilience.degrade.run_dispatch``)
  once per compiled-call launch.  The plan deterministically raises
  :class:`~repro.resilience.errors.TransientDispatchError` for the
  first ``transient_dispatches`` launches and
  :class:`~repro.resilience.errors.SimulatedResourceExhausted` for the
  first ``resident_oom`` launches that would run on the resident tier.
  Faults fire BEFORE the compiled call, so donated input buffers are
  never consumed by a failed launch and a retry is always safe.
  ``install_from_env()`` reads the plan from ``REPRO_FAULTS`` (a JSON
  object), which is how the CI chaos job injects into a subprocess CLI
  run without touching its command line.

* **Checkpoint corrupters** -- functions that reproduce the on-disk
  crash topologies against a ``Checkpointer`` step directory:
  ``kill_mid_write`` (torn write, no DONE), ``truncate_arrays``
  (short ``arrays.npz`` under a valid DONE), ``stale_done`` (DONE
  marker outliving its arrays), and ``flip_byte`` (silent bit rot).
  Each is deterministic given its arguments; they drive both the test
  suite and the chaos CI job (``python -m repro.resilience corrupt``).

When no plan is installed the dispatch-fault check is one global
``is None`` load -- nothing on the hot path changes shape.
"""
from __future__ import annotations

import dataclasses
import json
import os
from contextlib import contextmanager
from typing import Optional

from .errors import (FaultPlanError, SimulatedResourceExhausted,
                     TransientDispatchError)

#: environment variable ``install_from_env`` reads a JSON plan from
ENV_VAR = "REPRO_FAULTS"


@dataclasses.dataclass
class FaultPlan:
    """Counters of faults still to inject; fields tick down to zero.

    ``transient_dispatches`` -- raise ``TransientDispatchError`` on
    this many dispatch launches (recoverable by bounded retry).
    ``resident_oom`` -- raise ``SimulatedResourceExhausted`` on this
    many launches whose engine would use the resident kernel tier
    (recoverable by demotion to the per-half-sweep fallback tier).
    """

    transient_dispatches: int = 0
    resident_oom: int = 0
    #: injections actually fired, by kind (for assertions/telemetry)
    fired: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if self.transient_dispatches < 0 or self.resident_oom < 0:
            raise ValueError(f"fault counts must be >= 0: {self}")

    def maybe_fail_dispatch(self, resident_active: bool) -> None:
        if resident_active and self.resident_oom > 0:
            self.resident_oom -= 1
            self.fired["resident_oom"] = \
                self.fired.get("resident_oom", 0) + 1
            raise SimulatedResourceExhausted(
                "resident kernel VMEM working set over budget")
        if self.transient_dispatches > 0:
            self.transient_dispatches -= 1
            self.fired["transient_dispatch"] = \
                self.fired.get("transient_dispatch", 0) + 1
            raise TransientDispatchError(
                "UNAVAILABLE: injected transient dispatch failure")

    @classmethod
    def from_json(cls, s: str) -> "FaultPlan":
        """Parse a plan from JSON; every malformation -- syntax error,
        non-object document, unknown fault kind, non-integer or
        negative count -- raises a typed
        :class:`~repro.resilience.errors.FaultPlanError` carrying the
        offending text (the ``REPRO_FAULTS`` contract: a chaos job
        must fail loudly, not run faultless)."""
        try:
            d = json.loads(s)
        except json.JSONDecodeError as e:
            raise FaultPlanError(f"malformed JSON: {e}", s) from e
        if not isinstance(d, dict):
            raise FaultPlanError(
                f"must be a JSON object, got {type(d).__name__}", s)
        unknown = sorted(set(d) - {"transient_dispatches",
                                   "resident_oom"})
        if unknown:
            raise FaultPlanError(
                f"unknown fault kind(s) {unknown}; known: "
                f"['resident_oom', 'transient_dispatches']", s)
        counts = {}
        for k, v in d.items():
            if isinstance(v, bool) or not isinstance(v, int):
                raise FaultPlanError(
                    f"count {k}={v!r} must be an integer", s)
            counts[k] = v
        try:
            return cls(**counts)
        except ValueError as e:  # __post_init__: negative counts
            raise FaultPlanError(str(e), s) from e


_PLAN: Optional[FaultPlan] = None


def install(plan: FaultPlan) -> FaultPlan:
    """Make ``plan`` the process-global dispatch fault plan."""
    global _PLAN
    _PLAN = plan
    return plan


def clear() -> None:
    global _PLAN
    _PLAN = None


def active_plan() -> Optional[FaultPlan]:
    return _PLAN


@contextmanager
def injected(plan: FaultPlan):
    """Scoped installation: ``with faults.injected(FaultPlan(...)):``"""
    prev = _PLAN
    install(plan)
    try:
        yield plan
    finally:
        install(prev) if prev is not None else clear()


def install_from_env(env_var: str = ENV_VAR) -> Optional[FaultPlan]:
    """Install a plan from ``$REPRO_FAULTS`` (JSON object); no-op and
    ``None`` when the variable is unset/empty.  Called by the CLI
    supervise path so the chaos job can inject into a subprocess."""
    raw = os.environ.get(env_var, "")
    if not raw:
        return None
    return install(FaultPlan.from_json(raw))


# ---------------------------------------------------------------------------
# file corrupters: byte-level crash topologies on ANY file.  The
# checkpoint corrupters below and the serve journal torn-write tests
# (tests/test_serve.py) share these primitives.
# ---------------------------------------------------------------------------

def truncate_file(path: str, keep_bytes: int) -> str:
    """Truncate ``path`` to ``keep_bytes`` -- a torn write: the tail of
    the file never reached disk (power cut mid-append, lost page-cache
    flush)."""
    if keep_bytes < 0:
        raise ValueError(f"keep_bytes must be >= 0, got {keep_bytes}")
    with open(path, "r+b") as f:
        f.truncate(keep_bytes)
    return path


def flip_byte_in_file(path: str, offset: int = 128) -> str:
    """XOR one byte of ``path`` at ``offset`` (mod file size): silent
    bit rot that only a content checksum catches."""
    size = os.path.getsize(path)
    if size == 0:
        raise ValueError(f"{path}: empty file, nothing to flip")
    with open(path, "r+b") as f:
        f.seek(offset % size)
        b = f.read(1)
        f.seek(offset % size)
        f.write(bytes([b[0] ^ 0xFF]))
    return path


# ---------------------------------------------------------------------------
# checkpoint corrupters: the on-disk crash topologies
# ---------------------------------------------------------------------------

def _step_dir(directory: str, step: int) -> str:
    return os.path.join(directory, f"step_{step:010d}")


def kill_mid_write(directory: str, step: int,
                   partial_bytes: bytes = b"\x93NUMPY-torn") -> str:
    """A writer killed mid-step: the step dir exists with a partial
    ``arrays.npz`` and NO DONE marker (what a crash between ``savez``
    and the marker write leaves when the tmp-rename is also lost)."""
    path = _step_dir(directory, step)
    os.makedirs(path, exist_ok=True)
    with open(os.path.join(path, "arrays.npz"), "wb") as f:
        f.write(partial_bytes)
    done = os.path.join(path, "DONE")
    if os.path.exists(done):
        os.remove(done)
    return path


def truncate_arrays(directory: str, step: int,
                    keep_bytes: int = 64) -> str:
    """Truncate a COMMITTED step's ``arrays.npz`` to ``keep_bytes``,
    leaving the DONE marker valid -- a torn write the marker outlived
    (lost page-cache flush, partial copy)."""
    return truncate_file(
        os.path.join(_step_dir(directory, step), "arrays.npz"),
        keep_bytes)


def stale_done(directory: str, step: int) -> str:
    """Delete a committed step's ``arrays.npz`` out from under its DONE
    marker (a partially-propagated object-store delete, or tooling that
    removed the payload but not the marker)."""
    path = os.path.join(_step_dir(directory, step), "arrays.npz")
    os.remove(path)
    return path


def flip_byte(directory: str, step: int, offset: int = 128,
              filename: str = "arrays.npz") -> str:
    """XOR one byte of a committed step's payload: silent bit rot the
    zip container may or may not notice, but the CRC32C manifest must."""
    return flip_byte_in_file(
        os.path.join(_step_dir(directory, step), filename), offset)


#: corrupter registry for the ``python -m repro.resilience corrupt`` CLI
CORRUPTERS = {
    "kill-mid-write": kill_mid_write,
    "truncate": truncate_arrays,
    "stale-done": stale_done,
    "flip-byte": flip_byte,
}
