"""Graceful dispatch degradation: bounded retry + resident demotion.

One compiled-call launch travels through :func:`run_dispatch`, which
classifies failures by *recoverability* (DESIGN.md S13):

* **transient** (:func:`is_transient` -- ``TransientDispatchError`` or
  an XLA ``UNAVAILABLE``/``DEADLINE_EXCEEDED`` status) -- retried with
  exponential backoff under a bounded :class:`RetryPolicy`; each retry
  increments the ``resilience.retry`` counter and emits a
  ``resilience.retry`` trace instant.
* **resident-tier resource exhaustion** (:func:`is_resident_oom` -- an
  ``XlaRuntimeError``-style message carrying ``RESOURCE_EXHAUSTED``,
  the class a resident kernel's VMEM working set hits on real
  hardware) -- the (engine family, lattice) is *demoted* to the
  per-half-sweep fallback tier for the rest of the process and the
  launch retried immediately.  Both tiers draw the same Philox stream
  (tests/test_resident.py), so demotion is invisible in the
  trajectory; it costs one re-JIT and O(k) extra HBM traffic.
* anything else propagates unchanged.

Demotions live in a process-global registry keyed ``(family, n, m)``:
``kernels.resident.plan_resident`` and ``decision_attrs`` consult it,
so engine construction, ``--dry-run`` plans, and dispatch span
attributes all agree that a demoted lattice runs the fallback tier.

Injected faults (``repro.resilience.faults``) are checked BEFORE the
compiled call is invoked, so a failed launch never consumes donated
input buffers and retrying with the same state is always safe.  With
no fault plan installed and no failure raised, ``run_dispatch`` adds
one ``is None`` load to the hot path.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Optional, Tuple

import repro.telemetry as tel

from . import faults
from .errors import TransientDispatchError

#: recovery counters -- module-held references survive REGISTRY.reset()
RETRIES = tel.REGISTRY.counter("resilience.retry")
DEMOTIONS = tel.REGISTRY.counter("resident.demote")

#: XLA status tokens worth a bounded retry (transport/queue hiccups)
_TRANSIENT_TOKENS = ("UNAVAILABLE", "DEADLINE_EXCEEDED", "ABORTED")


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded exponential backoff for transient dispatch failures.

    ``sleep`` is injectable so tests retry without wall-clock cost.
    """

    max_retries: int = 3
    base_delay_s: float = 0.05
    multiplier: float = 4.0
    max_delay_s: float = 5.0
    sleep: Callable[[float], None] = time.sleep

    def delay(self, attempt: int) -> float:
        """Backoff before retry ``attempt`` (0-based)."""
        return min(self.base_delay_s * self.multiplier ** attempt,
                   self.max_delay_s)


DEFAULT_POLICY = RetryPolicy()


def is_transient(exc: BaseException) -> bool:
    """Worth retrying: our typed transient error, or an XLA runtime
    failure whose status token marks the *attempt* (not the program)
    as the problem."""
    if isinstance(exc, TransientDispatchError):
        return True
    msg = str(exc)
    return any(tok in msg for tok in _TRANSIENT_TOKENS)


def is_resident_oom(exc: BaseException) -> bool:
    """A resource-exhaustion failure (real XLA OOM or the injected
    stand-in): recoverable by demoting the resident tier, NOT by
    retrying the same program."""
    return "RESOURCE_EXHAUSTED" in str(exc)


# ---------------------------------------------------------------------------
# demotion registry: (family, n, m) -> reason, process-global
# ---------------------------------------------------------------------------

_DEMOTED: Dict[Tuple[str, int, int], str] = {}


def demote(family: str, n: int, m: int, reason: str) -> None:
    """Record that (family, n, m) must run the fallback tier from now
    on.  Idempotent; the first reason wins."""
    _DEMOTED.setdefault((family, n, m), reason)


def demotion_reason(family: str, n: int, m: int) -> Optional[str]:
    """The recorded demotion reason, or ``None`` when not demoted."""
    return _DEMOTED.get((family, n, m))


def demotions() -> Dict[Tuple[str, int, int], str]:
    """Snapshot of the registry (copy; mutating it changes nothing)."""
    return dict(_DEMOTED)


def reset_demotions() -> None:
    """Forget every demotion -- test isolation, not production use."""
    _DEMOTED.clear()


def _engine_demotable(engine) -> bool:
    return getattr(engine, "resident_plan", None) is not None


def run_dispatch(attempt: Callable[[], object], *, engine=None,
                 on_demote: Optional[Callable[[], None]] = None,
                 policy: Optional[RetryPolicy] = None):
    """Run one compiled-call launch with recovery (module docstring).

    ``attempt`` is a zero-arg closure over the launch; it is re-invoked
    as-is on retry, and after a demotion it must observe the engine's
    new tier (the engine wrappers re-read ``self.resident_plan`` /
    their jit caches on every call, so a plain closure does).
    ``on_demote`` lets callers owning their own jit caches (the batched
    runners) invalidate them when the engine's tier changes.
    """
    policy = DEFAULT_POLICY if policy is None else policy
    retries = 0
    while True:
        plan = faults.active_plan()
        try:
            if plan is not None:
                plan.maybe_fail_dispatch(_engine_demotable(engine))
            return attempt()
        except Exception as exc:
            if (engine is not None and _engine_demotable(engine)
                    and is_resident_oom(exc)):
                DEMOTIONS.inc()
                tel.instant("resident.demote", engine=engine.name,
                            lattice=(engine.cfg.n, engine.cfg.m),
                            reason=str(exc))
                engine._demote_resident(str(exc))
                if on_demote is not None:
                    on_demote()
                continue  # immediate retry on the fallback tier
            if is_transient(exc) and retries < policy.max_retries:
                delay = policy.delay(retries)
                retries += 1
                RETRIES.inc()
                tel.instant("resilience.retry", attempt=retries,
                            max_retries=policy.max_retries,
                            delay_s=delay, error=str(exc))
                policy.sleep(delay)
                continue
            raise
