"""``python -m repro.serve DIR`` -- run the sweep-farm server.

Also reachable as ``python -m repro serve DIR`` (the unified CLI).
Exit codes follow the ``--supervise`` convention: 0 = drained with
nothing outstanding, 3 = drained-preempted (checkpointed work remains;
rerun the same command to resume it).
"""
from __future__ import annotations

import argparse
import sys


def add_serve_args(ap: argparse.ArgumentParser) -> None:
    """The serve flag set (shared with ``python -m repro serve``)."""
    ap.add_argument("dir", metavar="DIR",
                    help="farm directory: journal, results, batch "
                         "checkpoints, endpoint file")
    ap.add_argument("--port", type=int, default=0,
                    help="HTTP port (0: ephemeral; the bound port is "
                         "written to DIR/serve.json)")
    ap.add_argument("--max-queue", type=int, default=64,
                    help="bounded queue depth: outstanding (non-"
                         "terminal) jobs beyond this are rejected "
                         "with HTTP 429 backpressure")
    ap.add_argument("--max-batch", type=int, default=8,
                    help="most compatible specs fused into one "
                         "vmapped ensemble dispatch")
    ap.add_argument("--chunk", type=int, default=64,
                    help="supervisor sweep-chunk per batch: drain "
                         "latency and deadline granularity")
    ap.add_argument("--ckpt-every-sweeps", type=int, default=0,
                    help="checkpoint cadence inside a batch (0: only "
                         "the preemption/final checkpoint)")
    ap.add_argument("--keep", type=int, default=3,
                    help="checkpoint steps kept per batch")
    ap.add_argument("--poll", type=float, default=0.25,
                    help="idle loop poll interval (seconds)")
    ap.add_argument("--drain-on-idle", action="store_true",
                    help="exit 0 once every accepted job is terminal "
                         "(batch/CI mode) instead of serving forever")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.serve",
        description="fault-tolerant sweep-farm server "
                    "(exit 0 done / 3 drained-preempted)")
    add_serve_args(ap)
    args = ap.parse_args(argv)
    return run_server(args)


def run_server(args) -> int:
    from repro.resilience import faults

    from .server import serve
    faults.install_from_env()  # CI chaos: REPRO_FAULTS JSON plan
    return serve(args.dir, port=args.port, poll=args.poll,
                 drain_on_idle=args.drain_on_idle,
                 max_queue=args.max_queue, max_batch=args.max_batch,
                 chunk=args.chunk,
                 ckpt_every_sweeps=args.ckpt_every_sweeps,
                 keep=args.keep)


if __name__ == "__main__":
    sys.exit(main())
