"""Admission + coalescing: queued jobs -> deterministic dispatch batches.

Two pure pieces the farm loop composes (DESIGN.md S14):

* **admission** -- ``parse_envelope`` maps a client document to a
  validated ``(RunSpec, sweeps, timeout_s)`` triple, converting every
  malformation into a typed :class:`~repro.serve.errors.AdmissionError`
  (the server never crashes on input; the HTTP layer maps the type to
  a 400);

* **coalescing** -- ``plan_batches`` groups compatible queued jobs into
  vmapped ensemble dispatches.  Compatible = single-mode spec on a
  counter-based engine (same engine + params, same lattice, same sweep
  target) with a seed below 2**32 (the ensemble bit-exactness bound):
  exactly the conditions under which member ``i`` of the fused batch
  reproduces job ``i``'s single-run trajectory bit-for-bit, so
  coalescing changes THROUGHPUT, never results.  Everything else runs
  uncoalesced as its own supervised run.

Grouping is a pure function of the queued jobs (submit order, chunks
of ``max_batch``) and batch ids hash (key, member ids) -- so a farm
restarted after a crash re-forms the identical batches and the
supervisor finds the checkpoints the killed run left behind.
"""
from __future__ import annotations

import dataclasses
import json
from typing import List, Optional, Tuple

from repro.api import BatchSpec, RunSpec
from repro.api.spec import MAX_BATCH_SEED
from repro.resilience import integrity

from .errors import AdmissionError

#: submission envelope keys (a bare RunSpec document is also accepted)
ENVELOPE_KEYS = ("spec", "sweeps", "timeout_s")


@dataclasses.dataclass
class Job:
    """One accepted submission, in-memory view of its journal records."""

    id: str
    spec: RunSpec
    sweeps: int
    timeout_s: Optional[float]
    submitted_t: float
    status: str = "queued"       # queued|running|completed|failed
    digest: Optional[str] = None
    error: Optional[str] = None
    summary: dict = dataclasses.field(default_factory=dict)

    @property
    def terminal(self) -> bool:
        return self.status in ("completed", "failed")

    def expired(self, now: float) -> bool:
        return (self.timeout_s is not None
                and now - self.submitted_t > self.timeout_s)

    def to_dict(self) -> dict:
        return {"id": self.id, "status": self.status,
                "sweeps": self.sweeps, "timeout_s": self.timeout_s,
                "digest": self.digest, "error": self.error,
                "summary": self.summary,
                "spec": self.spec.to_dict()}


def parse_envelope(doc) -> Tuple[RunSpec, int, Optional[float]]:
    """Validate a submission document -> ``(spec, sweeps, timeout_s)``.

    Accepts either ``{"spec": <RunSpec doc>, "sweeps": N,
    "timeout_s": T}`` or a bare RunSpec document (sweep target then
    taken from ``spec.sweep.total_sweeps``).  Every malformation is an
    :class:`AdmissionError` -- never a server crash.
    """
    if not isinstance(doc, dict):
        raise AdmissionError(
            f"submission must be a JSON object, got "
            f"{type(doc).__name__}")
    sweeps = None
    timeout_s = None
    spec_doc = doc
    if "spec" in doc:
        unknown = sorted(set(doc) - set(ENVELOPE_KEYS))
        if unknown:
            raise AdmissionError(
                f"envelope: unknown key(s) {unknown}; allowed: "
                f"{sorted(ENVELOPE_KEYS)}")
        spec_doc = doc["spec"]
        sweeps = doc.get("sweeps")
        timeout_s = doc.get("timeout_s")
    try:
        spec = RunSpec.from_dict(spec_doc)
    except (ValueError, KeyError, TypeError) as e:
        raise AdmissionError(f"bad RunSpec: {e}") from e
    if sweeps is None:
        if spec.sweep is None:
            raise AdmissionError(
                "no sweep target: pass 'sweeps' in the envelope or a "
                "spec with a sweep plan")
        sweeps = spec.sweep.total_sweeps
    if isinstance(sweeps, bool) or not isinstance(sweeps, int) \
            or sweeps <= 0:
        raise AdmissionError(
            f"sweeps must be a positive integer, got {sweeps!r}")
    if timeout_s is not None:
        if isinstance(timeout_s, bool) \
                or not isinstance(timeout_s, (int, float)) \
                or float(timeout_s) <= 0:
            raise AdmissionError(
                f"timeout_s must be a positive number, got "
                f"{timeout_s!r}")
        timeout_s = float(timeout_s)
    if spec.mesh is not None:
        # MeshSpec jobs are admitted as SOLO (never-coalesced) runs --
        # coalesce_key already returns None for mode != "single" -- but
        # only when this server's device pool can host the mesh; a
        # too-big mesh is a typed rejection, not a mid-run crash
        import jax
        if spec.mesh.n_devices > jax.device_count():
            raise AdmissionError(
                f"mesh {list(spec.mesh.shape)} needs "
                f"{spec.mesh.n_devices} devices; this server has "
                f"{jax.device_count()}")
    return spec, int(sweeps), timeout_s


# ---------------------------------------------------------------------------
# coalescing
# ---------------------------------------------------------------------------

def coalesce_key(job: Job) -> Optional[tuple]:
    """The compatibility key of a job, or ``None`` when it must run
    uncoalesced.  Jobs with equal keys fuse into one vmapped ensemble
    dispatch without changing any member's result (see module doc)."""
    spec = job.spec
    if spec.mode != "single":
        return None
    if not spec.engine.cls.counter_based:
        return None
    if spec.seed >= MAX_BATCH_SEED:
        return None
    return (spec.engine.name, spec.engine.params,
            spec.lattice.n, spec.lattice.m, spec.lattice.init_p_up,
            job.sweeps)


@dataclasses.dataclass
class Batch:
    """One dispatch unit: either a fused ensemble of coalesced jobs
    (``key`` set) or a single job run as-is (``key`` None)."""

    id: str
    jobs: List[Job]
    key: Optional[tuple]

    @property
    def coalesced(self) -> bool:
        return self.key is not None

    @property
    def sweeps(self) -> int:
        return self.jobs[0].sweeps

    def spec(self) -> RunSpec:
        """The RunSpec this batch executes: the fused ensemble spec for
        a coalesced batch (member order = job order), the job's own
        spec otherwise."""
        if not self.coalesced:
            return self.jobs[0].spec
        j0 = self.jobs[0].spec
        return RunSpec(
            lattice=j0.lattice, engine=j0.engine,
            temperature=j0.temperature, seed=j0.seed,
            batch=BatchSpec(
                temperatures=tuple(j.spec.temperature
                                   for j in self.jobs),
                seeds=tuple(j.spec.seed for j in self.jobs)))

    def runner_key(self) -> tuple:
        """The compiled-executable cache key: everything the traced
        computation's SHAPE depends on -- engine + params, lattice,
        batch size -- and nothing member-specific (temperatures and
        seeds are traced arguments; ``_EnsembleRunner.rebind``)."""
        j0 = self.jobs[0].spec
        return (j0.engine.name, j0.engine.params,
                j0.lattice.n, j0.lattice.m, j0.lattice.init_p_up,
                len(self.jobs))


def _batch_id(key: Optional[tuple], jobs: List[Job]) -> str:
    blob = json.dumps([list(key) if key else None,
                       [j.id for j in jobs]], sort_keys=True)
    return f"b{integrity.crc32c(blob.encode()):08x}"


def plan_batches(jobs: List[Job], max_batch: int) -> List[Batch]:
    """Deterministically group queued jobs into dispatch batches.

    Pure function of (job order, ``max_batch``): coalescible jobs
    group by key in submit order and split into chunks of at most
    ``max_batch``; uncoalescible jobs become singleton batches.
    Batches are ordered by their first member's submit position, and
    ids hash (key, member ids) -- a restarted farm re-plans the same
    queue into byte-identical batches, which is how an interrupted
    batch's checkpoints are found again.
    """
    if max_batch <= 0:
        raise ValueError(f"max_batch must be positive, got {max_batch}")
    groups: dict = {}
    order: List[tuple] = []  # (first position, key-or-job-marker)
    for pos, job in enumerate(jobs):
        key = coalesce_key(job)
        gk = key if key is not None else ("__solo__", job.id)
        if gk not in groups:
            groups[gk] = []
            order.append((pos, gk))
        groups[gk].append(job)
    batches: List[Batch] = []
    for _, gk in order:
        members = groups[gk]
        key = None if gk[0] == "__solo__" else gk
        if key is None:
            batches.append(Batch(_batch_id(None, members), members,
                                 None))
            continue
        for i in range(0, len(members), max_batch):
            chunk = members[i:i + max_batch]
            batches.append(Batch(_batch_id(key, chunk), chunk, key))
    return batches
