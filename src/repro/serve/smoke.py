"""Serve smoke drill: submit, SIGKILL, restart, verify (DESIGN.md S14).

The end-to-end crash-safety gate CI runs on every push, usable
locally as well:

    python -m repro.serve.smoke --workdir /tmp/serve_smoke

Two phases, each against a real ``python -m repro serve`` subprocess:

1. **crash safety** -- submit N mixed jobs (coalescible multispin
   specs + odd-shaped ones) through the HTTP client, SIGKILL the
   server as soon as the first batch starts, restart it with
   ``--drain-on-idle``, and assert: every acked job completes, each
   has EXACTLY one ``done`` record (the journal's ``job_table`` raises
   on duplicates), and every digest is bit-identical to a direct
   in-process ``Session`` run of the same spec;

2. **coalescing** -- on a fresh directory, queue k compatible specs
   behind a blocker job and assert from the journal that all k ran as
   ONE batch and from ``metrics.json`` that the whole phase cost one
   compiled dispatch per batch (``chunk >= sweeps``).

SIGKILL -- not SIGTERM -- is the point: no handler runs, nothing
flushes, and the journal's fsync-before-ack contract is the only thing
standing between the farm and lost work.
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import signal
import subprocess
import sys
import time

from repro.api import EngineSpec, LatticeSpec, RunSpec

from .journal import JOURNAL_NAME, Journal, job_table


def _specs(args):
    """N mixed submissions: ``args.k`` coalescible multispin jobs plus
    two odd ones (different engine / lattice), all counter-based so
    digests are chunk-grid-invariant."""
    out = []
    for i in range(args.k):
        out.append(RunSpec(
            lattice=LatticeSpec(n=args.n, m=args.n),
            engine=EngineSpec("multispin"),
            temperature=2.0 + 0.1 * i, seed=20 + i))
    out.append(RunSpec(lattice=LatticeSpec(n=2 * args.n, m=2 * args.n),
                       engine=EngineSpec("bitplane"),
                       temperature=2.3, seed=91))
    out.append(RunSpec(lattice=LatticeSpec(n=args.n, m=args.n),
                       engine=EngineSpec("basic_philox"),
                       temperature=1.8, seed=92))
    return out


def _reference_digests(specs, sweeps):
    from repro.api import Session
    refs = []
    for spec in specs:
        s = Session.open(spec)
        s.run(sweeps)
        refs.append(s.state_digest())
    return refs


def _server_cmd(args, workdir, drain_on_idle):
    cmd = [sys.executable, "-m", "repro", "serve", workdir,
           "--chunk", str(args.chunk),
           "--max-batch", str(args.max_batch),
           "--ckpt-every-sweeps", str(args.chunk),
           "--poll", "0.05"]
    if drain_on_idle:
        cmd.append("--drain-on-idle")
    return cmd


def _start_server(args, workdir, drain_on_idle=False):
    proc = subprocess.Popen(_server_cmd(args, workdir, drain_on_idle),
                            text=True, stdout=subprocess.PIPE,
                            stderr=subprocess.STDOUT)
    ep = os.path.join(workdir, "serve.json")
    deadline = time.monotonic() + args.timeout
    while time.monotonic() < deadline:
        if os.path.exists(ep):
            # the endpoint file must name THIS process (a restart
            # overwrites the previous server's file)
            with open(ep) as f:
                if json.load(f).get("pid") == proc.pid:
                    return proc
        if proc.poll() is not None:
            out, _ = proc.communicate()
            raise SystemExit(f"server died during startup "
                             f"(exit {proc.returncode}):\n{out}")
        time.sleep(0.05)
    proc.kill()
    raise SystemExit("server did not write serve.json in time")


def _journal_records(workdir):
    j = Journal(os.path.join(workdir, JOURNAL_NAME))
    try:
        return list(j.records)
    finally:
        j.close()


def _phase_crash(args) -> None:
    from .client import ServeClient
    workdir = os.path.join(args.workdir, "crash")
    shutil.rmtree(workdir, ignore_errors=True)
    os.makedirs(workdir, exist_ok=True)

    specs = _specs(args)
    print(f"# [1/2] crash drill: {len(specs)} jobs, computing "
          f"reference digests in-process", flush=True)
    refs = _reference_digests(specs, args.sweeps)

    proc = _start_server(args, workdir)
    client = ServeClient(workdir)
    jids = [client.submit({"spec": s.to_dict(),
                           "sweeps": args.sweeps}) for s in specs]
    print(f"# submitted {jids}", flush=True)

    # SIGKILL as soon as the first batch starts: no handler, no flush
    deadline = time.monotonic() + args.timeout
    while time.monotonic() < deadline:
        if any(r.get("kind") == "start"
               for r in _journal_records(workdir)):
            break
        time.sleep(0.02)
    proc.send_signal(signal.SIGKILL)
    proc.wait(timeout=args.timeout)
    print(f"# SIGKILLed server pid {proc.pid}", flush=True)

    print("# restarting with --drain-on-idle", flush=True)
    proc = _start_server(args, workdir, drain_on_idle=True)
    out, _ = proc.communicate(timeout=args.timeout)
    print(out, end="", flush=True)
    if proc.returncode != 0:
        raise SystemExit(f"restarted server exited "
                         f"{proc.returncode}, want 0 (drained idle)")

    records = _journal_records(workdir)
    submits, dones = job_table(records)  # raises on duplicate done
    missing = [j for j in jids if j not in dones]
    if missing:
        raise SystemExit(f"jobs lost across the kill: {missing}")
    for jid, spec, want in zip(jids, specs, refs):
        done = dones[jid]
        if done["status"] != "completed":
            raise SystemExit(f"{jid} finished {done['status']}: "
                             f"{done.get('error')}")
        if done["digest"] != want:
            raise SystemExit(
                f"{jid} ({spec.engine.name}): digest "
                f"{done['digest']} != direct-Session reference "
                f"{want}")
    print(f"# crash drill OK: {len(jids)} jobs exactly-once, every "
          f"digest bit-identical to a direct run", flush=True)


def _phase_coalesce(args) -> None:
    from .client import ServeClient
    workdir = os.path.join(args.workdir, "coalesce")
    shutil.rmtree(workdir, ignore_errors=True)
    os.makedirs(workdir, exist_ok=True)
    print(f"# [2/2] coalescing drill: {args.k} compatible specs "
          f"behind a blocker", flush=True)

    # chunk >= sweeps: every batch is exactly one compiled dispatch
    co_args = argparse.Namespace(**{**vars(args),
                                    "chunk": args.sweeps})
    proc = _start_server(co_args, workdir, drain_on_idle=True)
    client = ServeClient(workdir)
    blocker = RunSpec(lattice=LatticeSpec(n=2 * args.n, m=2 * args.n),
                      engine=EngineSpec("multispin"),
                      temperature=2.5, seed=7)
    bid = client.submit({"spec": blocker.to_dict(),
                         "sweeps": args.sweeps})
    jids = [client.submit({"spec": s.to_dict(),
                           "sweeps": args.sweeps})
            for s in _specs(args)[:args.k]]
    out, _ = proc.communicate(timeout=args.timeout)
    print(out, end="", flush=True)
    if proc.returncode != 0:
        raise SystemExit(f"coalesce server exited {proc.returncode}")

    starts = [r for r in _journal_records(workdir)
              if r.get("kind") == "start"]
    fused = [s for s in starts if set(jids) <= set(s["jobs"])]
    if not fused:
        grouping = [s["jobs"] for s in starts]
        raise SystemExit(
            f"jobs {jids} did not coalesce into one batch; start "
            f"records grouped them as {grouping}")
    with open(os.path.join(workdir, "metrics.json")) as f:
        counters = json.load(f)["counters"]
    dispatches = counters.get("dispatches", 0)
    want = len(starts)  # one compiled dispatch per batch
    if dispatches != want:
        raise SystemExit(
            f"dispatches={dispatches}, want {want} (one per batch "
            f"at chunk >= sweeps); batches: "
            f"{[s['batch'] for s in starts]}")
    _ = bid
    print(f"# coalescing OK: {args.k} specs + 1 blocker ran as "
          f"{len(starts)} batches / {dispatches} compiled dispatches",
          flush=True)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.serve.smoke",
        description="sweep-farm crash + coalescing drill")
    ap.add_argument("--workdir", default="results/serve_smoke")
    ap.add_argument("--n", type=int, default=16,
                    help="coalescible-job lattice size")
    ap.add_argument("--k", type=int, default=4,
                    help="coalescible multispin jobs")
    ap.add_argument("--sweeps", type=int, default=192)
    ap.add_argument("--chunk", type=int, default=32)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--timeout", type=float, default=600.0,
                    help="per-wait wall-clock budget (s)")
    args = ap.parse_args(argv)
    _phase_crash(args)
    _phase_coalesce(args)
    print("serve smoke OK: crash safety + coalescing verified")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
