"""The sweep farm: exactly-once job execution over a durable journal.

``SweepFarm`` is the whole service in one in-process object (the HTTP
front-end below is a thin threaded shell around it; tests and the
throughput bench drive the farm directly).  The contract (DESIGN.md
S14):

* **exactly-once** -- a submission is journaled (fsync'd) BEFORE it is
  acked; a completion is journaled BEFORE the job is reported
  terminal.  Killing the process at any point -- SIGKILL included --
  loses nothing: construction replays the journal, re-queues every
  acked-but-unfinished job, and never re-runs a job with a ``done``
  record.  Results are bit-reproducible (counter-based engines), so
  re-running an interrupted job from its supervised checkpoint -- or
  from scratch -- yields the identical digest;

* **coalescing** -- compatible queued jobs fuse into one vmapped
  ensemble dispatch (``repro.serve.scheduler``); a compiled-runner
  pool keyed by dispatch shape (``_EnsembleRunner.rebind``) makes the
  steady state one compiled executable per shape, k specs per
  dispatch -- the ``dispatches`` telemetry counter is the proof;

* **robustness** -- admission is typed (never a crash), the queue is
  bounded (backpressure), per-job timeouts fail work instead of
  wedging it, dispatch faults ride the ``resilience.degrade`` retry
  path, and SIGTERM drains gracefully: stop admitting, checkpoint the
  in-flight batch at the next chunk boundary, exit 3 (the
  ``--supervise`` preemption convention).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Dict, List, Optional

import repro.telemetry as tel
from repro.resilience.errors import SupervisorError

from .errors import (AdmissionError, DrainingError, JournalError,
                     QueueFullError)
from .journal import JOURNAL_NAME, Journal, job_table
from .scheduler import Batch, Job, parse_envelope, plan_batches

#: module-held references survive REGISTRY.reset()
SUBMITTED = tel.REGISTRY.counter("serve.submitted")
REJECTED = tel.REGISTRY.counter("serve.rejected")
COMPLETED = tel.REGISTRY.counter("serve.completed")
FAILED = tel.REGISTRY.counter("serve.failed")
BATCHES = tel.REGISTRY.counter("serve.batches")
COALESCED = tel.REGISTRY.counter("serve.coalesced")
CACHE_HITS = tel.REGISTRY.counter("serve.cache_hit")
CACHE_MISSES = tel.REGISTRY.counter("serve.cache_miss")

#: default supervisor chunk for farm batches (sweeps between control
#: points: drain latency and deadline granularity)
DEFAULT_CHUNK = 64


class SweepFarm:
    """See the module docstring; construction RECOVERS the directory."""

    def __init__(self, directory: str, *, max_queue: int = 64,
                 max_batch: int = 8, chunk: int = DEFAULT_CHUNK,
                 ckpt_every_sweeps: int = 0, keep: int = 3):
        self.dir = directory
        self.max_queue = max_queue
        self.max_batch = max_batch
        self.chunk = chunk
        self.ckpt_every_sweeps = ckpt_every_sweeps
        self.keep = keep
        self.results_dir = os.path.join(directory, "results")
        self.batches_dir = os.path.join(directory, "batches")
        os.makedirs(self.results_dir, exist_ok=True)
        os.makedirs(self.batches_dir, exist_ok=True)
        # re-entrant: the executor thread journals while holding the
        # lock from nested paths (step -> _fail_expired -> _finish)
        self._lock = threading.RLock()
        self._work = threading.Condition(self._lock)
        self._draining = threading.Event()
        self._current: Optional[Batch] = None
        self._expired_stop = False
        self._runner_pool: dict = {}
        self.journal = Journal(os.path.join(directory, JOURNAL_NAME))
        self.jobs: Dict[str, Job] = {}
        self._next_seq = 1
        self._recover()

    # -- recovery ------------------------------------------------------------
    def _recover(self) -> None:
        submits, dones = job_table(self.journal.records)
        for jid, r in submits.items():
            spec, sweeps, timeout_s = parse_envelope(
                {"spec": r["spec"], "sweeps": r["sweeps"],
                 "timeout_s": r.get("timeout_s")})
            job = Job(id=jid, spec=spec, sweeps=sweeps,
                      timeout_s=timeout_s, submitted_t=r["t"])
            done = dones.get(jid)
            if done is not None:
                job.status = done["status"]
                job.digest = done.get("digest")
                job.error = done.get("error")
                job.summary = done.get("summary", {})
                self._write_result(job)  # regenerable from the journal
            self.jobs[jid] = job
            self._next_seq = max(self._next_seq,
                                 int(jid.lstrip("j")) + 1)
        if submits:
            tel.instant("serve.recover", dir=self.dir,
                        jobs=len(submits), done=len(dones),
                        requeued=len(submits) - len(dones))
        self._gc_batch_dirs()

    def _gc_batch_dirs(self) -> None:
        """Drop batch workdirs no replanned batch will ever resume
        (their jobs all reached ``done`` before the crash); the live
        ones keep their checkpoints for the resume path."""
        queued = [j for j in self.jobs.values() if j.status == "queued"]
        live = {b.id for b in plan_batches(queued, self.max_batch)}
        try:
            stale = [d for d in os.listdir(self.batches_dir)
                     if d not in live]
        except FileNotFoundError:
            return
        for d in stale:
            shutil.rmtree(os.path.join(self.batches_dir, d),
                          ignore_errors=True)

    # -- admission -----------------------------------------------------------
    def submit(self, doc) -> str:
        """Admit one submission document; returns the job id.  The
        submit record is fsync'd before this returns -- an acked job
        survives any crash.  Raises :class:`AdmissionError` /
        :class:`QueueFullError` / :class:`DrainingError`."""
        try:
            spec, sweeps, timeout_s = parse_envelope(doc)
        except AdmissionError:
            REJECTED.inc()
            raise
        with self._work:
            if self._draining.is_set():
                REJECTED.inc()
                raise DrainingError(
                    "server is draining; not admitting new work")
            depth = sum(1 for j in self.jobs.values()
                        if not j.terminal)
            if depth >= self.max_queue:
                REJECTED.inc()
                raise QueueFullError(
                    f"queue at capacity ({depth}/{self.max_queue} "
                    f"jobs outstanding); retry later")
            jid = f"j{self._next_seq:06d}"
            self._next_seq += 1
            now = time.time()
            self.journal.append({"kind": "submit", "job": jid,
                                 "spec": spec.to_dict(),
                                 "sweeps": sweeps,
                                 "timeout_s": timeout_s, "t": now})
            self.jobs[jid] = Job(id=jid, spec=spec, sweeps=sweeps,
                                 timeout_s=timeout_s, submitted_t=now)
            SUBMITTED.inc()
            self._work.notify_all()
            return jid

    # -- introspection -------------------------------------------------------
    def job(self, jid: str) -> Optional[dict]:
        with self._lock:
            job = self.jobs.get(jid)
            return None if job is None else job.to_dict()

    def status(self) -> dict:
        with self._lock:
            by = {"queued": 0, "running": 0, "completed": 0,
                  "failed": 0}
            for j in self.jobs.values():
                by[j.status] += 1
            return {"jobs": by, "draining": self._draining.is_set(),
                    "max_queue": self.max_queue,
                    "max_batch": self.max_batch,
                    "runner_pool": len(self._runner_pool)}

    @property
    def idle(self) -> bool:
        """Every ACCEPTED job is terminal -- vacuously false with no
        jobs at all, so a ``--drain-on-idle`` server waits for its
        first submission instead of exiting at startup."""
        with self._lock:
            return bool(self.jobs) and all(j.terminal
                                           for j in self.jobs.values())

    # -- drain ---------------------------------------------------------------
    def request_drain(self) -> None:
        """Stop admitting; ask the in-flight batch to checkpoint and
        stop at its next chunk boundary.  Signal-handler safe."""
        self._draining.set()
        tel.instant("serve.drain", dir=self.dir)
        with self._work:
            self._work.notify_all()

    @property
    def draining(self) -> bool:
        return self._draining.is_set()

    # -- execution -----------------------------------------------------------
    def _queued(self) -> List[Job]:
        return [j for j in self.jobs.values() if j.status == "queued"]

    def _fail_expired(self) -> None:
        now = time.time()
        for j in self._queued():
            if j.expired(now):
                self._finish(j, "failed",
                             error=f"deadline exceeded: timeout_s="
                                   f"{j.timeout_s} elapsed before "
                                   f"dispatch")

    def _finish(self, job: Job, status: str, digest: str = None,
                summary: dict = None, error: str = None) -> None:
        """The ONLY path to a terminal state: journal the done record
        (fsync'd), then publish.  Guards exactly-once."""
        with self._lock:
            if job.terminal:
                raise JournalError(
                    f"job {job.id} is already {job.status}; refusing "
                    f"a second done record (exactly-once)")
            self.journal.append({"kind": "done", "job": job.id,
                                 "status": status, "digest": digest,
                                 "summary": summary or {},
                                 "error": error, "t": time.time()})
            job.status = status
            job.digest = digest
            job.summary = summary or {}
            job.error = error
            self._write_result(job)
        (COMPLETED if status == "completed" else FAILED).inc()

    def _write_result(self, job: Job) -> None:
        path = os.path.join(self.results_dir, f"{job.id}.json")
        if os.path.exists(path):
            return
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(job.to_dict(), f, indent=1, sort_keys=True)
            f.write("\n")
        os.replace(tmp, path)

    def _on_chunk(self, sup) -> None:
        if self._draining.is_set():
            sup.request_stop()
            return
        batch = self._current
        if batch is not None and batch.jobs and \
                all(j.expired(time.time()) for j in batch.jobs):
            self._expired_stop = True
            sup.request_stop()

    def _open_supervisor(self, batch: Batch, workdir: str):
        from repro.api.session import Session
        spec = batch.spec()
        session = None
        hit = False
        if batch.coalesced:
            from repro.ckpt import Checkpointer
            fresh = Checkpointer(workdir, keep=self.keep) \
                .latest_step() is None
            if fresh:
                runner = self._runner_pool.pop(batch.runner_key(),
                                               None)
                if runner is not None:
                    runner.rebind(spec)
                    session = Session(spec, runner=runner)
                    hit = True
            (CACHE_HITS if hit else CACHE_MISSES).inc()
        try:
            return _make_supervisor(
                spec, workdir, every_sweeps=self.ckpt_every_sweeps,
                chunk=self.chunk, keep=self.keep,
                install_signal_handlers=False,
                on_chunk=self._on_chunk, session=session)
        except SupervisorError:
            # a checkpoint from a DIFFERENT grouping (e.g. the farm's
            # max_batch changed across the restart): the work is lost,
            # correctness is not -- wipe and run fresh
            shutil.rmtree(workdir, ignore_errors=True)
            return _make_supervisor(
                spec, workdir, every_sweeps=self.ckpt_every_sweeps,
                chunk=self.chunk, keep=self.keep,
                install_signal_handlers=False,
                on_chunk=self._on_chunk, session=session)

    def _run_batch(self, batch: Batch) -> str:
        """Execute one batch; returns ``"completed"``, ``"preempted"``
        (drain: jobs stay queued for the restart), or ``"failed"``."""
        workdir = os.path.join(self.batches_dir, batch.id)
        jids = [j.id for j in batch.jobs]
        with self._lock:
            self.journal.append({"kind": "start", "batch": batch.id,
                                 "jobs": jids,
                                 "key": list(batch.key) if batch.key
                                 else None, "t": time.time()})
            for j in batch.jobs:
                j.status = "running"
        self._current = batch
        self._expired_stop = False
        BATCHES.inc()
        if batch.coalesced and len(batch.jobs) > 1:
            COALESCED.inc(len(batch.jobs))
        try:
            with tel.span("serve.batch", batch=batch.id, jobs=jids,
                          coalesced=batch.coalesced,
                          sweeps=batch.sweeps):
                sup = self._open_supervisor(batch, workdir)
                res = sup.run(batch.sweeps)
                session = sup.session
        except Exception as e:  # noqa: BLE001 -- a job must never
            # take the server down; the failure is the job's result
            for j in batch.jobs:
                self._finish(j, "failed",
                             error=f"{type(e).__name__}: {e}")
            shutil.rmtree(workdir, ignore_errors=True)
            return "failed"
        finally:
            self._current = None
        if res.status == "preempted":
            if self._expired_stop:
                for j in batch.jobs:
                    self._finish(j, "failed",
                                 error=f"deadline exceeded at sweep "
                                       f"{res.step_count}/"
                                       f"{batch.sweeps}")
                shutil.rmtree(workdir, ignore_errors=True)
                return "failed"
            with self._lock:  # drain: progress is checkpointed
                for j in batch.jobs:
                    j.status = "queued"
            return "preempted"
        import numpy as np
        mags = np.atleast_1d(np.asarray(session.magnetization()))
        for i, job in enumerate(batch.jobs):
            if batch.coalesced:
                digest = session.state_digest(member=i)
                abs_m = float(abs(mags[i]))
            else:
                digest = session.state_digest()
                abs_m = float(np.mean(np.abs(mags)))
            self._finish(job, "completed", digest=digest,
                         summary={"abs_m": abs_m,
                                  "step_count": res.step_count,
                                  "batch": batch.id,
                                  "coalesced": len(batch.jobs)})
        if batch.coalesced:
            self._runner_pool[batch.runner_key()] = session._runner
        shutil.rmtree(workdir, ignore_errors=True)
        return "completed"

    def step(self) -> bool:
        """Fail expired queued jobs, then run the next planned batch
        (if any); returns whether any work was done."""
        with self._lock:
            self._fail_expired()
            batches = plan_batches(self._queued(), self.max_batch)
        if not batches or self._draining.is_set():
            return False
        self._run_batch(batches[0])
        return True

    def run_until_idle(self) -> int:
        """Drive the queue to empty (in-process entry point for tests
        and the throughput bench); returns the number of batches run."""
        n = 0
        while not self._draining.is_set():
            if not self.step():
                break
            n += 1
        return n

    def serve_forever(self, poll: float = 0.25,
                      drain_on_idle: bool = False) -> int:
        """The executor loop (run on the MAIN thread so the supervisor
        chunk boundaries see drain requests promptly).  Returns the
        process exit code: 0 = drained with nothing outstanding,
        3 = drained with checkpointed work left (rerun to resume)."""
        while True:
            worked = self.step()
            if self._draining.is_set():
                break
            if worked:
                continue
            if drain_on_idle and self.idle:
                return 0
            with self._work:
                if not self._queued() and not self._draining.is_set():
                    self._work.wait(timeout=poll)
        return 3 if any(not j.terminal
                        for j in self.jobs.values()) else 0

    def write_metrics(self) -> str:
        """Snapshot the telemetry registry (dispatch + serve counters)
        to ``metrics.json`` -- the smoke drill's coalescing evidence."""
        path = os.path.join(self.dir, "metrics.json")
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(tel.REGISTRY.snapshot(), f, indent=1,
                      sort_keys=True)
            f.write("\n")
        os.replace(tmp, path)
        return path

    def close(self) -> None:
        self.journal.close()


def _make_supervisor(*args, **kwargs):
    """Late import: ``repro.resilience.supervisor`` imports the session
    layer, which imports the engine layer -- keep farm import light."""
    from repro.resilience import Supervisor
    return Supervisor(*args, **kwargs)


# ---------------------------------------------------------------------------
# HTTP front-end: a thin threaded shell over SweepFarm
# ---------------------------------------------------------------------------

#: endpoint discovery file the server writes into its directory
ENDPOINT_NAME = "serve.json"

#: AdmissionError -> 400, QueueFullError -> 429, DrainingError -> 503
_STATUS = {AdmissionError: 400, QueueFullError: 429,
           DrainingError: 503}


def _make_handler(farm: SweepFarm):
    from http.server import BaseHTTPRequestHandler

    class Handler(BaseHTTPRequestHandler):
        protocol_version = "HTTP/1.1"

        def _reply(self, code: int, doc: dict) -> None:
            body = json.dumps(doc).encode() + b"\n"
            self.send_response(code)
            self.send_header("Content-Type", "application/json")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def log_message(self, fmt, *args):  # quiet by default
            pass

        def do_GET(self):
            if self.path == "/v1/status":
                return self._reply(200, farm.status())
            if self.path.startswith("/v1/jobs/"):
                job = farm.job(self.path[len("/v1/jobs/"):])
                if job is None:
                    return self._reply(404, {"error": "unknown job"})
                return self._reply(200, job)
            return self._reply(404, {"error": f"no route {self.path}"})

        def do_POST(self):
            if self.path == "/v1/drain":
                farm.request_drain()
                return self._reply(200, {"draining": True})
            if self.path != "/v1/jobs":
                return self._reply(404,
                                   {"error": f"no route {self.path}"})
            try:
                n = int(self.headers.get("Content-Length", 0))
                doc = json.loads(self.rfile.read(n) or b"null")
            except (ValueError, json.JSONDecodeError) as e:
                REJECTED.inc()
                return self._reply(400, {"error": "AdmissionError",
                                         "detail": f"bad JSON: {e}"})
            try:
                jid = farm.submit(doc)
            except (AdmissionError, QueueFullError,
                    DrainingError) as e:
                return self._reply(_STATUS[type(e)],
                                   {"error": type(e).__name__,
                                    "detail": str(e)})
            return self._reply(200, {"job": jid})

    return Handler


def serve(directory: str, *, port: int = 0, poll: float = 0.25,
          drain_on_idle: bool = False, **farm_kwargs) -> int:
    """Run the farm with the HTTP front-end until drained; returns the
    exit code (0 done / 3 drained-preempted).  Installs SIGTERM/SIGINT
    handlers that trigger a graceful drain; writes ``serve.json``
    (host/port/pid) into the directory for client discovery and a
    final ``metrics.json`` snapshot on the way out."""
    import signal
    from http.server import ThreadingHTTPServer

    farm = SweepFarm(directory, **farm_kwargs)
    httpd = ThreadingHTTPServer(("127.0.0.1", port),
                                _make_handler(farm))
    endpoint = {"host": "127.0.0.1",
                "port": httpd.server_address[1],
                "pid": os.getpid()}
    ep_path = os.path.join(directory, ENDPOINT_NAME)
    with open(ep_path + ".tmp", "w") as f:
        json.dump(endpoint, f)
    os.replace(ep_path + ".tmp", ep_path)

    def _drain_handler(signum, frame):
        farm.request_drain()

    prev = {}
    for sig in (signal.SIGTERM, signal.SIGINT):
        prev[sig] = signal.signal(sig, _drain_handler)
    t = threading.Thread(target=httpd.serve_forever, daemon=True)
    t.start()
    print(f"# serving {directory} on "
          f"http://127.0.0.1:{endpoint['port']} (pid {os.getpid()})",
          flush=True)
    try:
        code = farm.serve_forever(poll=poll,
                                  drain_on_idle=drain_on_idle)
    finally:
        for sig, h in prev.items():
            signal.signal(sig, h)
        httpd.shutdown()
        farm.write_metrics()
        farm.close()
    n_done = sum(1 for j in farm.jobs.values() if j.terminal)
    print(f"# drained: {n_done}/{len(farm.jobs)} jobs terminal, "
          f"exit {code}", flush=True)
    return code
