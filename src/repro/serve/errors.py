"""Typed exceptions of the sweep-farm service (DESIGN.md S14).

Admission failures are part of the API, not crashes: every malformed
or unacceptable submission maps to one of these types, and the HTTP
front-end maps each type to a status code (400/429/503).  Nothing a
client sends may take the server down -- that is the robustness
contract the admission tests pin.
"""
from __future__ import annotations


class ServeError(RuntimeError):
    """Base class of the serve subsystem's own failures."""


class AdmissionError(ServeError):
    """A submission is malformed or invalid (bad JSON envelope, spec
    that fails :class:`~repro.api.spec.RunSpec` validation, missing
    sweep target).  HTTP 400."""


class QueueFullError(ServeError):
    """The bounded submission queue is at capacity -- backpressure,
    not data loss: the client retries later.  HTTP 429."""


class DrainingError(ServeError):
    """The server is draining (SIGTERM or ``/v1/drain``) and no longer
    admits work.  HTTP 503."""


class JournalError(ServeError):
    """The job journal cannot be read or written (unrecoverable framing
    damage in the middle of the file, I/O failure on append)."""
