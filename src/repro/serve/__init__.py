"""``repro.serve`` -- the fault-tolerant sweep-farm service.

A long-running server (``python -m repro serve DIR``) that accepts
``RunSpec`` JSON submissions and executes them with exactly-once,
crash-safe semantics (DESIGN.md S14):

* :mod:`~repro.serve.journal` -- the durable write-ahead job journal
  (CRC-framed, fsync'd, torn-write recovery);
* :mod:`~repro.serve.scheduler` -- typed admission and the coalescer
  that fuses compatible specs into one vmapped ensemble dispatch;
* :mod:`~repro.serve.server` -- :class:`SweepFarm` (the in-process
  service object) and the stdlib HTTP front-end;
* :mod:`~repro.serve.client` -- :class:`ServeClient`, the matching
  submit/poll/drain client;
* :mod:`~repro.serve.smoke` -- the CI crash drill: submit, SIGKILL,
  restart, assert every job completes with digests bit-identical to
  direct ``Session`` runs.

``SweepFarm``/``ServeClient`` are loaded lazily (PEP 562): the server
module pulls in telemetry and, at run time, the session/engine stack.
"""
from __future__ import annotations

from .errors import (AdmissionError, DrainingError, JournalError,
                     QueueFullError, ServeError)

__all__ = [
    "ServeError", "AdmissionError", "QueueFullError",
    "DrainingError", "JournalError",
    "Journal", "SweepFarm", "ServeClient",
]


def __getattr__(name: str):
    if name == "Journal":
        from .journal import Journal
        return Journal
    if name == "SweepFarm":
        from .server import SweepFarm
        return SweepFarm
    if name == "ServeClient":
        from .client import ServeClient
        return ServeClient
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
