"""Client for the sweep-farm HTTP API (stdlib urllib only).

``ServeClient`` discovers the endpoint from the farm directory's
``serve.json`` (or takes an explicit URL), and maps the server's typed
rejections back to the same exception types the in-process farm
raises -- a caller handles ``QueueFullError`` identically whether it
talks to a ``SweepFarm`` object or a server across a socket.

    client = ServeClient("results/farm")
    jid = client.submit({"spec": spec.to_dict(), "sweeps": 512})
    client.wait([jid], timeout=300)
    print(client.job(jid)["digest"])
"""
from __future__ import annotations

import json
import os
import time
import urllib.error
import urllib.request
from typing import List, Optional

from .errors import (AdmissionError, DrainingError, QueueFullError,
                     ServeError)
from .server import ENDPOINT_NAME

#: HTTP status -> the typed exception the in-process farm would raise
_ERRORS = {400: AdmissionError, 429: QueueFullError,
           503: DrainingError}


class ServeClient:
    def __init__(self, directory_or_url: str,
                 timeout: float = 30.0):
        if directory_or_url.startswith("http://") \
                or directory_or_url.startswith("https://"):
            self.base = directory_or_url.rstrip("/")
        else:
            ep = os.path.join(directory_or_url, ENDPOINT_NAME)
            with open(ep) as f:
                d = json.load(f)
            self.base = f"http://{d['host']}:{d['port']}"
        self.timeout = timeout

    def _call(self, method: str, path: str,
              body: Optional[dict] = None) -> dict:
        data = None if body is None else json.dumps(body).encode()
        req = urllib.request.Request(
            self.base + path, data=data, method=method,
            headers={"Content-Type": "application/json"})
        try:
            with urllib.request.urlopen(req,
                                        timeout=self.timeout) as r:
                return json.loads(r.read())
        except urllib.error.HTTPError as e:
            doc = {}
            try:
                doc = json.loads(e.read())
            except (json.JSONDecodeError, ValueError):
                pass
            exc = _ERRORS.get(e.code, ServeError)
            raise exc(doc.get("detail",
                              f"HTTP {e.code} on {path}")) from e
        except urllib.error.URLError as e:
            raise ServeError(
                f"server unreachable at {self.base}: {e}") from e

    # -- the API -------------------------------------------------------------
    def submit(self, doc: dict) -> str:
        """Submit an envelope (``{"spec":..., "sweeps":...}``) or bare
        RunSpec document; returns the journaled job id."""
        return self._call("POST", "/v1/jobs", doc)["job"]

    def job(self, jid: str) -> dict:
        return self._call("GET", f"/v1/jobs/{jid}")

    def status(self) -> dict:
        return self._call("GET", "/v1/status")

    def drain(self) -> dict:
        """Ask the server to drain (stop admitting, checkpoint the
        in-flight batch, exit 3)."""
        return self._call("POST", "/v1/drain")

    def wait(self, jids: List[str], timeout: float = 300.0,
             poll: float = 0.25) -> List[dict]:
        """Poll until every listed job is terminal; returns their
        final records (order preserved).  Raises on timeout."""
        deadline = time.monotonic() + timeout
        while True:
            docs = [self.job(j) for j in jids]
            if all(d["status"] in ("completed", "failed")
                   for d in docs):
                return docs
            if time.monotonic() > deadline:
                pend = [d["id"] for d in docs
                        if d["status"] not in ("completed", "failed")]
                raise ServeError(
                    f"timeout waiting for jobs {pend}")
            time.sleep(poll)
