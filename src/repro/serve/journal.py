"""Durable write-ahead job journal: CRC-framed, fsync'd, replayable.

The journal is the farm's ONLY durable source of truth (DESIGN.md
S14).  One append-only text file, one record per line:

    <crc32c hex8> <compact JSON object>\\n

The checksum (``repro.resilience.integrity.crc32c`` over the JSON
bytes) frames each record independently, so recovery needs no global
index: replay walks the file line by line and stops at the first line
that is torn (no trailing newline), malformed, or checksum-broken.
Everything after the damage is BY CONSTRUCTION unacknowledged -- a
record is fsync'd before the caller acts on it (``append`` returns
only after ``os.fsync``), so a torn tail can only be the record that
was being written when the process died.

Recovery truncates the file back to the last whole record and
preserves the damaged tail bytes in a ``journal.torn.<k>`` sidecar
(quarantine ethos: never destroy evidence).  Damage in the MIDDLE of
the file -- a good line after a bad one -- is not a crash topology an
append-only fsync'd writer can produce; that is real corruption and
raises :class:`~repro.serve.errors.JournalError` instead of silently
dropping acknowledged records.

Record kinds (the scheduler's protocol, validated loosely here --
the journal stores dicts, the farm assigns meaning):

* ``submit`` -- an accepted job: id, spec document, sweep target,
  optional timeout; fsync'd BEFORE the client is acked, so an acked
  job is never lost;
* ``start``  -- a dispatch batch began: batch id, member job ids,
  coalesce key (informational: replay does not need it, the smoke
  drill asserts coalescing from it);
* ``done``   -- a job reached a terminal state: completed (with
  digest + summary) or failed (with error text).  At most one per
  job -- the exactly-once invariant replay enforces.
"""
from __future__ import annotations

import json
import os
from typing import Iterator, List, Optional, Tuple

from repro.resilience import integrity

from .errors import JournalError

#: journal file name inside the farm directory
JOURNAL_NAME = "journal.jsonl"


def _frame(record: dict) -> bytes:
    body = json.dumps(record, sort_keys=True,
                      separators=(",", ":")).encode()
    crc = integrity.crc32c(body)
    return f"{crc:08x} ".encode() + body + b"\n"


def _parse_line(line: bytes) -> Optional[dict]:
    """The record a complete line holds, or ``None`` when the line is
    damaged (bad frame, bad checksum, bad JSON)."""
    if not line.endswith(b"\n"):
        return None
    try:
        crc_hex, body = line[:-1].split(b" ", 1)
        if len(crc_hex) != 8:
            return None
        want = int(crc_hex, 16)
    except ValueError:
        return None
    if integrity.crc32c(body) != want:
        return None
    try:
        record = json.loads(body)
    except json.JSONDecodeError:
        return None
    return record if isinstance(record, dict) else None


class Journal:
    """Append-only journal over one file; construction RECOVERS.

    ``Journal(path)`` replays the existing file (if any), truncates a
    torn tail (keeping it in a sidecar), and opens for appending; the
    replayed records are in :attr:`records`.  ``append`` is durable:
    it returns only after the bytes are flushed and fsync'd.
    """

    def __init__(self, path: str):
        self.path = path
        self.records: List[dict] = []
        self.recovered_tail: Optional[str] = None  # sidecar path
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        self._recover()
        self._f = open(path, "ab")

    # -- recovery ------------------------------------------------------------
    def _recover(self) -> None:
        if not os.path.exists(self.path):
            return
        with open(self.path, "rb") as f:
            data = f.read()
        good_end = 0
        records: List[dict] = []
        pos = 0
        while pos < len(data):
            nl = data.find(b"\n", pos)
            line = data[pos:] if nl < 0 else data[pos:nl + 1]
            record = _parse_line(line)
            if record is None:
                break
            records.append(record)
            pos = nl + 1
            good_end = pos
        tail = data[good_end:]
        if tail:
            # every line after the damage must ALSO be damaged-or-empty
            # territory; a valid record after a torn one means the file
            # was corrupted in place, not torn by a crash
            rest = tail.split(b"\n")
            for i, cand in enumerate(rest[1:], start=1):
                if cand and _parse_line(cand + b"\n") is not None:
                    raise JournalError(
                        f"{self.path}: valid record found AFTER damaged "
                        f"bytes at offset {good_end} -- mid-file "
                        f"corruption, not a torn append; refusing to "
                        f"drop acknowledged records")
            self.recovered_tail = self._quarantine_tail(tail)
            with open(self.path, "r+b") as f:
                f.truncate(good_end)
                f.flush()
                os.fsync(f.fileno())
        self.records = records

    def _quarantine_tail(self, tail: bytes) -> str:
        k = 0
        while True:
            side = f"{self.path}.torn.{k}"
            if not os.path.exists(side):
                break
            k += 1
        with open(side, "wb") as f:
            f.write(tail)
        return side

    # -- append --------------------------------------------------------------
    def append(self, record: dict) -> dict:
        """Durably append one record (flush + fsync before returning);
        returns the record for chaining."""
        if not isinstance(record, dict) or "kind" not in record:
            raise JournalError(
                f"journal records are dicts with a 'kind', got "
                f"{record!r}")
        try:
            self._f.write(_frame(record))
            self._f.flush()
            os.fsync(self._f.fileno())
        except OSError as e:
            raise JournalError(
                f"{self.path}: append failed: {e}") from e
        self.records.append(record)
        return record

    def close(self) -> None:
        self._f.close()

    def __enter__(self) -> "Journal":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def replay(path: str) -> Iterator[dict]:
    """Read-only replay of the whole records stream (recovery included,
    via a throwaway :class:`Journal`); what the smoke drill and tests
    use to inspect a farm directory without opening it for writing."""
    j = Journal(path)
    try:
        yield from j.records
    finally:
        j.close()


def job_table(records) -> Tuple[dict, dict]:
    """Fold a record stream into ``(jobs, dones)``:

    ``jobs``  -- job id -> its ``submit`` record, submission order
    preserved (dict insertion order);
    ``dones`` -- job id -> its first ``done`` record.  A second done
    for the same job violates exactly-once and raises."""
    jobs: dict = {}
    dones: dict = {}
    for r in records:
        kind = r.get("kind")
        if kind == "submit":
            jid = r["job"]
            if jid in jobs:
                raise JournalError(
                    f"duplicate submit record for job {jid}")
            jobs[jid] = r
        elif kind == "done":
            jid = r["job"]
            if jid not in jobs:
                raise JournalError(
                    f"done record for unknown job {jid}")
            if jid in dones:
                raise JournalError(
                    f"duplicate done record for job {jid} -- "
                    f"exactly-once violated")
            dones[jid] = r
    return jobs, dones
