"""``python -m repro.telemetry`` -- inspect exported traces.

    python -m repro.telemetry summarize trace.json
    python -m repro.telemetry validate  trace.jsonl

``summarize`` renders the per-phase breakdown (span type -> count,
total/mean/min/max ms), the counters, and the gauges of a trace written
by ``python -m repro run --trace`` or ``benchmarks/run.py --trace``.
Both subcommands validate against :mod:`repro.telemetry.schema` first
and exit 1 on a malformed document -- CI runs ``summarize`` on the
bench-smoke trace artifact so a schema regression fails the build.

Reads both export formats: Chrome trace-event JSON (``traceEvents``)
and the JSONL stream (one ``kind``-tagged object per line).
"""
from __future__ import annotations

import argparse
import json
import sys

from .schema import TelemetryError, validate_snapshot, validate_trace


def _load(path: str) -> dict:
    """Either format -> the Chrome-document shape ``{traceEvents,
    metrics?, meta?}`` (JSONL spans/instants are re-rendered as X/i
    events so downstream code has one shape)."""
    if not path.endswith(".jsonl"):
        with open(path) as f:
            return json.load(f)
    doc: dict = {"traceEvents": [], "displayTimeUnit": "ms"}
    with open(path) as f:
        for lineno, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                obj = json.loads(line)
            except json.JSONDecodeError as e:
                raise TelemetryError(f"{path}:{lineno}: not JSON: {e}")
            kind = obj.pop("kind", None)
            if kind == "meta":
                doc["meta"] = obj
            elif kind == "metrics":
                doc["metrics"] = obj
            elif kind in ("span", "instant"):
                ev = {"name": obj["name"], "cat": "repro",
                      "ph": "X" if kind == "span" else "i",
                      "ts": obj["ts_us"], "pid": 0,
                      "tid": obj.get("tid", 0),
                      "args": dict(obj.get("args", {}),
                                   depth=obj.get("depth", 0))}
                if kind == "span":
                    ev["dur"] = obj["dur_us"]
                else:
                    ev["s"] = "t"
                doc["traceEvents"].append(ev)
            else:
                raise TelemetryError(
                    f"{path}:{lineno}: unknown kind {kind!r}")
    doc["traceEvents"].sort(key=lambda ev: ev["ts"])
    return doc


def _validate(doc: dict) -> None:
    validate_trace(doc)
    if "metrics" in doc:
        validate_snapshot(doc["metrics"], ctx="metrics")


def _fmt_ms(us: float) -> str:
    return f"{us / 1e3:10.3f}"


def summarize(doc: dict, out=None) -> None:
    out = out if out is not None else sys.stdout
    spans: dict = {}
    instants: dict = {}
    for ev in doc["traceEvents"]:
        if ev["ph"] == "X":
            durs = spans.setdefault(ev["name"], [])
            durs.append(ev["dur"])
        else:
            instants[ev["name"]] = instants.get(ev["name"], 0) + 1

    print("== spans ==", file=out)
    if spans:
        print(f"{'phase':<24}{'count':>7}{'total ms':>11}{'mean ms':>11}"
              f"{'min ms':>11}{'max ms':>11}", file=out)
        for name in sorted(spans, key=lambda n: -sum(spans[n])):
            d = spans[name]
            print(f"{name:<24}{len(d):>7}{_fmt_ms(sum(d))}"
                  f"{_fmt_ms(sum(d) / len(d))}{_fmt_ms(min(d))}"
                  f"{_fmt_ms(max(d))}", file=out)
    else:
        print("(no spans -- was tracing enabled?)", file=out)
    if instants:
        print("== instants ==", file=out)
        for name in sorted(instants):
            print(f"{name:<24}{instants[name]:>7}", file=out)

    metrics = doc.get("metrics")
    if metrics:
        if metrics.get("counters"):
            print("== counters ==", file=out)
            for name, v in sorted(metrics["counters"].items()):
                print(f"{name:<24}{v:>18}", file=out)
        if metrics.get("gauges"):
            print("== gauges ==", file=out)
            for name, v in sorted(metrics["gauges"].items()):
                print(f"{name:<24}{v:>18.6g}", file=out)
    meta = doc.get("meta")
    if meta:
        print("== meta ==", file=out)
        for k, v in sorted(meta.items()):
            print(f"{k:<24}{v}", file=out)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.telemetry",
        description="Inspect repro telemetry trace exports")
    sub = ap.add_subparsers(dest="cmd", required=True)
    for name, help_ in (("summarize", "validate + per-phase breakdown"),
                        ("validate", "schema check only (exit 0/1)")):
        p = sub.add_parser(name, help=help_)
        p.add_argument("trace", help="trace .json (Chrome) or .jsonl")
    args = ap.parse_args(argv)
    try:
        doc = _load(args.trace)
        _validate(doc)
    except (TelemetryError, OSError, KeyError,
            json.JSONDecodeError) as e:
        print(f"INVALID {args.trace}: {e}", file=sys.stderr)
        return 1
    if args.cmd == "summarize":
        summarize(doc)
    else:
        n = len(doc["traceEvents"])
        print(f"OK {args.trace}: {n} events, "
              f"{len({e['name'] for e in doc['traceEvents']})} span "
              f"types, metrics={'yes' if 'metrics' in doc else 'no'}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
