"""Span tracer: monotonic-clock phase timing with explicit fencing.

A *span* is one named, attributed, nested interval of host wall-clock
(``time.perf_counter_ns``) around a phase of the execution stack --
``spec.validate``, ``session.open``, ``measure_scan``, ``dispatch``,
``ckpt.save`` ... (taxonomy: DESIGN.md S12).  Because JAX dispatch is
asynchronous, a span that times device work must *fence* before it
closes: ``sp.fence(out)`` remembers the output pytree and the tracer
``jax.block_until_ready``-s it on exit, so the recorded duration covers
the device work, not just the enqueue.  Fencing (like every other part
of a span) is a NO-OP while tracing is disabled -- the default -- so
instrumented code keeps JAX's async pipelining when nobody is looking.

Export formats:

* ``export_chrome(path)`` -- Chrome trace-event JSON (``traceEvents``
  complete/instant events), loadable in Perfetto / ``chrome://tracing``
  as-is; extra top-level keys carry the metrics snapshot and run meta.
* ``export_jsonl(path)`` -- one JSON object per line (``kind: span |
  instant | metrics | meta``), for streaming consumers.

Span close also feeds a ``span_ms.<name>`` histogram in the metrics
registry, so the snapshot carries per-phase timing even without the
event list.  Thread-safe: the nesting stack is thread-local (the async
checkpoint writer records ``ckpt.write`` spans from its worker thread),
the event list is lock-guarded, and events carry their ``tid``.
"""
from __future__ import annotations

import json
import threading
import time
from typing import Any, Dict, List, Optional

from .metrics import REGISTRY


def _jsonable(v) -> Any:
    """Attribute values must survive ``json.dumps`` losslessly."""
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    if isinstance(v, (tuple, list)):
        return [_jsonable(x) for x in v]
    return str(v)


class _NullSpan:
    """The shared no-op handle yielded while tracing is disabled."""

    __slots__ = ()
    duration_ns: Optional[int] = None

    def set(self, **attrs) -> None:
        pass

    def fence(self, value) -> None:
        pass


NULL_SPAN = _NullSpan()


class SpanHandle:
    """Live span: ``set`` adds attributes, ``fence`` registers a pytree
    to block on before the close timestamp is taken; after the ``with``
    block exits, ``duration_ns`` holds the fenced wall-clock."""

    __slots__ = ("name", "attrs", "t0_ns", "depth", "tid", "_fence",
                 "duration_ns")

    def __init__(self, name: str, attrs: Dict[str, Any], t0_ns: int,
                 depth: int, tid: int):
        self.name = name
        self.attrs = attrs
        self.t0_ns = t0_ns
        self.depth = depth
        self.tid = tid
        self._fence = None
        self.duration_ns: Optional[int] = None

    def set(self, **attrs) -> None:
        for k, v in attrs.items():
            self.attrs[k] = _jsonable(v)

    def fence(self, value) -> None:
        self._fence = value


class _Scope:
    """Context manager returned by :meth:`Tracer.span`."""

    __slots__ = ("_tracer", "_handle")

    def __init__(self, tracer: "Tracer", handle):
        self._tracer = tracer
        self._handle = handle

    def __enter__(self):
        h = self._handle
        if h is not NULL_SPAN:
            self._tracer._push(h)
            h.t0_ns = time.perf_counter_ns()
        return h

    def __exit__(self, exc_type, exc, tb):
        h = self._handle
        if h is not NULL_SPAN:
            self._tracer._close(h, error=exc_type is not None)
        return False


class Tracer:
    """Collects span/instant events while ``enabled``; no-ops otherwise."""

    def __init__(self):
        self.enabled = False
        self._lock = threading.Lock()
        self._events: List[dict] = []
        self._tls = threading.local()
        self._origin_ns = time.perf_counter_ns()

    # -- lifecycle ----------------------------------------------------------
    def enable(self) -> None:
        self.enabled = True

    def disable(self) -> None:
        self.enabled = False

    def clear(self) -> None:
        with self._lock:
            self._events = []
        self._origin_ns = time.perf_counter_ns()

    # -- recording ----------------------------------------------------------
    def _stack(self) -> list:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    def _push(self, handle: SpanHandle) -> None:
        st = self._stack()
        handle.depth = len(st)
        st.append(handle)

    def span(self, name: str, **attrs) -> _Scope:
        """``with tracer.span("dispatch", engine="multispin") as sp:``

        Yields :data:`NULL_SPAN` while disabled.  Attributes are
        JSON-normalized at entry; ``sp.set(...)`` adds more, and
        ``sp.fence(out)`` makes the close wait for device completion.
        """
        if not self.enabled:
            return _Scope(self, NULL_SPAN)
        handle = SpanHandle(name,
                            {k: _jsonable(v) for k, v in attrs.items()},
                            0, 0, threading.get_ident())
        return _Scope(self, handle)

    def _close(self, handle: SpanHandle, error: bool = False) -> None:
        if handle._fence is not None:
            import jax
            jax.block_until_ready(handle._fence)
            handle._fence = None
        t1 = time.perf_counter_ns()
        st = self._stack()
        if st and st[-1] is handle:
            st.pop()
        handle.duration_ns = t1 - handle.t0_ns
        if error:
            handle.attrs["error"] = True
        event = {"kind": "span", "name": handle.name,
                 "ts_us": (handle.t0_ns - self._origin_ns) / 1e3,
                 "dur_us": handle.duration_ns / 1e3,
                 "depth": handle.depth, "tid": handle.tid,
                 "args": handle.attrs}
        with self._lock:
            self._events.append(event)
        REGISTRY.histogram(f"span_ms.{handle.name}").observe(
            handle.duration_ns / 1e6)

    def instant(self, name: str, **attrs) -> None:
        """A zero-duration annotation event (e.g. ``planner.decide``)."""
        if not self.enabled:
            return
        event = {"kind": "instant", "name": name,
                 "ts_us": (time.perf_counter_ns() - self._origin_ns) / 1e3,
                 "depth": len(self._stack()),
                 "tid": threading.get_ident(),
                 "args": {k: _jsonable(v) for k, v in attrs.items()}}
        with self._lock:
            self._events.append(event)

    # -- reading ------------------------------------------------------------
    @property
    def events(self) -> List[dict]:
        """Snapshot copy of the recorded events (chronological per
        thread; spans are appended at CLOSE time, so a parent span
        appears after its children)."""
        with self._lock:
            return list(self._events)

    def span_names(self) -> List[str]:
        return sorted({e["name"] for e in self.events})

    # -- export -------------------------------------------------------------
    def to_chrome(self, metrics: Optional[dict] = None,
                  meta: Optional[dict] = None) -> dict:
        """The Chrome trace-event document (Perfetto-loadable): every
        span as a ``ph: "X"`` complete event, instants as ``ph: "i"``;
        ``metrics``/``meta`` ride along as extra top-level keys that
        trace viewers ignore and ``summarize`` reads back."""
        trace_events = []
        for e in self.events:
            ev = {"name": e["name"], "cat": "repro",
                  "ph": "X" if e["kind"] == "span" else "i",
                  "ts": e["ts_us"], "pid": 0, "tid": e["tid"],
                  "args": dict(e["args"], depth=e["depth"])}
            if e["kind"] == "span":
                ev["dur"] = e["dur_us"]
            else:
                ev["s"] = "t"  # instant scope: thread
            trace_events.append(ev)
        # viewers sort by ts, but keep the file humanly chronological
        trace_events.sort(key=lambda ev: ev["ts"])
        doc = {"traceEvents": trace_events, "displayTimeUnit": "ms"}
        if metrics is not None:
            doc["metrics"] = metrics
        if meta is not None:
            doc["meta"] = meta
        return doc

    def export_chrome(self, path: str, metrics: Optional[dict] = None,
                      meta: Optional[dict] = None) -> str:
        with open(path, "w") as f:
            json.dump(self.to_chrome(metrics=metrics, meta=meta), f,
                      indent=1, sort_keys=True)
        return path

    def export_jsonl(self, path: str, metrics: Optional[dict] = None,
                     meta: Optional[dict] = None) -> str:
        with open(path, "w") as f:
            if meta is not None:
                f.write(json.dumps({"kind": "meta", **meta},
                                   sort_keys=True) + "\n")
            for e in self.events:
                f.write(json.dumps(e, sort_keys=True) + "\n")
            if metrics is not None:
                f.write(json.dumps({"kind": "metrics", **metrics},
                                   sort_keys=True) + "\n")
        return path


#: the process-global tracer every subsystem records into
TRACER = Tracer()
