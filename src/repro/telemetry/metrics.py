"""Metrics registry: counters, gauges, timing histograms (DESIGN.md S12).

The registry is the *always-on* half of the telemetry subsystem: a
counter increment is one locked integer add on a host-level code path
(once per compiled dispatch, never per sweep or per site), so the
counters stay correct whether or not span tracing is enabled -- the
dispatch-count contract of ``repro.analysis.measure`` is asserted
against them in tests and *measured* into every BENCH row.

Three instrument kinds, all process-global through :data:`REGISTRY`:

* :class:`Counter`   -- monotone int (dispatches, sweeps, spin_flips,
  philox_draws, planner decisions).  ``value`` reads, ``inc`` adds.
* :class:`Gauge`     -- last-written float (rolling flips/ns).
* :class:`Histogram` -- streaming count/sum/min/max of float samples;
  span close times feed ``span_ms.<name>`` histograms when tracing is
  enabled, so the snapshot carries a per-phase timing summary even
  without the event list.

``REGISTRY.snapshot()`` renders everything as one plain-JSON dict in
the validated schema of :mod:`repro.telemetry.schema` (the
``repro.perf.schema`` style: every emission validates before export).
``reset()`` zeroes instruments *in place* -- modules hold references to
their counters (e.g. ``repro.telemetry.DISPATCHES``), so the objects
must survive a reset.
"""
from __future__ import annotations

import math
import threading
from typing import Dict, Optional


class Counter:
    """Monotone integer counter; ``inc`` is host-side only (an increment
    inside a jit trace would run once, at trace time)."""

    __slots__ = ("name", "_value", "_lock")

    def __init__(self, name: str, lock: threading.Lock):
        self.name = name
        self._value = 0
        self._lock = lock

    def inc(self, n: int = 1) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name!r}: inc({n}) -- "
                             f"counters are monotone")
        with self._lock:
            self._value += int(n)

    @property
    def value(self) -> int:
        return self._value

    def _reset(self) -> None:
        with self._lock:
            self._value = 0


class Gauge:
    """Last-written float value; ``None`` until first ``set``."""

    __slots__ = ("name", "_value", "_lock")

    def __init__(self, name: str, lock: threading.Lock):
        self.name = name
        self._value: Optional[float] = None
        self._lock = lock

    def set(self, v: float) -> None:
        f = float(v)
        if not math.isfinite(f):
            raise ValueError(f"gauge {self.name!r}: non-finite {v!r}")
        with self._lock:
            self._value = f

    @property
    def value(self) -> Optional[float]:
        return self._value

    def _reset(self) -> None:
        with self._lock:
            self._value = None


class Histogram:
    """Streaming summary (count/sum/min/max) of float observations."""

    __slots__ = ("name", "count", "sum", "min", "max", "_lock")

    def __init__(self, name: str, lock: threading.Lock):
        self.name = name
        self._lock = lock
        self._zero()

    def _zero(self) -> None:
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, v: float) -> None:
        f = float(v)
        if not math.isfinite(f):
            raise ValueError(f"histogram {self.name!r}: non-finite {v!r}")
        with self._lock:
            self.count += 1
            self.sum += f
            self.min = min(self.min, f)
            self.max = max(self.max, f)

    def stats(self) -> dict:
        """``{count, sum, min, max, mean}``; empty histograms report
        only ``count=0`` (a min/max of +-inf is not JSON)."""
        with self._lock:
            if not self.count:
                return {"count": 0}
            return {"count": self.count, "sum": self.sum,
                    "min": self.min, "max": self.max,
                    "mean": self.sum / self.count}

    def _reset(self) -> None:
        with self._lock:
            self._zero()


class MetricsRegistry:
    """Name -> instrument map with get-or-create accessors.

    A name is permanently bound to its first-created kind; asking for a
    ``counter`` that exists as a ``gauge`` is a bug and raises.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    def _get(self, table, others, name: str, factory):
        if not isinstance(name, str) or not name:
            raise ValueError(f"metric name must be a non-empty string, "
                             f"got {name!r}")
        with self._lock:
            for other in others:
                if name in other:
                    raise ValueError(
                        f"metric {name!r} already registered as a "
                        f"different instrument kind")
            inst = table.get(name)
            if inst is None:
                inst = table[name] = factory(name, self._lock)
            return inst

    def counter(self, name: str) -> Counter:
        return self._get(self._counters,
                         (self._gauges, self._histograms), name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(self._gauges,
                         (self._counters, self._histograms), name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(self._histograms,
                         (self._counters, self._gauges), name, Histogram)

    def snapshot(self) -> dict:
        """The whole registry as one plain-JSON dict (validated shape:
        :func:`repro.telemetry.schema.validate_snapshot`).  Unset gauges
        are omitted -- ``None`` is not a measurement."""
        with self._lock:
            counters = {n: c._value for n, c in self._counters.items()}
            gauges = {n: g._value for n, g in self._gauges.items()
                      if g._value is not None}
            hists = list(self._histograms.items())
        return {"counters": counters, "gauges": gauges,
                "histograms": {n: h.stats() for n, h in hists}}

    def reset(self) -> None:
        """Zero every instrument IN PLACE (module-held references stay
        valid) -- test isolation, not production use."""
        with self._lock:
            tables = (list(self._counters.values())
                      + list(self._gauges.values())
                      + list(self._histograms.values()))
        for inst in tables:
            inst._reset()


def diff_counters(base: dict, now: dict) -> dict:
    """Counter deltas ``now - base`` of two snapshots (both from
    :meth:`MetricsRegistry.snapshot`) -- how a traced region renders
    its *own* totals out of the process-global monotone counters."""
    out = {}
    for name, v in now.get("counters", {}).items():
        out[name] = v - base.get("counters", {}).get(name, 0)
    return out


#: the process-global registry every subsystem records into
REGISTRY = MetricsRegistry()
