"""``repro.telemetry`` -- spans, counters, and trace export (DESIGN.md S12).

Zero-dependency observability for the whole execution stack.  Two
halves with different costs:

* **Metrics** (:data:`REGISTRY`) are *always on*: one locked integer
  add per compiled dispatch on the host path.  The canonical counters
  below are the repo's physical accounting -- every BENCH dispatch
  column and every test dispatch assertion reads them.
* **Spans** (:data:`TRACER`) are *opt-in* (``enable()`` /
  ``python -m repro run --trace out.json``): when disabled, a span is
  one ``if not enabled`` branch and fencing never happens, so JAX's
  async pipelining is preserved (<2% overhead budget, EXPERIMENTS.md).

Quickstart::

    import repro.telemetry as tel
    tel.enable()
    ... run things ...
    tel.export("trace.json")        # Chrome trace (Perfetto-loadable)
    tel.export("trace.jsonl")       # line-delimited stream
    print(tel.REGISTRY.snapshot())  # counters/gauges/histograms

Counter semantics (asserted in tests/test_telemetry.py):

* ``dispatches``   -- +1 per compiled-call invocation (one fused
  measure_scan = ONE dispatch, regardless of sweeps inside).
* ``sweeps``       -- lattice-time sweeps advanced, NOT multiplied by
  replicas or batch members (a bitplane sweep advances 32 replicas one
  sweep = 1 here).
* ``spin_flips``   -- update attempts: sweeps x sites x replicas x
  batch (the flips/ns numerator of the paper's Table 1).
* ``philox_draws`` -- uint32s drawn by counter-based engines:
  sweeps x sites x batch (one draw per site per sweep; multispin packs
  8 sites per word but draws 8 offsets/word, bitplane shares one draw
  across its 32 replicas -- both land on exactly sites draws/sweep).
* ``halo_exchanges`` -- halo exchange *events* on the sharded paths:
  the per-half-sweep distributed tier performs 2 per sweep, the
  sharded resident tier (DESIGN.md S15 double-halo) exactly one per k
  sweeps -- the counter IS the assertion of that claim
  (tests/test_dist.py).
* ``halo_bytes``     -- bytes moved across the mesh by those
  exchanges, summed over every shard.
"""
from __future__ import annotations

from .metrics import (REGISTRY, Counter, Gauge, Histogram,
                      MetricsRegistry, diff_counters)
from .schema import (TelemetryError, validate_event, validate_snapshot,
                     validate_trace)
from .trace import NULL_SPAN, TRACER, SpanHandle, Tracer

__all__ = [
    "TRACER", "REGISTRY", "Tracer", "MetricsRegistry",
    "Counter", "Gauge", "Histogram", "SpanHandle", "NULL_SPAN",
    "TelemetryError", "validate_snapshot", "validate_trace",
    "validate_event", "diff_counters",
    "DISPATCHES", "SWEEPS", "SPIN_FLIPS", "PHILOX_DRAWS",
    "HALO_EXCHANGES", "HALO_BYTES",
    "enable", "disable", "enabled", "reset", "span", "instant",
    "record_dispatch", "record_halo_exchange", "export",
]

#: canonical counters -- module-held references survive REGISTRY.reset()
DISPATCHES = REGISTRY.counter("dispatches")
SWEEPS = REGISTRY.counter("sweeps")
SPIN_FLIPS = REGISTRY.counter("spin_flips")
PHILOX_DRAWS = REGISTRY.counter("philox_draws")
HALO_EXCHANGES = REGISTRY.counter("halo_exchanges")
HALO_BYTES = REGISTRY.counter("halo_bytes")


def enable() -> None:
    """Turn span tracing on (counters are always on)."""
    TRACER.enable()


def disable() -> None:
    TRACER.disable()


def enabled() -> bool:
    return TRACER.enabled


def reset() -> None:
    """Drop recorded events and zero every metric (test isolation /
    the start of a traced bench run), keeping instrument identity."""
    TRACER.clear()
    REGISTRY.reset()


#: module-level aliases so call sites read ``tel.span("dispatch", ...)``
span = TRACER.span
instant = TRACER.instant


def record_dispatch(*, n_sweeps: int, sites: int, replicas: int = 1,
                    batch: int = 1, counter_based: bool = False) -> None:
    """Account one compiled-call invocation into the canonical counters.

    Call this from the stateful host wrapper that launches the compiled
    function -- NEVER from inside traced code (a jit trace would run the
    increment once, at trace time).
    """
    if n_sweeps < 0:
        raise ValueError(f"record_dispatch: n_sweeps={n_sweeps}")
    draws = int(n_sweeps) * int(sites)
    # all instruments share the registry lock: batch the adds into one
    # acquisition -- this sits on every dispatch path, so the disabled-
    # telemetry overhead budget (<2%, EXPERIMENTS.md) is set right here
    with REGISTRY._lock:
        DISPATCHES._value += 1
        SWEEPS._value += int(n_sweeps)
        SPIN_FLIPS._value += draws * int(replicas) * int(batch)
        if counter_based:
            PHILOX_DRAWS._value += draws * int(batch)


def record_halo_exchange(exchanges: int, bytes_moved: int) -> None:
    """Account halo traffic of one sharded dispatch: ``exchanges``
    exchange events moving ``bytes_moved`` bytes total (all shards,
    both planes).  Host-side only, like :func:`record_dispatch` --
    never call from traced code."""
    if exchanges < 0 or bytes_moved < 0:
        raise ValueError(
            f"record_halo_exchange: {exchanges=}, {bytes_moved=}")
    with REGISTRY._lock:
        HALO_EXCHANGES._value += int(exchanges)
        HALO_BYTES._value += int(bytes_moved)


def export(path: str, meta: dict | None = None) -> str:
    """Validate and write the current trace + metrics snapshot.

    ``*.jsonl`` -> line-delimited stream; anything else -> Chrome
    trace-event JSON (open in Perfetto / ``chrome://tracing``).
    """
    snap = REGISTRY.snapshot()
    validate_snapshot(snap)
    if path.endswith(".jsonl"):
        return TRACER.export_jsonl(path, metrics=snap, meta=meta)
    validate_trace(TRACER.to_chrome(metrics=snap, meta=meta))
    return TRACER.export_chrome(path, metrics=snap, meta=meta)
