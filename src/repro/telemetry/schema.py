"""Schema for telemetry exports (the ``repro.perf.schema`` style).

Two validated documents:

* the **metrics snapshot** (``MetricsRegistry.snapshot()``): counters
  are non-negative ints, gauges finite floats, histograms carry
  consistent ``count/sum/min/max/mean`` summaries;
* the **trace document** (``Tracer.to_chrome()``): a Perfetto-loadable
  ``traceEvents`` list of complete (``ph: "X"``) and instant
  (``ph: "i"``) events with finite non-negative timestamps/durations
  and JSON-scalar span attributes, plus the optional embedded
  ``metrics`` snapshot and ``meta`` block.

Every export path validates before writing (``python -m repro run
--trace``, ``benchmarks/run.py --trace``), and the committed golden
trace is validated forever in ``tests/test_telemetry.py`` -- a trace a
viewer cannot load, or a snapshot a dashboard cannot chart, must die at
emission time, not in a later consumer.
"""
from __future__ import annotations

import math


class TelemetryError(ValueError):
    """A telemetry document violates the trace/snapshot schema."""


def _fail(ctx: str, msg: str) -> None:
    raise TelemetryError(f"{ctx}: {msg}")


def _check_num(ctx: str, key: str, v, *, nonneg: bool = True) -> float:
    if isinstance(v, bool) or not isinstance(v, (int, float)):
        _fail(ctx, f"{key} must be a number, got {type(v).__name__}")
    f = float(v)
    if not math.isfinite(f):
        _fail(ctx, f"{key} must be finite, got {v!r}")
    if nonneg and f < 0:
        _fail(ctx, f"{key} must be >= 0, got {v!r}")
    return f


# ---------------------------------------------------------------------------
# metrics snapshot
# ---------------------------------------------------------------------------

SNAPSHOT_KEYS = frozenset({"counters", "gauges", "histograms"})

#: keys a histogram summary may carry; count-0 histograms carry only
#: ``count`` (a min/max of an empty stream is not a measurement)
HIST_KEYS = frozenset({"count", "sum", "min", "max", "mean"})


def _check_name(ctx: str, name) -> None:
    if not isinstance(name, str) or not name:
        _fail(ctx, f"metric name must be a non-empty string, "
                   f"got {name!r}")


def validate_snapshot(snap: dict, ctx: str = "snapshot") -> None:
    """Raise :class:`TelemetryError` unless ``snap`` is a valid metrics
    snapshot."""
    if not isinstance(snap, dict):
        _fail(ctx, f"snapshot must be a dict, got {type(snap).__name__}")
    extra = set(snap) - SNAPSHOT_KEYS
    if extra:
        _fail(ctx, f"unknown snapshot keys {sorted(extra)}")
    for req in SNAPSHOT_KEYS:
        if not isinstance(snap.get(req), dict):
            _fail(ctx, f"missing/invalid {req!r} (must be a dict)")
    for name, v in snap["counters"].items():
        _check_name(f"{ctx} counters", name)
        if isinstance(v, bool) or not isinstance(v, int) or v < 0:
            _fail(ctx, f"counter {name!r} must be an int >= 0, got {v!r}")
    for name, v in snap["gauges"].items():
        _check_name(f"{ctx} gauges", name)
        _check_num(ctx, f"gauge {name!r}", v, nonneg=False)
    for name, h in snap["histograms"].items():
        _check_name(f"{ctx} histograms", name)
        hctx = f"{ctx} histogram {name!r}"
        if not isinstance(h, dict):
            _fail(hctx, "summary must be a dict")
        extra = set(h) - HIST_KEYS
        if extra:
            _fail(hctx, f"unknown keys {sorted(extra)}")
        count = h.get("count")
        if isinstance(count, bool) or not isinstance(count, int) \
                or count < 0:
            _fail(hctx, f"count must be an int >= 0, got {count!r}")
        if count == 0:
            if set(h) != {"count"}:
                _fail(hctx, "empty histogram must carry only count=0")
            continue
        for k in ("sum", "min", "max", "mean"):
            if k not in h:
                _fail(hctx, f"missing {k!r}")
            _check_num(hctx, k, h[k], nonneg=False)
        if not h["min"] <= h["mean"] <= h["max"]:
            _fail(hctx, f"min <= mean <= max violated: {h}")


# ---------------------------------------------------------------------------
# chrome trace document
# ---------------------------------------------------------------------------

TRACE_KEYS = frozenset({"traceEvents", "displayTimeUnit", "metrics",
                        "meta"})
EVENT_KEYS = frozenset({"name", "cat", "ph", "ts", "dur", "pid", "tid",
                        "args", "s"})


def _check_args(ctx: str, args) -> None:
    if not isinstance(args, dict):
        _fail(ctx, "args must be a dict")
    for k, v in args.items():
        if not isinstance(k, str) or not k:
            _fail(ctx, f"args key {k!r} must be a non-empty string")
        if isinstance(v, list):
            bad = [x for x in v
                   if not isinstance(x, (str, int, float, bool))
                   and x is not None]
            if bad:
                _fail(ctx, f"args[{k!r}] list holds non-scalars {bad!r}")
        elif not isinstance(v, (str, int, float, bool)) and v is not None:
            _fail(ctx, f"args[{k!r}] must be a JSON scalar or scalar "
                       f"list, got {type(v).__name__}")


def validate_event(ev: dict, ctx: str = "event") -> None:
    if not isinstance(ev, dict):
        _fail(ctx, f"event must be a dict, got {type(ev).__name__}")
    name = ev.get("name")
    if not isinstance(name, str) or not name:
        _fail(ctx, f"name must be a non-empty string, got {name!r}")
    ctx = f"{ctx} {name!r}"
    extra = set(ev) - EVENT_KEYS
    if extra:
        _fail(ctx, f"unknown event keys {sorted(extra)}")
    ph = ev.get("ph")
    if ph not in ("X", "i"):
        _fail(ctx, f"ph must be 'X' (complete) or 'i' (instant), "
                   f"got {ph!r}")
    _check_num(ctx, "ts", ev.get("ts"))
    if ph == "X":
        if "dur" not in ev:
            _fail(ctx, "complete event missing dur")
        _check_num(ctx, "dur", ev["dur"])
    elif "dur" in ev:
        _fail(ctx, "instant event carries dur")
    for k in ("pid", "tid"):
        v = ev.get(k)
        if isinstance(v, bool) or not isinstance(v, int):
            _fail(ctx, f"{k} must be an int, got {v!r}")
    _check_args(ctx, ev.get("args", {}))


def validate_trace(doc: dict, ctx: str = "trace") -> None:
    """Raise :class:`TelemetryError` unless ``doc`` is a valid Chrome
    trace-event document (with the optional embedded snapshot)."""
    if not isinstance(doc, dict):
        _fail(ctx, f"trace must be a dict, got {type(doc).__name__}")
    extra = set(doc) - TRACE_KEYS
    if extra:
        _fail(ctx, f"unknown top-level keys {sorted(extra)}")
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        _fail(ctx, "traceEvents must be a list")
    for i, ev in enumerate(events):
        validate_event(ev, ctx=f"{ctx} traceEvents[{i}]")
    if "metrics" in doc:
        validate_snapshot(doc["metrics"], ctx=f"{ctx} metrics")
    if "meta" in doc and not isinstance(doc["meta"], dict):
        _fail(ctx, "meta must be a dict")
