"""Simulation driver: registry-dispatched engines, measurements, checkpoints.

``Simulation`` owns the (state, step_count) pair and delegates every
engine-specific operation -- state layout, sweeps, observables, checkpoint
(de)serialization -- to the :mod:`repro.core.engine` registry, so the
driver contains no per-engine branches (DESIGN.md S3).  State (lattice +
RNG offset + step counter) checkpoints atomically to .npz; a restarted
run of a counter-based engine continues the exact Philox stream
(fault-tolerance contract, tested in tests/).
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile

import jax
import numpy as np

from .engine import ENGINES, make_engine


@dataclasses.dataclass
class SimConfig:
    n: int = 512
    m: int = 512
    temperature: float = 2.0
    seed: int = 1234
    engine: str = "multispin"
    tc_block: int = 128
    # 0.5 = random (hot) start; 1.0 = ordered start.  Steady-state
    # measurements below Tc should use an ordered start: the paper (S5.3)
    # reports that cold random starts on large lattices can fall into
    # long-lived striped metastable states.
    init_p_up: float = 0.5
    # spin-glass only: probability that a quenched bond is ferromagnetic
    p_ferro: float = 0.5

    @property
    def inv_temp(self) -> float:
        return 1.0 / self.temperature


class Simulation:
    """2D Ising simulation with a registry-pluggable engine."""

    def __init__(self, config: SimConfig):
        self.config = config
        self.engine = make_engine(config)
        self.step_count = 0
        self.state = self.engine.init_state(
            jax.random.PRNGKey(config.seed))

    # -- state ------------------------------------------------------------
    def full_lattice(self) -> jax.Array:
        return self.engine.full_lattice(self.state)

    # -- dynamics ---------------------------------------------------------
    def run(self, n_sweeps: int) -> None:
        self.state = self.engine.sweeps(self.state, n_sweeps,
                                        self.step_count)
        self.step_count += n_sweeps

    # -- measurement ------------------------------------------------------
    def magnetization(self) -> float:
        return float(self.engine.magnetization(self.state))

    def energy(self) -> float:
        return float(self.engine.energy(self.state))

    def measure(self, plan) -> dict:
        """Run a :class:`repro.analysis.MeasurementPlan` in ONE compiled
        dispatch (observables fused into the sweep scan -- DESIGN.md S7).

        Returns ``{field: (n_measure,) float32 ndarray}``.
        """
        from repro.analysis.measure import measure_scan
        self.state, traj, self.step_count = measure_scan(
            self.engine, self.state, plan, step_count=self.step_count)
        return traj

    def trajectory(self, n_measure: int, sweeps_between: int,
                   thermalize: int = 0) -> np.ndarray:
        """Magnetization samples via the fused scan: one device dispatch
        per trajectory, bit-identical to the legacy per-sample loop.
        Shape ``(n_measure,)``; replicated engines (bitplane) return
        ``(n_measure, replicas)`` -- one series per replica chain."""
        from repro.analysis.measure import MeasurementPlan
        plan = MeasurementPlan(n_measure, sweeps_between, thermalize,
                               fields=("m",))
        return self.measure(plan)["m"]

    # -- fault tolerance ---------------------------------------------------
    def save(self, path: str) -> None:
        """Atomic checkpoint (write temp + rename)."""
        cfg = self.config
        arrays = {f"state_{k}": v
                  for k, v in self.engine.state_arrays(self.state).items()}
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".",
                                   suffix=".tmp")
        with os.fdopen(fd, "wb") as f:
            np.savez(f, step_count=self.step_count,
                     config_json=json.dumps(dataclasses.asdict(cfg)),
                     **arrays)
        os.replace(tmp, path)

    @classmethod
    def restore(cls, path: str) -> "Simulation":
        with np.load(path, allow_pickle=False) as z:
            if "config_json" not in z.files:
                raise ValueError(
                    f"{path}: not a Simulation checkpoint in the registry "
                    "layout (missing 'config_json'; pre-registry .npz "
                    "files are not restorable by this release)")
            cfg = SimConfig(**json.loads(str(z["config_json"])))
            sim = cls.__new__(cls)
            sim.config = cfg
            sim.engine = make_engine(cfg)
            sim.step_count = int(z["step_count"])
            arrays = {k[len("state_"):]: z[k] for k in z.files
                      if k.startswith("state_")}
            sim.state = sim.engine.from_arrays(arrays)
        return sim
