"""Simulation: compatibility shim over :class:`repro.api.Session`.

.. deprecated:: PR 5
   ``Simulation``/``SimConfig`` remain fully supported, but they are now
   a thin façade over the unified ``repro.api`` entry point -- a
   ``RunSpec`` with neither batch nor mesh, executed by ``Session``'s
   single-mode runner.  New code should build a ``RunSpec`` directly
   (one typed, serializable config for single, ensemble, and sharded
   runs -- DESIGN.md S10); this class is kept so every existing call
   site and checkpoint keeps working bit-for-bit.

Checkpoints written here carry BOTH the serialized ``RunSpec``
(``spec_json``, the unified layout ``Session.restore`` reads) and the
legacy ``config_json`` so a restored ``.config`` compares equal to the
saved one including engine-irrelevant knobs.
"""
from __future__ import annotations

import dataclasses
import json

import numpy as np

from .engine import ENGINES, make_engine  # noqa: F401  (re-export)


@dataclasses.dataclass
class SimConfig:
    n: int = 512
    m: int = 512
    temperature: float = 2.0
    seed: int = 1234
    engine: str = "multispin"
    tc_block: int = 128
    # 0.5 = random (hot) start; 1.0 = ordered start.  Steady-state
    # measurements below Tc should use an ordered start: the paper (S5.3)
    # reports that cold random starts on large lattices can fall into
    # long-lived striped metastable states.
    init_p_up: float = 0.5
    # spin-glass only: probability that a quenched bond is ferromagnetic
    p_ferro: float = 0.5

    @property
    def inv_temp(self) -> float:
        return 1.0 / self.temperature


class Simulation:
    """2D Ising simulation with a registry-pluggable engine (shim)."""

    def __init__(self, config: SimConfig):
        from repro.api import RunSpec, Session
        self.config = config
        self._session = Session.open(RunSpec.from_sim_config(config))

    # -- delegated internals ----------------------------------------------
    @property
    def engine(self):
        return self._session._runner.engine

    @property
    def state(self):
        return self._session.state

    @state.setter
    def state(self, v):
        self._session.state = v

    @property
    def step_count(self) -> int:
        return self._session.step_count

    @step_count.setter
    def step_count(self, v: int) -> None:
        self._session.step_count = v

    # -- state ------------------------------------------------------------
    def full_lattice(self):
        return self._session.full_lattice()

    # -- dynamics ---------------------------------------------------------
    def run(self, n_sweeps: int) -> None:
        self._session.run(n_sweeps)

    # -- measurement ------------------------------------------------------
    def magnetization(self) -> float:
        return self._session.magnetization()

    def energy(self) -> float:
        return self._session.energy()

    def measure(self, plan) -> dict:
        """Run a :class:`repro.analysis.MeasurementPlan` in ONE compiled
        dispatch (observables fused into the sweep scan -- DESIGN.md S7).

        Returns ``{field: (n_measure,) float32 ndarray}``.
        """
        return self._session.measure(plan)

    def trajectory(self, n_measure: int, sweeps_between: int,
                   thermalize: int = 0) -> np.ndarray:
        """Magnetization samples via the fused scan: one device dispatch
        per trajectory, bit-identical to the legacy per-sample loop.
        Shape ``(n_measure,)``; replicated engines (bitplane) return
        ``(n_measure, replicas)`` -- one series per replica chain."""
        return self._session.trajectory(n_measure, sweeps_between,
                                        thermalize)

    # -- fault tolerance ---------------------------------------------------
    def save(self, path: str) -> None:
        """Atomic checkpoint (write temp + rename); unified spec layout
        plus the legacy ``config_json`` for exact config round-trip."""
        self._session.save(path, extra={
            "config_json": json.dumps(dataclasses.asdict(self.config))})

    @classmethod
    def restore(cls, path: str) -> "Simulation":
        from repro.api import Session
        from repro.api.session import _load_checkpoint
        spec, step_count, arrays, legacy = _load_checkpoint(path)
        if spec.mode != "single":
            raise ValueError(
                f"{path} holds a {spec.mode!r} checkpoint; restore it "
                "with repro.api.Session.restore")
        sim = cls.__new__(cls)
        sim.config = SimConfig(**legacy) if legacy is not None \
            else spec.sim_config()
        sim._session = Session._from_arrays(spec, arrays, step_count)
        return sim
