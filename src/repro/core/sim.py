"""Simulation driver: engine selection, measurement schedule, checkpointing.

Ties the three single-device engines (basic / multispin / tensorcore) and
the distributed engine behind one interface.  State (lattice + RNG offset +
step counter) checkpoints atomically to .npz; a restarted run continues the
exact Philox stream (fault-tolerance contract, tested in tests/).
"""
from __future__ import annotations

import dataclasses
import os
import tempfile
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import lattice as lat
from . import metropolis, multispin, observables, tensorcore

ENGINES = ("basic", "basic_philox", "multispin", "tensorcore")


@dataclasses.dataclass
class SimConfig:
    n: int = 512
    m: int = 512
    temperature: float = 2.0
    seed: int = 1234
    engine: str = "multispin"
    tc_block: int = 128
    # 0.5 = random (hot) start; 1.0 = ordered start.  Steady-state
    # measurements below Tc should use an ordered start: the paper (S5.3)
    # reports that cold random starts on large lattices can fall into
    # long-lived striped metastable states.
    init_p_up: float = 0.5

    @property
    def inv_temp(self) -> float:
        return 1.0 / self.temperature


class Simulation:
    """2D Ising Metropolis simulation with a pluggable engine."""

    def __init__(self, config: SimConfig):
        assert config.engine in ENGINES, config.engine
        self.config = config
        self.step_count = 0
        key = jax.random.PRNGKey(config.seed)
        full = lat.init_lattice(key, config.n, config.m,
                                p_up=config.init_p_up)
        self._set_lattice(full)

    # -- state ------------------------------------------------------------
    def _set_lattice(self, full: jax.Array) -> None:
        cfg = self.config
        if cfg.engine == "tensorcore":
            self.state = tensorcore.decompose(full)
        else:
            b, w = lat.split_checkerboard(full)
            if cfg.engine == "multispin":
                self.state = multispin.pack_lattice(b, w)
            else:
                self.state = (b, w)

    def full_lattice(self) -> jax.Array:
        cfg = self.config
        if cfg.engine == "tensorcore":
            return tensorcore.recompose(self.state)
        if cfg.engine == "multispin":
            b, w = multispin.unpack_lattice(*self.state)
        else:
            b, w = self.state
        return lat.merge_checkerboard(b, w)

    # -- dynamics ---------------------------------------------------------
    def run(self, n_sweeps: int) -> None:
        cfg = self.config
        beta = jnp.float32(cfg.inv_temp)
        if cfg.engine == "basic":
            key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed),
                                     self.step_count)
            b, w, _ = metropolis.run_sweeps(*self.state, beta, key, n_sweeps)
            self.state = (b, w)
        elif cfg.engine == "basic_philox":
            self.state = tuple(metropolis.run_sweeps_philox(
                *self.state, beta, n_sweeps, seed=cfg.seed,
                start_offset=2 * self.step_count))
        elif cfg.engine == "multispin":
            self.state = tuple(multispin.run_sweeps_packed(
                *self.state, beta, n_sweeps, seed=cfg.seed,
                start_offset=2 * self.step_count))
        else:  # tensorcore
            key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed),
                                     self.step_count)
            planes, _ = tensorcore.run_sweeps_tc(
                self.state, beta, key, n_sweeps, block=cfg.tc_block)
            self.state = planes
        self.step_count += n_sweeps

    # -- measurement ------------------------------------------------------
    def magnetization(self) -> float:
        cfg = self.config
        if cfg.engine == "tensorcore":
            m = sum(p.astype(jnp.float32).sum() for p in self.state.values())
            return float(m / (cfg.n * cfg.m))
        if cfg.engine == "multispin":
            b, w = multispin.unpack_lattice(*self.state)
        else:
            b, w = self.state
        return float(observables.magnetization(b, w))

    def energy(self) -> float:
        b, w = lat.split_checkerboard(self.full_lattice())
        return float(observables.energy_per_spin(b, w))

    def trajectory(self, n_measure: int, sweeps_between: int,
                   thermalize: int = 0) -> np.ndarray:
        """Run and collect magnetization samples."""
        if thermalize:
            self.run(thermalize)
        out = np.empty(n_measure, np.float32)
        for i in range(n_measure):
            self.run(sweeps_between)
            out[i] = self.magnetization()
        return out

    # -- fault tolerance ---------------------------------------------------
    def save(self, path: str) -> None:
        """Atomic checkpoint (write temp + rename)."""
        cfg = self.config
        arrays = {}
        if cfg.engine == "tensorcore":
            for k, v in self.state.items():
                arrays[f"plane_{k}"] = np.asarray(v)
        else:
            arrays["s0"], arrays["s1"] = (np.asarray(s) for s in self.state)
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".",
                                   suffix=".tmp")
        with os.fdopen(fd, "wb") as f:
            np.savez(f, step_count=self.step_count,
                     engine=cfg.engine, n=cfg.n, m=cfg.m,
                     temperature=cfg.temperature, seed=cfg.seed, **arrays)
        os.replace(tmp, path)

    @classmethod
    def restore(cls, path: str) -> "Simulation":
        with np.load(path, allow_pickle=False) as z:
            cfg = SimConfig(n=int(z["n"]), m=int(z["m"]),
                            temperature=float(z["temperature"]),
                            seed=int(z["seed"]), engine=str(z["engine"]))
            sim = cls.__new__(cls)
            sim.config = cfg
            sim.step_count = int(z["step_count"])
            if cfg.engine == "tensorcore":
                sim.state = {k: jnp.asarray(z[f"plane_{k}"])
                             for k in ("00", "01", "10", "11")}
            else:
                sim.state = (jnp.asarray(z["s0"]), jnp.asarray(z["s1"]))
        return sim
