"""Distributed Ising engine: shard_map pencil decomposition + ICI halos.

The paper (S4) distributes the lattice as horizontal slabs, one per GPU, and
lets unified memory fetch the two boundary rows over NVLink.  TPUs have no
unified memory; the TPU-native equivalent is an explicit halo exchange with
``lax.ppermute`` over the ICI torus -- constant bytes/device, so unlike the
paper's single-NVSwitch ceiling (16 GPUs) this scales to arbitrary pods.

Layout: the two compact color planes ``(N, M/2)`` are sharded as a 2-D
pencil grid -- rows over the (pod, data) ring, columns over the model ring.
Each half-sweep exchanges one row-halo in each vertical direction and one
column-halo in each horizontal direction (the column halo carries the
single boundary spin of the paper's Fig. 3 side-word logic).

Randomness is global-position-keyed Philox, so results are *independent of
the device grid* -- resharding to a different mesh reproduces the same
physics trajectory bit-for-bit (tested in tests/test_distributed.py).

Halo/bulk overlap (beyond-paper, DESIGN.md S6.4): the update is split into
an interior region that depends only on local data and 1-wide border strips
that consume the halos, so XLA's latency-hiding scheduler can run the
ppermutes concurrently with the interior update.
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import compat
from . import metropolis as metro
from . import rng as crng


# ---------------------------------------------------------------------------
# multi-level ring shift over a product of mesh axes
# ---------------------------------------------------------------------------

def ring_shift(x: jax.Array, axis_names: Sequence[str], shift: int):
    """Shift x by one position around the ring formed by the product of
    ``axis_names`` (most-significant first).  shift=+1 receives from the
    previous ring position (use for a *top* halo), -1 from the next.

    Implemented as a cascade: permute the least-significant axis, then fix
    up the wrap positions with permutes over progressively more significant
    axes (DESIGN.md S5: this is how a (pod, data) slab ring is built from
    per-axis ppermutes; ppermute itself is single-axis).
    """
    assert shift in (+1, -1)
    names = list(axis_names)

    def perm(axis, val):
        n = compat.axis_size(axis)
        pairs = [((i - shift) % n, i) for i in range(n)]
        return jax.lax.ppermute(val, axis, pairs)

    out = perm(names[-1], x)
    # positions that wrapped on the k-th axis also need the (k-1)-th hop
    for k in range(len(names) - 1, 0, -1):
        idx = jax.lax.axis_index(names[k])
        n = compat.axis_size(names[k])
        at_wrap = (idx == 0) if shift == +1 else (idx == n - 1)
        cross = perm(names[k - 1], out)
        out = jnp.where(at_wrap, cross, out)
    return out


def _exchange_halos(op, row_axes, col_axes):
    """Return (top, bottom, left, right) halos of the opposite-color plane."""
    top = ring_shift(op[-1:, :], row_axes, +1)      # last row of upper nbr
    bottom = ring_shift(op[:1, :], row_axes, -1)    # first row of lower nbr
    left = ring_shift(op[:, -1:], col_axes, +1)
    right = ring_shift(op[:, :1], col_axes, -1)
    return top, bottom, left, right


def _haloed_taps(op, halos):
    """(up, down, nxt, prv) neighbor taps of the local shard with the
    exchanged halo rows/columns spliced in.

    H1.4 (EXPERIMENTS.md S Perf): every shifted read is pad+slice (a
    fusible producer) and the halo row/column enters through an
    iota-mask select over a virtual broadcast -- no extended buffer, no
    concatenates -- so each color update stays one fusion whose HBM
    traffic is read(op) + read(target) + write(target).  Shared by the
    basic, packed, and bitplane distributed updates.
    """
    top, bottom, left, right = halos
    nl, wl = op.shape
    zero = jnp.zeros((), op.dtype)
    row_i = jax.lax.broadcasted_iota(jnp.int32, op.shape, 0)
    col_i = jax.lax.broadcasted_iota(jnp.int32, op.shape, 1)

    def shift(x, dr, dc):
        """out[i,j] = x[i+dr, j+dc], zero-filled out of range."""
        pad_cfg = [(max(-dr, 0), max(dr, 0), 0),
                   (max(-dc, 0), max(dc, 0), 0)]
        padded = jax.lax.pad(x, zero, pad_cfg)
        return jax.lax.slice(padded, (max(dr, 0), max(dc, 0)),
                             (max(dr, 0) + nl, max(dc, 0) + wl))

    up = jnp.where(row_i == 0, top, shift(op, -1, 0))
    down = jnp.where(row_i == nl - 1, bottom, shift(op, 1, 0))
    nxt = jnp.where(col_i == wl - 1, right, shift(op, 0, 1))   # (i, k+1)
    prv = jnp.where(col_i == 0, left, shift(op, 0, -1))        # (i, k-1)
    return up, down, nxt, prv


# ---------------------------------------------------------------------------
# halo-aware neighbor sums (basic int8 engine)
# ---------------------------------------------------------------------------

def _nn_with_halos(op, halos, is_black, row0_parity):
    """4-neighbor sums for the local shard given exchanged halos.

    ``row0_parity`` is the global parity of the shard's first row (0 if the
    per-shard row count is even, which mesh construction guarantees).
    int8 arithmetic throughout: 4-neighbor sums fit, avoiding 4x-wide
    intermediates if XLA materializes anything (H1.5, EXPERIMENTS.md).
    """
    up, down, plus, minus = _haloed_taps(op, halos)
    rows = (jnp.arange(op.shape[0]) + row0_parity) % 2
    rows = rows[:, None]
    if is_black:
        side = jnp.where(rows == 1, plus, minus)
    else:
        side = jnp.where(rows == 1, minus, plus)
    return up + down + op + side  # int8 arithmetic: |sum| <= 4


def _global_positions(shape, row_axes, col_axes):
    """Global (row, col) index arrays of the local shard's cells."""
    n_loc, m_loc = shape

    def multi_index(axes):
        idx = jnp.int32(0)
        for a in axes:
            idx = idx * compat.axis_size(a) + jax.lax.axis_index(a)
        return idx

    r0 = multi_index(row_axes) * n_loc
    c0 = multi_index(col_axes) * m_loc
    rows = r0 + jnp.arange(n_loc, dtype=jnp.int32)[:, None]
    cols = c0 + jnp.arange(m_loc, dtype=jnp.int32)[None, :]
    return rows, cols


def update_color_dist(target, op, inv_temp, is_black, seed, offset,
                      global_cols: int, row_axes, col_axes):
    """One distributed half-sweep of the basic engine on the local shard."""
    halos = _exchange_halos(op, row_axes, col_axes)
    rows, cols = _global_positions(target.shape, row_axes, col_axes)
    nn = _nn_with_halos(op, halos, is_black, row0_parity=0)
    gidx = (rows * global_cols + cols).astype(jnp.uint32)
    u = crng.uniforms(seed, gidx, jnp.uint32(offset))[0]
    t = target.astype(jnp.int32)
    acc = jnp.exp(-2.0 * inv_temp * nn.astype(jnp.float32)
                  * t.astype(jnp.float32))
    return jnp.where(u < acc, -t, t).astype(target.dtype)


def sweep_dist(black, white, inv_temp, seed, sweep_index, global_cols,
               row_axes, col_axes):
    black = update_color_dist(black, white, inv_temp, True, seed,
                              crng.half_sweep_offset(0, sweep_index, 0),
                              global_cols, row_axes, col_axes)
    white = update_color_dist(white, black, inv_temp, False, seed,
                              crng.half_sweep_offset(0, sweep_index, 1),
                              global_cols, row_axes, col_axes)
    return black, white


# ---------------------------------------------------------------------------
# public factory
# ---------------------------------------------------------------------------

def make_ising_step(mesh, *, n: int, m: int, seed: int = 0,
                    n_sweeps: int = 1, row_axes=None, col_axes=None):
    """Build a jitted multi-device Ising sweep function for ``mesh``.

    Rows of the compact planes are sharded over ``row_axes`` (default: all
    mesh axes but the last), columns over ``col_axes`` (default: the last
    mesh axis).  Returns (step_fn, plane_sharding).
    """
    names = list(mesh.axis_names)
    row_axes = tuple(row_axes if row_axes is not None else names[:-1])
    col_axes = tuple(col_axes if col_axes is not None else names[-1:])
    half = m // 2
    rows_devs = 1
    for a in row_axes:
        rows_devs *= mesh.shape[a]
    cols_devs = 1
    for a in col_axes:
        cols_devs *= mesh.shape[a]
    assert n % rows_devs == 0 and (n // rows_devs) % 2 == 0, (
        "per-shard row count must be even so checkerboard parity is uniform")
    assert half % cols_devs == 0

    spec = P(row_axes, col_axes)
    sharding = jax.sharding.NamedSharding(mesh, spec)

    @functools.partial(
        compat.shard_map, mesh=mesh,
        in_specs=(spec, spec, P(), P()),
        out_specs=(spec, spec),
        check_vma=False)
    def _sweeps(black, white, inv_temp, sweep0):
        def body(i, carry):
            b, w = carry
            return sweep_dist(b, w, inv_temp, seed, sweep0 + i, half,
                              row_axes, col_axes)
        return jax.lax.fori_loop(0, n_sweeps, body, (black, white))

    # plane buffers are donated: callers rebind (b, w = step(b, w, ...)),
    # so a sharded lattice never holds two copies per device in HBM
    return jax.jit(_sweeps, donate_argnums=(0, 1)), sharding


def make_packed_ising_step(mesh, *, n: int, m: int, seed: int = 0,
                           n_sweeps: int = 1, row_axes=None, col_axes=None):
    """Multispin (packed uint32 nibble) distributed sweep -- the paper's
    optimized engine on the full mesh.  Halos: one word-row per vertical
    direction, one word-column per horizontal direction (the column halo
    carries the paper's Fig. 3 boundary nibble).  Returns
    (jitted step(black, white, inv_temp, sweep0), word-plane sharding)."""
    from . import lattice as lat
    from . import multispin as ms

    names = list(mesh.axis_names)
    row_axes = tuple(row_axes if row_axes is not None else names[:-1])
    col_axes = tuple(col_axes if col_axes is not None else names[-1:])
    words = m // 2 // lat.SPINS_PER_WORD
    spec = P(row_axes, col_axes)
    nib = lat.NIBBLE_BITS

    def update_packed(target, op, is_black, offset, thresholds):
        halos = _exchange_halos(op, row_axes, col_axes)
        up, down, nxt, prv = _haloed_taps(op, halos)
        plus = (op >> jnp.uint32(nib)) | (nxt << jnp.uint32(32 - nib))
        minus = (op << jnp.uint32(nib)) | (prv >> jnp.uint32(32 - nib))
        rows = (jax.lax.broadcasted_iota(jnp.uint32, op.shape, 0)
                % jnp.uint32(2))
        side = jnp.where(rows == 1, plus, minus) if is_black \
            else jnp.where(rows == 1, minus, plus)
        nn_words = up + down + op + side
        rpos, cpos = _global_positions(target.shape, row_axes, col_axes)
        widx = (rpos * words + cpos).astype(jnp.uint32)
        draws = ms.word_randoms(seed, widx, offset)
        flip = jnp.zeros_like(target)
        for k in range(lat.SPINS_PER_WORD):
            sh = jnp.uint32(k * nib)
            s = (target >> sh) & jnp.uint32(1)
            nnk = (nn_words >> sh) & jnp.uint32(0xF)
            idx = (s * jnp.uint32(5) + nnk).astype(jnp.int32)
            t = jnp.take(thresholds, idx)   # integer-domain accept (H1.6)
            flip = flip | ((draws[k] < t).astype(jnp.uint32) << sh)
        return target ^ flip

    @functools.partial(compat.shard_map, mesh=mesh,
                       in_specs=(spec, spec, P(), P()),
                       out_specs=(spec, spec), check_vma=False)
    def sweeps(black, white, inv_temp, sweep0):
        thresholds = ms.acceptance_thresholds(inv_temp)  # hoisted (H1.6)

        def body(i, carry):
            b, w = carry
            b = update_packed(b, w, True,
                              crng.half_sweep_offset(sweep0, i, 0),
                              thresholds)
            w = update_packed(w, b, False,
                              crng.half_sweep_offset(sweep0, i, 1),
                              thresholds)
            return b, w
        return jax.lax.fori_loop(0, n_sweeps, body, (black, white))

    return (jax.jit(sweeps, donate_argnums=(0, 1)),
            jax.sharding.NamedSharding(mesh, spec))


def make_bitplane_ising_step(mesh, *, n: int, m: int, seed: int = 0,
                             n_sweeps: int = 1, row_axes=None,
                             col_axes=None):
    """Bitplane (32 replicas/word, DESIGN.md S8) distributed sweep.

    Same ring-shift halo machinery as the other engines: one word-row
    per vertical direction, one word-column per horizontal direction
    (the side tap reads a whole neighbor word -- the bitplane layout
    keeps one word per site, so no sub-word splice is needed).  The
    shared per-site Philox draw is keyed on the *global* (site // 4,
    site % 4) pair, recomputed per local site with a lane select, so the
    step reproduces the single-device ``run_sweeps_bitplane`` trajectory
    bit-for-bit on any mesh (tests/test_bitplane.py).  Returns
    (jitted step(black, white, inv_temp, sweep0), word-plane sharding);
    the plane buffers are donated.
    """
    from . import bitplane as bp
    from . import multispin as ms

    names = list(mesh.axis_names)
    row_axes = tuple(row_axes if row_axes is not None else names[:-1])
    col_axes = tuple(col_axes if col_axes is not None else names[-1:])
    half = m // 2
    assert half % 4 == 0, "bitplane planes need a multiple-of-4 width"
    rows_devs = 1
    for a in row_axes:
        rows_devs *= mesh.shape[a]
    cols_devs = 1
    for a in col_axes:
        cols_devs *= mesh.shape[a]
    assert n % rows_devs == 0 and (n // rows_devs) % 2 == 0, (
        "per-shard row count must be even so checkerboard parity is uniform")
    assert half % cols_devs == 0
    spec = P(row_axes, col_axes)

    # static: when every shard's column range is 4-aligned (the common
    # case), whole draw groups are shard-local and one Philox call serves
    # 4 sites, exactly as core.bitplane.site_randoms; otherwise fall back
    # to a per-site call + lane select (4x the Philox work, same bits)
    aligned_cols = (half // cols_devs) % 4 == 0

    def site_draws(shape, offset):
        nl, wl = shape
        k0, k1 = crng.seed_keys(seed)
        off = jnp.asarray(offset, jnp.uint32)
        if aligned_cols:
            rpos, gcol = _global_positions((nl, wl // 4), row_axes,
                                           col_axes)
            g = (rpos * (half // 4) + gcol).astype(jnp.uint32)
            zg = jnp.zeros_like(g)
            lanes = crng.philox4x32(off, zg, g, zg, k0, k1)
            return jnp.stack(lanes, axis=-1).reshape(nl, wl)
        rpos, cpos = _global_positions(shape, row_axes, col_axes)
        g = (rpos * (half // 4) + cpos // 4).astype(jnp.uint32)
        lane = (cpos % 4).astype(jnp.uint32)
        zg = jnp.zeros_like(g)
        l0, l1, l2, l3 = crng.philox4x32(off, zg, g, zg, k0, k1)
        return jnp.where(lane == 0, l0,
                         jnp.where(lane == 1, l1,
                                   jnp.where(lane == 2, l2, l3)))

    def update_bitplane(target, op, is_black, offset, thresholds):
        halos = _exchange_halos(op, row_axes, col_axes)
        up, down, nxt, prv = _haloed_taps(op, halos)
        rpos, _ = _global_positions(target.shape, row_axes, col_axes)
        parity = (rpos % 2).astype(jnp.uint32)
        side = jnp.where(parity == 1, nxt, prv) if is_black \
            else jnp.where(parity == 1, prv, nxt)
        counts = bp.bit_count_neighbors(up, down, op, side)
        draws = site_draws(target.shape, offset)
        return target ^ bp.flip_word_from_classes(target, counts, draws,
                                                  thresholds)

    @functools.partial(compat.shard_map, mesh=mesh,
                       in_specs=(spec, spec, P(), P()),
                       out_specs=(spec, spec), check_vma=False)
    def sweeps(black, white, inv_temp, sweep0):
        thresholds = ms.acceptance_thresholds(inv_temp)  # hoisted (H1.6)

        def body(i, carry):
            b, w = carry
            b = update_bitplane(b, w, True,
                                crng.half_sweep_offset(sweep0, i, 0),
                                thresholds)
            w = update_bitplane(w, b, False,
                                crng.half_sweep_offset(sweep0, i, 1),
                                thresholds)
            return b, w
        return jax.lax.fori_loop(0, n_sweeps, body, (black, white))

    return (jax.jit(sweeps, donate_argnums=(0, 1)),
            jax.sharding.NamedSharding(mesh, spec))


def magnetization_dist(mesh, row_axes=None, col_axes=None):
    """shard_map'd magnetization (psum over the whole mesh)."""
    names = list(mesh.axis_names)
    row_axes = tuple(row_axes if row_axes is not None else names[:-1])
    col_axes = tuple(col_axes if col_axes is not None else names[-1:])
    spec = P(row_axes, col_axes)

    @functools.partial(compat.shard_map, mesh=mesh, in_specs=(spec, spec),
                       out_specs=P(), check_vma=False)
    def _mag(black, white):
        s = black.astype(jnp.float32).sum() + white.astype(jnp.float32).sum()
        s = jax.lax.psum(s, row_axes + col_axes)
        count = 2.0 * black.size * jax.lax.psum(1, row_axes + col_axes)
        return s / count

    return jax.jit(_mag)
