"""Pluggable engine registry: one protocol, ten update algorithms.

The paper's contribution is *comparing implementations* of the same 2D
Ising Metropolis update; this module is the seam that makes the
implementations interchangeable (DESIGN.md S3).  Every engine subclasses
:class:`Engine` and registers itself in :data:`ENGINES` under its paper
name; the :class:`~repro.core.sim.Simulation` driver and the
:class:`~repro.core.ensemble.Ensemble` batched driver dispatch purely
through the registry, so adding an engine never touches the drivers.

Protocol (all methods pure in the JAX sense unless noted):

* ``init_state(key)``        -- PRNG key -> engine-native state pytree;
* ``sweeps(state, n, step)`` -- advance ``n`` full lattice sweeps (stateful
                                wrapper: owns jit caching / RNG offsets);
* ``full_lattice(state)``    -- state -> the (N, M) +-1 int8 lattice;
* ``magnetization(state)``   -- mean spin (scalar array);
* ``state_arrays(state)``    -- state -> {name: np.ndarray} for .npz;
* ``from_arrays(arrays)``    -- inverse of ``state_arrays``.

Counter-based engines (Philox randomness addressed by (seed, position,
offset), cuRAND semantics -- DESIGN.md S4) additionally expose
``sweep_fn(state, inv_temp, seed, start_offset, n_sweeps)``: a pure
function with *traceable* seed and temperature, which is what the
ensemble driver ``vmap``s over a (temperature, seed) batch axis.

Two hooks added for the measurement subsystem (DESIGN.md S7):

* ``observables(state, inv_temp)`` -- pure, trace/vmap-safe map of the
  engine-native state to ``{"m": mean spin, "e": energy/spin}``; the
  default routes through ``full_lattice``, so it is correct for every
  layout (packed words, tensor-core planes, ...) -- engines with a
  cheaper or physically different path (spin glass) override it;
* ``scan_step(state, inv_temp, seed, step_count, n_sweeps)`` -- pure
  version of ``sweeps`` with a *traceable* cumulative-sweep counter, the
  unit that ``repro.analysis.measure.measure_scan`` chains inside one
  ``jax.lax.scan``.  ``sweeps`` (the stateful wrapper) and ``scan_step``
  must draw the same random stream or trajectories would fork between
  the legacy per-sample loop and the fused scan (tested bit-exact).
"""
from __future__ import annotations

from contextlib import contextmanager
from typing import Callable, ClassVar, Dict, Optional, Type

import jax
import jax.numpy as jnp
import numpy as np

import repro.telemetry as tel
from repro.resilience import degrade

from . import bitplane as bp
from . import lattice as lat
from . import metropolis as metro
from . import multispin as ms
from . import observables as obs
from . import rng as crng
from . import spinglass as sg
from . import tensorcore as tc
from . import wolff as wolff_mod

ENGINES: Dict[str, Type["Engine"]] = {}


def register(cls: Type["Engine"]) -> Type["Engine"]:
    """Class decorator: add an engine to the registry under ``cls.name``."""
    assert cls.name not in ENGINES, f"duplicate engine {cls.name!r}"
    ENGINES[cls.name] = cls
    return cls


def make_engine(config) -> "Engine":
    """Instantiate the registered engine named by ``config.engine``."""
    try:
        cls = ENGINES[config.engine]
    except KeyError:
        raise ValueError(
            f"unknown engine {config.engine!r}; registered engines: "
            f"{sorted(ENGINES)}") from None
    return cls(config)


class Engine:
    """Base class: holds the config, defines the protocol and defaults."""

    name: ClassVar[str]
    counter_based: ClassVar[bool] = False  # True: vmap-safe Philox sweeps
    #: independent replica chains carried per state (1 for every engine
    #: except bitplane, whose observables are per-replica vectors)
    replicas: ClassVar[int] = 1
    #: engine-specific config knobs this engine actually consumes --
    #: ``repro.api.EngineSpec`` validates its params against this set at
    #: construction time (DESIGN.md S10)
    param_fields: ClassVar[tuple] = ()
    #: name of the ``repro.core.distributed`` step factory that advances
    #: this engine's random stream on a device mesh (``None`` = no
    #: sharded execution); the capability flag behind ``MeshSpec``
    dist_factory: ClassVar[Optional[str]] = None

    @classmethod
    def validate_lattice(cls, n: int, m: int) -> None:
        """Raise ValueError when (n, m) violates this engine's layout
        constraints -- called by ``RunSpec`` at construction, so bad
        geometry fails before any trace (DESIGN.md S10)."""
        if n % 2 or m % 2:
            raise ValueError(
                f"engine {cls.name!r} needs even lattice dims for the "
                f"checkerboard decomposition, got ({n}, {m})")

    def __init__(self, config):
        self.cfg = config

    # -- construction -------------------------------------------------------
    def init_state(self, key):
        """Fresh state from a PRNG key (vmap-safe for batched init)."""
        cfg = self.cfg
        full = lat.init_lattice(key, cfg.n, cfg.m, p_up=cfg.init_p_up)
        return self.from_full(full)

    def from_full(self, full):
        """(N, M) +-1 lattice -> engine-native state pytree."""
        raise NotImplementedError

    # -- views --------------------------------------------------------------
    def full_lattice(self, state):
        raise NotImplementedError

    def magnetization(self, state):
        b, w = lat.split_checkerboard(self.full_lattice(state))
        return obs.magnetization(b, w)

    def energy(self, state):
        return self.observables(state, jnp.float32(self.cfg.inv_temp))["e"]

    def observables(self, state, inv_temp):
        """Pure, trace/vmap-safe observables of the engine-native state.

        Returns ``{"m": mean spin, "e": energy per spin}``.  The default
        goes through ``full_lattice``, which is layout-correct for every
        engine; ``inv_temp`` is part of the contract so engines can add
        temperature-dependent observables without changing call sites.
        """
        full = self.full_lattice(state)
        return {"m": obs.magnetization_full(full),
                "e": obs.energy_per_spin_full(full)}

    # -- dynamics -----------------------------------------------------------
    @contextmanager
    def _dispatch(self, n_sweeps: int, batch: int = 1, **attrs):
        """Account + trace ONE compiled-call invocation.

        Every stateful ``sweeps`` wrapper (and the batched runners)
        launches its compiled call inside this scope: the canonical
        counters advance unconditionally (host-side, once per call --
        NEVER inside traced code), and when tracing is on a fenced
        ``dispatch`` span records the phase.  ``sp.fence(out)`` inside
        the ``with`` makes the span wait for device completion.
        """
        tel.record_dispatch(n_sweeps=n_sweeps,
                            sites=self.cfg.n * self.cfg.m,
                            replicas=self.replicas, batch=batch,
                            counter_based=self.counter_based)
        with tel.span("dispatch", engine=self.name,
                      lattice=(self.cfg.n, self.cfg.m), k=n_sweeps,
                      replicas=self.replicas, batch=batch,
                      **attrs) as sp:
            yield sp

    def sweeps(self, state, n_sweeps: int, step_count: int):
        """Default stateful wrapper: ``scan_step`` at the config's own
        temperature and seed, accounted as ONE dispatch.  Engines owning
        their jit caching (CounterEngine) override it.

        Launched through ``resilience.degrade.run_dispatch``: transient
        failures retry with bounded backoff; each (re)attempt is its
        own accounted dispatch.
        """
        def attempt():
            with self._dispatch(n_sweeps) as sp:
                out = self.scan_step(state,
                                     jnp.float32(self.cfg.inv_temp),
                                     self.cfg.seed, step_count, n_sweeps)
                sp.fence(out)
            return out

        return degrade.run_dispatch(attempt, engine=self)

    def scan_step(self, state, inv_temp, seed, step_count, n_sweeps: int):
        """Pure ``sweeps``: advance ``n_sweeps`` (static) from a traceable
        cumulative ``step_count``; must reproduce ``sweeps`` bit-for-bit."""
        raise NotImplementedError

    # -- checkpointing ------------------------------------------------------
    def state_arrays(self, state) -> dict:
        raise NotImplementedError

    def from_arrays(self, arrays: dict):
        raise NotImplementedError


class CounterEngine(Engine):
    """Shared machinery for counter-based (Philox skip-ahead) engines.

    Subclasses implement ``color_update`` (one half-sweep of the target
    plane); this base owns the 2-half-sweeps-per-sweep offset bookkeeping
    behind the stateful ``sweeps`` protocol method, plus per-``n_sweeps``
    jit caching.  The offset scheme must stay identical to the standalone
    ``run_sweeps_philox``/``run_sweeps_packed`` wrappers (same stream,
    cross-tied in tests/test_engines.py) or checkpoints would fork.
    """

    counter_based = True

    #: planner family key of the resident-sweep tier (DESIGN.md S9);
    #: ``None`` = engine has no resident kernel.  Pallas-backed engines
    #: set it; at construction the VMEM planner
    #: (:func:`repro.kernels.resident.plan_resident`) decides whether
    #: this lattice's planes fit per-core VMEM, and ``sweep_fn`` routes
    #: every n-sweep dispatch through ONE resident kernel call when they
    #: do -- ``Simulation``/``Ensemble``/``measure_scan`` pick the tier
    #: up through the registry with no caller changes.
    resident_family: ClassVar[Optional[str]] = None

    def __init__(self, config):
        super().__init__(config)
        self._jit_cache: Dict[int, Callable] = {}
        self.resident_plan = None
        #: the planner's decision as span attributes -- the SAME dict
        #: ``describe()`` renders in ``--dry-run``, so dry-run output
        #: and live traces can never disagree about the tier
        self.resident_attrs: dict = {}
        if self.resident_family is not None:
            from repro.kernels.resident import (decision_attrs,
                                                plan_resident)
            self.resident_plan = plan_resident(self.resident_family,
                                               config.n, config.m)
            self.resident_attrs = decision_attrs(self.resident_family,
                                                 config.n, config.m)

    def color_update(self, target, op, inv_temp, is_black, seed, offset,
                     ctx=None):
        """One half-sweep; ``seed`` may be a python int or uint32 trace.

        ``ctx`` receives :meth:`sweep_context`'s per-call precomputation.
        """
        raise NotImplementedError

    def sweep_context(self, inv_temp):
        """Loop-invariant precomputation (e.g. the integer acceptance
        thresholds, H1.6) evaluated ONCE per sweep call and passed to
        every ``color_update`` -- structurally hoisted out of the
        fori_loop rather than left to XLA's LICM."""
        return None

    def resident_sweeps(self, state, inv_temp, seed, start_offset,
                        n_sweeps: int):
        """Resident-tier dispatch (DESIGN.md S9): ``n_sweeps`` FULL
        sweeps in ONE kernel call, both planes VMEM-resident, Philox
        advanced in-kernel with the same (sweep, color) counter layout
        (``rng.half_sweep_offset``) as the fallback loop below -- must
        be bit-exact vs ``n_sweeps`` iterations of ``color_update``."""
        raise NotImplementedError

    def sweep_fn(self, state, inv_temp, seed, start_offset, n_sweeps: int):
        """Pure sweep kernel: n_sweeps x (black, white) half-sweeps with
        cuRAND-style offsets 2i / 2i+1 past ``start_offset``.

        Tiered (DESIGN.md S9): when the construction-time VMEM plan
        exists, the whole n-sweep block is ONE resident kernel dispatch;
        otherwise the per-half-sweep ``color_update`` fori_loop runs.
        Both tiers share one Philox counter layout, so which tier ran is
        unobservable in the trajectory (tested in tests/test_resident.py).
        ``n_sweeps == 0`` takes the fallback path, whose fori_loop
        no-ops, so the zero-sweep edge behaves alike on every tier.
        """
        if self.resident_plan is not None and n_sweeps > 0:
            return tuple(self.resident_sweeps(state, inv_temp, seed,
                                              start_offset, n_sweeps))
        start = jnp.uint32(start_offset)
        ctx = self.sweep_context(inv_temp)

        def body(i, carry):
            b, w = carry
            b = self.color_update(b, w, inv_temp, True, seed,
                                  crng.half_sweep_offset(start, i, 0), ctx)
            w = self.color_update(w, b, inv_temp, False, seed,
                                  crng.half_sweep_offset(start, i, 1), ctx)
            return (b, w)

        return jax.lax.fori_loop(0, n_sweeps, body, tuple(state))

    def scan_step(self, state, inv_temp, seed, step_count, n_sweeps: int):
        # one half-sweep offset per color: cumulative offset = 2 * sweeps
        return self.sweep_fn(state, inv_temp, seed, 2 * step_count, n_sweeps)

    def _demote_resident(self, reason: str) -> None:
        """Demote this (family, lattice) to the per-half-sweep fallback
        tier for the rest of the process (DESIGN.md S13): record it in
        the process-global registry (so freshly built engines and
        ``--dry-run`` plans agree), drop the plan, re-render the span
        attributes, and invalidate the jit cache so the next dispatch
        traces ``sweep_fn``'s fallback branch.  Both tiers draw the
        same Philox stream, so the trajectory does not fork."""
        from repro.kernels.resident import decision_attrs
        degrade.demote(self.resident_family, self.cfg.n, self.cfg.m,
                       reason)
        self.resident_plan = None
        self.resident_attrs = decision_attrs(self.resident_family,
                                             self.cfg.n, self.cfg.m)
        self._jit_cache.clear()

    def sweeps(self, state, n_sweeps: int, step_count: int):
        def attempt():
            # fn is re-read from the cache on every (re)attempt: a
            # demotion clears the cache, so the retry traces and runs
            # the fallback tier
            fn = self._jit_cache.get(n_sweeps)
            fresh = fn is None
            if fn is None:
                # seed closed over: python int, full 64-bit keys
                seed = self.cfg.seed
                # the incoming state buffers are donated: callers
                # rebind (state = engine.sweeps(state, ...)), so large
                # lattices never hold two copies of a plane in HBM
                fn = jax.jit(lambda s, beta, off: self.sweep_fn(
                    s, beta, seed, off, n_sweeps), donate_argnums=(0,))
                self._jit_cache[n_sweeps] = fn
            with self._dispatch(n_sweeps,
                                compile="first" if fresh else "steady",
                                **self.resident_attrs) as sp:
                out = fn(state, jnp.float32(self.cfg.inv_temp),
                         jnp.uint32(2 * step_count))
                sp.fence(out)
            return out

        return degrade.run_dispatch(attempt, engine=self)


def _even_block_rows(n: int, cap: int = 256) -> int:
    """Largest even row-block count <= ``cap`` dividing the plane height
    ``n`` -- the Pallas row-block engines need even blocks so checkerboard
    parity is uniform within a block."""
    best = 0
    for d in range(2, min(n, cap) + 1, 2):
        if n % d == 0:
            best = d
    assert best, f"Pallas row-block engines need an even lattice height," \
        f" got {n}"
    return best


# ---------------------------------------------------------------------------
# compact color-plane engines (basic / basic_philox / stencil_pallas)
# ---------------------------------------------------------------------------

class _PlanesEngine(Engine):
    """Common state handling for (black, white) compact-plane engines."""

    def from_full(self, full):
        return tuple(lat.split_checkerboard(full))

    def full_lattice(self, state):
        return lat.merge_checkerboard(*state)

    def magnetization(self, state):
        return obs.magnetization(*state)

    def state_arrays(self, state):
        return {"black": np.asarray(state[0]), "white": np.asarray(state[1])}

    def from_arrays(self, arrays):
        return (jnp.asarray(arrays["black"]), jnp.asarray(arrays["white"]))


@register
class BasicEngine(_PlanesEngine):
    """Paper S3.1 basic checkerboard Metropolis, jax.random uniforms."""

    name = "basic"

    def scan_step(self, state, inv_temp, seed, step_count, n_sweeps):
        key = jax.random.fold_in(jax.random.PRNGKey(seed), step_count)
        b, w, _ = metro.run_sweeps(*state, inv_temp, key, n_sweeps)
        return (b, w)



@register
class BasicPhiloxEngine(_PlanesEngine, CounterEngine):
    """Basic engine with in-place counter-based Philox (DESIGN.md S6.2)."""

    name = "basic_philox"
    dist_factory = "basic"

    def color_update(self, target, op, inv_temp, is_black, seed, offset,
                     ctx=None):
        return metro.update_color_philox(target, op, inv_temp, is_black,
                                         seed, offset)


@register
class StencilPallasEngine(_PlanesEngine, CounterEngine):
    """Fused Pallas stencil kernel (DESIGN.md S6.2); interpret-mode on CPU.

    Philox is keyed on the global (row, col) index, so this engine is
    bit-for-bit identical to ``basic_philox`` -- the kernel's pure-jnp
    oracle -- at any block size (tested in tests/test_engines.py).
    """

    name = "stencil_pallas"
    resident_family = "stencil"
    dist_factory = "basic"  # bit-for-bit the basic_philox stream

    def __init__(self, config):
        super().__init__(config)
        self.block_rows = _even_block_rows(config.n)
        self.interpret = jax.default_backend() != "tpu"

    def color_update(self, target, op, inv_temp, is_black, seed, offset,
                     ctx=None):
        from repro.kernels.stencil.stencil import stencil_update
        return stencil_update(target, op, inv_temp, is_black=is_black,
                              seed=seed, offset=offset,
                              block_rows=self.block_rows,
                              interpret=self.interpret)

    def resident_sweeps(self, state, inv_temp, seed, start_offset,
                        n_sweeps):
        from repro.kernels.stencil.resident import stencil_sweeps_resident
        return stencil_sweeps_resident(*state, inv_temp,
                                       n_sweeps=n_sweeps, seed=seed,
                                       start_offset=start_offset,
                                       interpret=self.interpret)


# ---------------------------------------------------------------------------
# multi-spin packed engine
# ---------------------------------------------------------------------------

@register
class MultispinEngine(CounterEngine):
    """Paper S3.3 multi-spin coding: 8 spins/uint32 word (DESIGN.md S2)."""

    name = "multispin"
    dist_factory = "packed"

    @classmethod
    def validate_lattice(cls, n, m):
        super().validate_lattice(n, m)
        if (m // 2) % lat.SPINS_PER_WORD:
            raise ValueError(
                f"engine {cls.name!r} packs {lat.SPINS_PER_WORD} "
                f"spins/uint32 word: the compact plane width m/2 must "
                f"be a multiple of {lat.SPINS_PER_WORD}, got m={m}")

    def from_full(self, full):
        return ms.pack_lattice(*lat.split_checkerboard(full))

    def full_lattice(self, state):
        return lat.merge_checkerboard(*ms.unpack_lattice(*state))

    def magnetization(self, state):
        return obs.magnetization(*ms.unpack_lattice(*state))

    def sweep_context(self, inv_temp):
        return ms.acceptance_thresholds(inv_temp)

    def color_update(self, target, op, inv_temp, is_black, seed, offset,
                     ctx=None):
        return ms.update_color_packed(target, op, inv_temp, is_black,
                                      seed, offset, thresholds=ctx)

    def state_arrays(self, state):
        return {"black_words": np.asarray(state[0]),
                "white_words": np.asarray(state[1])}

    def from_arrays(self, arrays):
        return (jnp.asarray(arrays["black_words"]),
                jnp.asarray(arrays["white_words"]))


@register
class MultispinPallasEngine(MultispinEngine):
    """Fused Pallas multispin kernel (DESIGN.md S6.3) as a registry
    engine; interpret-mode on CPU.

    Philox is keyed on the global word index, so this engine is
    bit-for-bit identical to ``multispin`` -- the kernel's pure-jnp
    oracle -- at any block size, and through the resident tier (S9).
    """

    name = "multispin_pallas"
    resident_family = "multispin"

    def __init__(self, config):
        super().__init__(config)
        self.block_rows = _even_block_rows(config.n)
        self.interpret = jax.default_backend() != "tpu"

    def color_update(self, target, op, inv_temp, is_black, seed, offset,
                     ctx=None):
        from repro.kernels.multispin.multispin import multispin_update
        return multispin_update(target, op, inv_temp, is_black=is_black,
                                seed=seed, offset=offset,
                                block_rows=self.block_rows,
                                interpret=self.interpret, thresholds=ctx)

    def resident_sweeps(self, state, inv_temp, seed, start_offset,
                        n_sweeps):
        from repro.kernels.multispin.resident import \
            multispin_sweeps_resident
        return multispin_sweeps_resident(*state, inv_temp,
                                         n_sweeps=n_sweeps, seed=seed,
                                         start_offset=start_offset,
                                         interpret=self.interpret)


# ---------------------------------------------------------------------------
# bitplane engines: 32 replicas/word (DESIGN.md S8)
# ---------------------------------------------------------------------------

@register
class BitplaneEngine(CounterEngine):
    """Bitplane multi-spin coding: 32 independent replica lattices packed
    1 bit/spin into each uint32 word (DESIGN.md S8, Block et al.).

    One simulation advances 32 replicas; ``observables`` returns
    *per-replica* (32,) vectors, which flow through ``measure_scan`` and
    the estimators unchanged (the trajectory gains a trailing replica
    axis).  ``full_lattice`` is the replica-0 view, and ``init_state``
    seeds replica 0 exactly like the single-lattice engines (replica r
    folds r into the key), so the cross-engine init contract holds.
    """

    name = "bitplane"
    replicas = bp.N_REPLICAS
    dist_factory = "bitplane"

    @classmethod
    def validate_lattice(cls, n, m):
        super().validate_lattice(n, m)
        if (m // 2) % 4:
            raise ValueError(
                f"engine {cls.name!r} draws one Philox call per 4-site "
                f"group: the compact plane width m/2 must be a multiple "
                f"of 4, got m={m}")

    def init_state(self, key):
        cfg = self.cfg

        def init_one(k):
            return lat.init_lattice(k, cfg.n, cfg.m, p_up=cfg.init_p_up)

        r0 = init_one(key)
        keys = jax.vmap(lambda r: jax.random.fold_in(key, r))(
            jnp.arange(1, bp.N_REPLICAS))
        rest = jax.vmap(init_one)(keys)
        return bp.pack_lattices(jnp.concatenate([r0[None], rest], axis=0))

    def from_full(self, full):
        black, white = lat.split_checkerboard(full)
        return (bp.broadcast_plane(lat.to_binary(black)),
                bp.broadcast_plane(lat.to_binary(white)))

    def full_lattice(self, state):
        return bp.replica_lattice(*state, r=0)

    def magnetization(self, state):
        # only the magnetizations: skip replica_observables' per-replica
        # energies, which an eager caller would pay for and discard
        fulls = bp.unpack_lattices(*state)
        return jnp.mean(jax.vmap(obs.magnetization_full)(fulls))

    def energy(self, state):
        # only the energies (see magnetization)
        fulls = bp.unpack_lattices(*state)
        return jnp.mean(jax.vmap(obs.energy_per_spin_full)(fulls))

    def observables(self, state, inv_temp):
        """Per-replica vectors: {"m": (32,), "e": (32,)}."""
        return bp.replica_observables(*state)

    def sweep_context(self, inv_temp):
        return ms.acceptance_thresholds(inv_temp)

    def color_update(self, target, op, inv_temp, is_black, seed, offset,
                     ctx=None):
        return bp.update_color_bitplane(target, op, inv_temp, is_black,
                                        seed, offset, thresholds=ctx)

    def state_arrays(self, state):
        return {"black_bits": np.asarray(state[0]),
                "white_bits": np.asarray(state[1])}

    def from_arrays(self, arrays):
        return (jnp.asarray(arrays["black_bits"]),
                jnp.asarray(arrays["white_bits"]))


@register
class BitplanePallasEngine(BitplaneEngine):
    """Fused Pallas bitplane kernel; interpret-mode on CPU.

    Philox is keyed on the global (site // 4, site % 4) pair, so this
    engine is bit-for-bit identical to ``bitplane`` -- the kernel's
    pure-jnp oracle -- at any block size (tests/test_bitplane.py).
    """

    name = "bitplane_pallas"
    resident_family = "bitplane"

    def __init__(self, config):
        super().__init__(config)
        self.block_rows = _even_block_rows(config.n)
        self.interpret = jax.default_backend() != "tpu"

    def color_update(self, target, op, inv_temp, is_black, seed, offset,
                     ctx=None):
        from repro.kernels.bitplane.bitplane import bitplane_update
        return bitplane_update(target, op, inv_temp, is_black=is_black,
                               seed=seed, offset=offset,
                               block_rows=self.block_rows,
                               interpret=self.interpret, thresholds=ctx)

    def resident_sweeps(self, state, inv_temp, seed, start_offset,
                        n_sweeps):
        from repro.kernels.bitplane.resident import \
            bitplane_sweeps_resident
        return bitplane_sweeps_resident(*state, inv_temp,
                                        n_sweeps=n_sweeps, seed=seed,
                                        start_offset=start_offset,
                                        interpret=self.interpret)


# ---------------------------------------------------------------------------
# tensor-core (MXU) engine
# ---------------------------------------------------------------------------

@register
class TensorCoreEngine(Engine):
    """Paper S3.2: neighbor sums as banded MXU matmuls (DESIGN.md S6.1)."""

    name = "tensorcore"
    param_fields = ("tc_block",)

    def from_full(self, full):
        return tc.decompose(full)

    def full_lattice(self, state):
        return tc.recompose(state)

    def magnetization(self, state):
        m = sum(p.astype(jnp.float32).sum() for p in state.values())
        return m / (self.cfg.n * self.cfg.m)

    def scan_step(self, state, inv_temp, seed, step_count, n_sweeps):
        key = jax.random.fold_in(jax.random.PRNGKey(seed), step_count)
        planes, _ = tc.run_sweeps_tc(state, inv_temp, key, n_sweeps,
                                     block=self.cfg.tc_block)
        return planes


    def state_arrays(self, state):
        return {f"plane_{k}": np.asarray(v) for k, v in state.items()}

    def from_arrays(self, arrays):
        return {k: jnp.asarray(arrays[f"plane_{k}"])
                for k in ("00", "01", "10", "11")}


# ---------------------------------------------------------------------------
# Wolff cluster engine
# ---------------------------------------------------------------------------

@register
class WolffEngine(Engine):
    """Wolff cluster updates (paper S2): one "sweep" = one cluster flip."""

    name = "wolff"

    def from_full(self, full):
        return full

    def full_lattice(self, state):
        return state

    def scan_step(self, state, inv_temp, seed, step_count, n_sweeps):
        # cfg.temperature, not 1/inv_temp: the float32 round trip can land
        # 1 ulp off, which would fork the scan path from ``sweeps``; wolff
        # is key-based so it is never vmapped over an inv_temp batch
        key = jax.random.fold_in(jax.random.PRNGKey(seed), step_count)
        new, _ = wolff_mod.run_wolff(key, state,
                                     jnp.float32(self.cfg.temperature),
                                     n_sweeps)
        return new


    def state_arrays(self, state):
        return {"lattice": np.asarray(state)}

    def from_arrays(self, arrays):
        return jnp.asarray(arrays["lattice"])


# ---------------------------------------------------------------------------
# Edwards-Anderson spin-glass engine
# ---------------------------------------------------------------------------

@register
class SpinGlassEngine(Engine):
    """2D +-J Edwards-Anderson spin glass (paper S6's extension).

    State carries the quenched couplings alongside the lattice so a
    checkpoint restores the exact disorder realization.  Couplings are a
    pure function of the config seed (fold_in with a fixed tag), so two
    simulations with the same seed share a disorder sample.
    """

    name = "spinglass"
    param_fields = ("p_ferro",)

    _COUPLING_TAG = 0x51A55  # "glass": fold_in tag for the coupling stream

    def from_full(self, full):
        cfg = self.cfg
        ck = jax.random.fold_in(jax.random.PRNGKey(cfg.seed),
                                self._COUPLING_TAG)
        j_up, j_left = sg.init_couplings(ck, cfg.n, cfg.m,
                                         p_ferro=cfg.p_ferro)
        return (full, j_up, j_left)

    def full_lattice(self, state):
        return state[0]

    def magnetization(self, state):
        return state[0].astype(jnp.float32).mean()

    def observables(self, state, inv_temp):
        # energy must weight every bond by its quenched coupling; the
        # layout-generic full-lattice default would silently assume J=+1
        return {"m": obs.magnetization_full(state[0]),
                "e": sg.energy_per_spin(*state)}

    def scan_step(self, state, inv_temp, seed, step_count, n_sweeps):
        full, j_up, j_left = state
        key = jax.random.fold_in(jax.random.PRNGKey(seed), step_count)
        full, _ = sg.run_sweeps(full, j_up, j_left, inv_temp, key, n_sweeps)
        return (full, j_up, j_left)


    def state_arrays(self, state):
        return {"lattice": np.asarray(state[0]),
                "j_up": np.asarray(state[1]),
                "j_left": np.asarray(state[2])}

    def from_arrays(self, arrays):
        return (jnp.asarray(arrays["lattice"]),
                jnp.asarray(arrays["j_up"]),
                jnp.asarray(arrays["j_left"]))
