"""Wolff cluster algorithm (paper S2) -- the critical-slowing-down fix.

The paper motivates Metropolis by noting Wolff is inefficient away from
T_c; we implement Wolff anyway as the framework's cluster-update option so
the crossover can be studied.  Cluster growth is a frontier BFS expressed
as ``lax.while_loop`` over boolean masks: every step, all four neighbors
of the current frontier that carry the seed spin and are not yet in the
cluster are admitted independently with ``p_add = 1 - exp(-2 beta J)``
(bonds re-tested from each new frontier site, per the correct algorithm).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def _neighbor_or(mask):
    """Union of the 4-neighborhood of a boolean mask (periodic)."""
    return (jnp.roll(mask, 1, 0) | jnp.roll(mask, -1, 0)
            | jnp.roll(mask, 1, 1) | jnp.roll(mask, -1, 1))


@functools.partial(jax.jit, static_argnames=())
def wolff_step(key, lattice, temperature):
    """One cluster flip. lattice: (N, M) int8 +-1. Returns (lattice, size)."""
    n, m = lattice.shape
    p_add = 1.0 - jnp.exp(-2.0 / temperature)
    k_seed, k_loop = jax.random.split(key)
    flat = jax.random.randint(k_seed, (), 0, n * m)
    si, sj = flat // m, flat % m
    seed_spin = lattice[si, sj]
    same = lattice == seed_spin

    cluster = jnp.zeros((n, m), bool).at[si, sj].set(True)
    frontier = cluster

    def cond(state):
        _, _, frontier = state
        return frontier.any()

    def body(state):
        key, cluster, frontier = state
        key, kd = jax.random.split(key)
        candidates = _neighbor_or(frontier) & same & ~cluster
        u = jax.random.uniform(kd, (n, m))
        added = candidates & (u < p_add)
        return key, cluster | added, added

    _, cluster, _ = jax.lax.while_loop(cond, body,
                                       (k_loop, cluster, frontier))
    flipped = jnp.where(cluster, -lattice, lattice)
    return flipped.astype(lattice.dtype), cluster.sum()


def run_wolff(key, lattice, temperature, n_steps: int):
    """n_steps cluster flips; returns (lattice, mean cluster size)."""
    def body(i, carry):
        lat, key, tot = carry
        key, k = jax.random.split(key)
        lat, size = wolff_step(k, lat, temperature)
        return lat, key, tot + size

    lat, _, tot = jax.lax.fori_loop(
        0, n_steps, body, (lattice, key, jnp.int32(0)))
    return lat, tot / jnp.maximum(n_steps, 1)
