"""Ising engines: the paper's contribution as composable JAX modules."""
from . import bitplane, distributed, lattice, metropolis, multispin, observables, rng, tensorcore  # noqa: F401
from .engine import ENGINES, Engine, make_engine  # noqa: F401
from .ensemble import Ensemble  # noqa: F401
from .sim import Simulation, SimConfig  # noqa: F401
