"""Counter-based Philox4x32-10 RNG in pure uint32 jnp ops.

The paper's optimized and tensor-core engines use cuRAND's Philox4x32_10
device API with explicit (seed, sequence, offset) skip-ahead so that no RNG
state is ever stored in global memory.  We reproduce exactly that scheme:
``philox4x32(counter, key)`` is a pure function of a 4-lane uint32 counter and
a 2-lane uint32 key, implemented with 16-bit-limb multiplies so it runs
without 64-bit types -- which means the *same* code executes inside Pallas
TPU kernel bodies (VPU uint32 lanes) and in pure-jnp reference paths.

Skip-ahead semantics mirror ``curand_init(seed, sequence, offset)``:
``sequence`` selects the counter high lanes, ``offset`` the low lanes, so any
(step, position) pair addresses an independent 128-bit counter block yielding
4 uint32s.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

# numpy scalars (not jnp arrays) so Pallas kernel bodies see literals,
# not captured constants
PHILOX_M0 = np.uint32(0xD2511F53)
PHILOX_M1 = np.uint32(0xCD9E8D57)
PHILOX_W0 = np.uint32(0x9E3779B9)
PHILOX_W1 = np.uint32(0xBB67AE85)

_LO16 = np.uint32(0xFFFF)

#: half-sweeps per full lattice sweep -- the unit of the Philox offset
#: counter.  Every sweep loop in the repo (host-side fori_loops, the
#: per-half-sweep Pallas wrappers, AND the in-kernel loops of the
#: resident-sweep tier, DESIGN.md S9) advances its offset with
#: :func:`half_sweep_offset`, so the counter layout cannot fork between
#: host-side and in-kernel advancement.
HALF_SWEEPS_PER_SWEEP = 2


def half_sweep_offset(start_offset, sweep, color):
    """Philox offset of half-sweep ``color`` (0 = black, 1 = white) of
    full sweep ``sweep`` past a cumulative ``start_offset``.

    ``start_offset`` itself is in half-sweep units (= 2 x sweeps already
    run, cuRAND's ``offset``); all args may be python ints or traced
    uint32 scalars.  uint32 wrap-around is the cuRAND behavior, kept.
    """
    return (jnp.asarray(start_offset, jnp.uint32)
            + np.uint32(HALF_SWEEPS_PER_SWEEP) * jnp.asarray(sweep,
                                                             jnp.uint32)
            + jnp.asarray(color, jnp.uint32))


def _mulhilo32(a, b):
    """32x32 -> (hi, lo) uint32 multiply via 16-bit limbs (no uint64)."""
    a = a.astype(jnp.uint32)
    b = b.astype(jnp.uint32)
    lo = a * b  # wrapping low half is exact
    a0 = a & _LO16
    a1 = a >> 16
    b0 = b & _LO16
    b1 = b >> 16
    a0b0 = a0 * b0
    a0b1 = a0 * b1
    a1b0 = a1 * b0
    a1b1 = a1 * b1
    # carry out of the middle 32 bits
    mid = (a0b1 & _LO16) + (a1b0 & _LO16) + (a0b0 >> 16)
    hi = a1b1 + (a0b1 >> 16) + (a1b0 >> 16) + (mid >> 16)
    return hi, lo


def _philox_round(c0, c1, c2, c3, k0, k1):
    hi0, lo0 = _mulhilo32(PHILOX_M0, c0)
    hi1, lo1 = _mulhilo32(PHILOX_M1, c2)
    return (hi1 ^ c1 ^ k0, lo1, hi0 ^ c3 ^ k1, lo0)


def philox4x32(c0, c1, c2, c3, k0, k1, rounds: int = 10):
    """Philox4x32-`rounds`. All args broadcastable uint32 arrays.

    Returns 4 uint32 arrays of the broadcast shape.
    """
    c0 = jnp.asarray(c0, jnp.uint32)
    c1 = jnp.asarray(c1, jnp.uint32)
    c2 = jnp.asarray(c2, jnp.uint32)
    c3 = jnp.asarray(c3, jnp.uint32)
    k0 = jnp.asarray(k0, jnp.uint32)
    k1 = jnp.asarray(k1, jnp.uint32)
    for r in range(rounds):
        if r > 0:
            k0 = k0 + PHILOX_W0
            k1 = k1 + PHILOX_W1
        c0, c1, c2, c3 = _philox_round(c0, c1, c2, c3, k0, k1)
    return c0, c1, c2, c3


def seed_keys(seed):
    """Split a seed into the two Philox key lanes ``(k0, k1)``.

    Accepts either a python int (full 64-bit split, cuRAND semantics) or a
    traced uint32 array (high lane zero) -- the latter is what lets the
    ensemble driver ``vmap`` a batch of per-replica seeds through the same
    compiled sweep (DESIGN.md S4).
    """
    if isinstance(seed, (int, np.integer)):
        return (jnp.uint32(seed & 0xFFFFFFFF),
                jnp.uint32((seed >> 32) & 0xFFFFFFFF))
    seed = jnp.asarray(seed).astype(jnp.uint32)
    return seed, jnp.zeros_like(seed)


def uniforms(seed, sequence, offset, n_lanes: int = 4):
    """cuRAND-style draw: (seed, sequence, offset) -> 4 uniform floats in [0,1).

    ``sequence``/``offset`` are uint32 arrays (e.g. linear thread index and a
    per-launch monotonically increasing offset).  Matches the paper's scheme
    where every kernel launch re-inits Philox with the same seed, the thread's
    grid index as sequence, and the cumulative draw count as offset.
    ``seed`` may be a python int or a traced uint32 array (:func:`seed_keys`).
    """
    seq = jnp.asarray(sequence, jnp.uint32)
    off = jnp.asarray(offset, jnp.uint32)
    k0, k1 = seed_keys(seed)
    r0, r1, r2, r3 = philox4x32(off, jnp.zeros_like(seq), seq,
                                jnp.zeros_like(seq), k0, k1)
    return tuple(u32_to_uniform(r) for r in (r0, r1, r2, r3))[:n_lanes]


def u32_to_uniform(bits):
    """uint32 -> float32 uniform in [0, 1) (multiply by 2^-32)."""
    return bits.astype(jnp.float32) * jnp.float32(2.3283064365386963e-10)
