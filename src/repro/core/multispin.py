"""Optimized multi-spin engine (paper S3.3), TPU-adapted, pure JAX reference.

Spins are 0/1 nibbles packed 8-per-uint32 (the TPU VPU analogue of the
paper's 16-per-uint64 -- see DESIGN.md S2).  Per target word the neighbor
sums cost THREE packed adds (vs 24 unpacked for 8 spins).  The Metropolis
accept compares the raw uint32 draw against a 10-entry *integer* threshold
LUT (H1.6) -- acceptance probabilities only take values
``exp(-2 beta (2s-1)(2 nn - 4))`` for ``s in {0,1}, nn in {0..4}``, so the
table is computed once per sweep call and the hot path does zero ``exp``
and zero draw->float conversion (beyond-paper: the paper evaluates exp on
the hot path).

Randomness is in-place counter-based Philox (cuRAND semantics): two
philox4x32 calls yield the 8 uint32 draws a word needs; the counter encodes
(half-sweep offset, word index) so the stream is launch-order independent
and checkpoint-restart continues it exactly.

The Pallas kernel in ``repro/kernels/multispin`` executes this same
algorithm on VMEM tiles; this module is its oracle (`ref.py` delegates here).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import lattice as lat
from . import rng as crng

_NIB = lat.NIBBLE_BITS


def acceptance_table(inv_temp) -> jax.Array:
    """p[s * 5 + nn] = exp(-2 beta (2s-1)(2 nn - 4)), 10 entries."""
    s = jnp.arange(2, dtype=jnp.float32)[:, None]      # 0/1
    nn = jnp.arange(5, dtype=jnp.float32)[None, :]     # 0..4
    p = jnp.exp(-2.0 * inv_temp * (2.0 * s - 1.0) * (2.0 * nn - 4.0))
    return p.reshape(10)


def acceptance_prob(inv_temp, s_u32, nn_u32):
    """Closed-form acceptance: identical floats to acceptance_table[idx]
    (same expression, same op order) but pure-elementwise, so XLA fuses
    it into the surrounding bitwise chain instead of materializing a
    gather -- the S Perf H1.1 change (EXPERIMENTS.md)."""
    s = s_u32.astype(jnp.float32)
    nn = nn_u32.astype(jnp.float32)
    return jnp.exp(-2.0 * inv_temp * (2.0 * s - 1.0) * (2.0 * nn - 4.0))


def acceptance_thresholds(inv_temp) -> jax.Array:
    """The 10-entry acceptance table in the *integer* domain (H1.6).

    ``t[s * 5 + nn]`` is a uint32 threshold such that ``raw_u32_draw < t``
    accepts with probability ``min(1, p(s, nn))`` up to 2^-32 quantization:
    classes with p >= 1 (energy-lowering or neutral flips) map to
    0xFFFFFFFF, so they accept with probability 1 - 2^-32 -- statistically
    invisible, and what buys the hot path freedom from per-spin ``exp``
    *and* the uint32->float32 draw conversion.  Computed once per sweep
    call (10 exps), hoisted out of the fori_loop by the sweep wrappers.
    """
    p = acceptance_table(inv_temp)
    # p < 1 in float32 means p <= 1 - 2^-24, so p * 2^32 <= 2^32 - 256
    # fits uint32 exactly; astype truncates toward zero.
    scaled = p * jnp.float32(4294967296.0)
    return jnp.where(p < 1.0, scaled.astype(jnp.uint32),
                     jnp.uint32(0xFFFFFFFF))


def word_randoms(seed, word_index, offset):
    """8 uint32 draws per word: two Philox4x32 calls (cuRAND-style).

    ``seed`` may be a python int or a traced uint32 array (ensemble vmap).
    """
    k0, k1 = crng.seed_keys(seed)
    z = jnp.zeros_like(word_index)
    lo = crng.philox4x32(jnp.uint32(2 * offset), z, word_index, z, k0, k1)
    hi = crng.philox4x32(jnp.uint32(2 * offset + 1), z, word_index, z, k0, k1)
    return lo + hi  # tuple of 8 uint32 arrays


def update_color_packed(target_words, op_words, inv_temp, is_black: bool,
                        seed: int, offset, thresholds=None):
    """One packed half-sweep. target/op are (N, W) uint32 nibble words.

    The accept is a raw-uint32 compare against the precomputed
    :func:`acceptance_thresholds` table (H1.6): no per-spin ``exp``, no
    draw->float conversion.  ``thresholds`` lets sweep loops hoist the
    table out of their ``fori_loop``; ``None`` computes it here.
    """
    nn_words = lat.packed_neighbor_sums(op_words, is_black)
    n, w = target_words.shape
    widx = jnp.arange(n * w, dtype=jnp.uint32).reshape(n, w)
    draws = word_randoms(seed, widx, offset)
    if thresholds is None:
        thresholds = acceptance_thresholds(inv_temp)

    flip_word = jnp.zeros_like(target_words)
    for nib in range(lat.SPINS_PER_WORD):
        s = (target_words >> jnp.uint32(nib * _NIB)) & jnp.uint32(1)
        nn = (nn_words >> jnp.uint32(nib * _NIB)) & jnp.uint32(0xF)
        idx = (s * jnp.uint32(5) + nn).astype(jnp.int32)
        t = jnp.take(thresholds, idx)   # 10-entry table, integer domain
        flip = (draws[nib] < t).astype(jnp.uint32)
        flip_word = flip_word | (flip << jnp.uint32(nib * _NIB))
    return target_words ^ flip_word


@functools.partial(jax.jit, static_argnames=("n_sweeps", "seed"),
                   donate_argnums=(0, 1))
def run_sweeps_packed(black_words, white_words, inv_temp, n_sweeps: int,
                      seed: int = 0, start_offset=0):
    start_offset = jnp.uint32(start_offset)
    thresholds = acceptance_thresholds(inv_temp)   # hoisted: once per call

    def body(i, carry):
        b, w = carry
        b = update_color_packed(b, w, inv_temp, True, seed,
                                crng.half_sweep_offset(start_offset, i, 0),
                                thresholds)
        w = update_color_packed(w, b, inv_temp, False, seed,
                                crng.half_sweep_offset(start_offset, i, 1),
                                thresholds)
        return (b, w)

    return jax.lax.fori_loop(0, n_sweeps, body,
                             (black_words, white_words))


def pack_lattice(black_pm1, white_pm1):
    """+-1 compact planes -> packed uint32 word planes."""
    return (lat.pack_nibbles(lat.to_binary(black_pm1)),
            lat.pack_nibbles(lat.to_binary(white_pm1)))


def unpack_lattice(black_words, white_words, dtype=jnp.int8):
    return (lat.from_binary(lat.unpack_nibbles(black_words), dtype),
            lat.from_binary(lat.unpack_nibbles(white_words), dtype))
