"""Basic checkerboard Metropolis engine (paper S3.1), pure JAX.

This is the stencil formulation: two compact color planes, 4-neighbor sums
via rolls, Metropolis accept with ``exp(-2 beta nn sigma)``.  Two variants:

* ``update_color``          -- pre-generated uniforms (the paper's basic path,
                               which pre-populates a random array per color);
* ``update_color_philox``   -- in-kernel-style counter-based Philox draws
                               (beyond-paper for the basic engine: removes the
                               uniform-array HBM traffic; see DESIGN.md S6).

Spins are stored as int8 +-1 in the compact planes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import lattice as lat
from . import rng as crng


def neighbor_sums(op_plane: jax.Array, is_black: bool) -> jax.Array:
    """4-neighbor spin sums for every target cell.

    Stays in int8 (H1.5): |sum| <= 4, so the narrow type is exact and
    the working set never widens 4x to int32; callers convert to
    float32 at the accept, where the int32 path converted anyway, so
    flip decisions are bit-identical (tests/test_resident.py).
    """
    op = op_plane.astype(jnp.int8)
    up = jnp.roll(op, 1, axis=0)
    down = jnp.roll(op, -1, axis=0)
    side = lat.side_shift(op, is_black)
    return up + down + op + side


def update_color(target, op_plane, uniforms, inv_temp, is_black: bool,
                 rule: str = "metropolis"):
    """One half-sweep with pre-generated uniforms.

    rule: 'metropolis' (accept with exp(-beta dE)) or 'heatbath'
    (flip with p = e^{-beta dE} / (1 + e^{-beta dE}), paper S2) -- both
    satisfy detailed balance on the checkerboard decomposition.
    """
    nn = neighbor_sums(op_plane, is_black)
    t = target  # +-1 in the plane dtype; int8 negate is exact (H1.5)
    arg = -2.0 * inv_temp * nn.astype(jnp.float32) * t.astype(jnp.float32)
    if rule == "heatbath":
        acceptance = jax.nn.sigmoid(arg)   # e^arg / (1 + e^arg)
    else:
        acceptance = jnp.exp(arg)
    flip = uniforms < acceptance
    return jnp.where(flip, -t, t).astype(target.dtype)


def update_color_philox(target, op_plane, inv_temp, is_black: bool,
                        seed: int, step_offset):
    """One half-sweep drawing uniforms from counter-based Philox in-place."""
    n, half = target.shape
    idx = jnp.arange(n * half, dtype=jnp.uint32).reshape(n, half)
    u = crng.uniforms(seed, idx, jnp.uint32(step_offset))[0]
    return update_color(target, op_plane, u, inv_temp, is_black)


@functools.partial(jax.jit, static_argnames=("n_sweeps", "seed"))
def run_sweeps(black, white, inv_temp, key, n_sweeps: int, seed: int = 0):
    """n_sweeps full lattice sweeps (black then white) with jax.random."""
    def body(i, carry):
        b, w, k = carry
        k, kb, kw = jax.random.split(k, 3)
        ub = jax.random.uniform(kb, b.shape)
        b = update_color(b, w, ub, inv_temp, is_black=True)
        uw = jax.random.uniform(kw, w.shape)
        w = update_color(w, b, uw, inv_temp, is_black=False)
        return (b, w, k)

    return jax.lax.fori_loop(0, n_sweeps, body, (black, white, key))


@functools.partial(jax.jit, static_argnames=("n_sweeps", "seed"),
                   donate_argnums=(0, 1))
def run_sweeps_philox(black, white, inv_temp, n_sweeps: int, seed: int = 0,
                      start_offset=0):
    """n_sweeps full sweeps with deterministic skip-ahead Philox.

    ``start_offset`` is the cumulative half-sweep count already consumed --
    exactly cuRAND's offset mechanism -- so a checkpoint/restart continues
    the *same* random sequence (tested bit-exact in tests/).

    The plane buffers are donated (callers rebind ``b, w = ...``): large
    lattices never hold two copies of a plane in HBM.
    """
    start_offset = jnp.uint32(start_offset)

    def body(i, carry):
        b, w = carry
        b = update_color_philox(b, w, inv_temp, True, seed,
                                crng.half_sweep_offset(start_offset, i, 0))
        w = update_color_philox(w, b, inv_temp, False, seed,
                                crng.half_sweep_offset(start_offset, i, 1))
        return (b, w)

    return jax.lax.fori_loop(0, n_sweeps, body, (black, white))
