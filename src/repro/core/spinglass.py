"""2D Edwards-Anderson Ising spin glass (paper S6's suggested extension).

"These codes can be easily extended to simulate other models for which
there are no analytical solutions, for instance a 2D Ising spin glass
model" -- here it is: quenched random couplings J_ij = +-1 per bond, same
checkerboard decomposition, Metropolis accept on the *coupling-weighted*
neighbor sum.

Bond layout: two compact coupling planes per color pair are not needed --
it is enough to store, for every site, the couplings to its N/S/E/W
neighbors with the convention that ``j_up[i,j]`` is the bond between
(i,j) and (i-1,j), so consistency requires j_up[i] == j_down[i-1]; we
generate j_up and j_left freely and derive the opposite directions by
rolls, which guarantees symmetry.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import lattice as lat


def init_couplings(key, n: int, m: int, p_ferro: float = 0.5):
    """Quenched +-1 bonds: (j_up, j_left) full-lattice planes."""
    k1, k2 = jax.random.split(key)
    j_up = jnp.where(jax.random.uniform(k1, (n, m)) < p_ferro, 1, -1)
    j_left = jnp.where(jax.random.uniform(k2, (n, m)) < p_ferro, 1, -1)
    return j_up.astype(jnp.int8), j_left.astype(jnp.int8)


def weighted_neighbor_sums(full, j_up, j_left):
    """sum_j J_ij sigma_j for every site of the full lattice."""
    s = full.astype(jnp.int32)
    ju = j_up.astype(jnp.int32)
    jl = j_left.astype(jnp.int32)
    up = ju * jnp.roll(s, 1, 0)                       # bond to (i-1, j)
    down = jnp.roll(ju, -1, 0) * jnp.roll(s, -1, 0)   # bond (i+1,j) uses its j_up
    left = jl * jnp.roll(s, 1, 1)
    right = jnp.roll(jl, -1, 1) * jnp.roll(s, -1, 1)
    return up + down + left + right


def energy_per_spin(full, j_up, j_left):
    """-1/N sum_<ij> J_ij s_i s_j (each bond once)."""
    s = full.astype(jnp.float32)
    e = -(j_up.astype(jnp.float32) * s * jnp.roll(s, 1, 0)).sum()
    e -= (j_left.astype(jnp.float32) * s * jnp.roll(s, 1, 1)).sum()
    return e / full.size


def update_color(full, j_up, j_left, uniforms, inv_temp, color: int):
    """Metropolis half-sweep on sites with (i+j)%2 == color."""
    nn = weighted_neighbor_sums(full, j_up, j_left)
    s = full.astype(jnp.int32)
    acc = jnp.exp(-2.0 * inv_temp * nn.astype(jnp.float32)
                  * s.astype(jnp.float32))
    ii = jax.lax.broadcasted_iota(jnp.int32, full.shape, 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, full.shape, 1)
    on_color = ((ii + jj) % 2) == color
    flip = on_color & (uniforms < acc)
    return jnp.where(flip, -s, s).astype(full.dtype)


@functools.partial(jax.jit, static_argnames=("n_sweeps",))
def run_sweeps(full, j_up, j_left, inv_temp, key, n_sweeps: int):
    def body(i, carry):
        f, k = carry
        k, k0, k1 = jax.random.split(k, 3)
        f = update_color(f, j_up, j_left,
                         jax.random.uniform(k0, f.shape), inv_temp, 0)
        f = update_color(f, j_up, j_left,
                         jax.random.uniform(k1, f.shape), inv_temp, 1)
        return (f, k)
    return jax.lax.fori_loop(0, n_sweeps, body, (full, key))
