"""Bitplane multi-spin coding: 32 independent replicas, 1 bit/spin/word.

The nibble engine (``core.multispin``) packs 8 *spatial* sites per uint32;
this module packs the other axis, following Block, Virnau & Preis
(arXiv:1007.3726): bit ``r`` of word ``(i, k)`` is the 0/1 spin of
**replica r** at compact site ``(i, k)``, so one ``(N, M/2)`` uint32 color
plane holds 32 complete, independently-evolving lattices.  Three levers
fall out of the layout (DESIGN.md S8):

* **Neighbor sums as carry-save adders** -- the 4-neighbor up-count
  (0..4) of all 32 replicas at a site is three *bitplanes* ``(n0, n1,
  n2)`` produced by a bit-sliced 4-input adder: 8 bitwise ops per word,
  i.e. 1/4 op per replica-spin (vs 3 packed adds per 8 spins for the
  nibble engine).
* **One shared Philox draw per site** -- all 32 replicas at a site
  consume the SAME uint32 draw (one Philox4x32 call per FOUR sites), a
  32x reduction in randomness cost over the nibble engine's
  draw-per-spin.  The chains remain individually exact Metropolis
  chains, but they are *correlated across replicas at equal
  (site, step)* -- see the shared-randoms caveat in DESIGN.md S8:
  replica series may be averaged (each is a valid estimator) but never
  treated as 32 fully independent streams when deriving error bars.
  The coupling also means identical configurations never separate, and
  below T_c replicas falling into the same magnetization well COALESCE
  into bit-identical lattices; the replica multiplier is real above and
  near T_c (where the extra samples matter) and void deep in the
  ordered phase -- use an Ensemble of distinct seeds there.
* **Bit-parallel accept** -- with the integer-domain 10-entry threshold
  table (``multispin.acceptance_thresholds``, H1.6) the accept for all
  32 replicas is ``OR_c(class_mask_c & broadcast(u < t_c))`` over the 10
  ``(s, nn)`` classes: pure boolean logic, zero ``exp``, zero per-spin
  extraction on the hot path.

The Pallas kernel in ``repro/kernels/bitplane`` executes this same
algorithm on VMEM tiles; this module is its bit-exact oracle (``ref.py``
delegates here).  The distributed variant is
``core.distributed.make_bitplane_ising_step``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import lattice as lat
from . import multispin as ms
from . import observables as obs
from . import rng as crng

N_REPLICAS = 32
# numpy scalar (not a jnp array) so Pallas kernel bodies see a
# literal, not a captured constant (same convention as core/rng.py)
_FULL = np.uint32(0xFFFFFFFF)


# ---------------------------------------------------------------------------
# packing: replica axis <-> word bits
# ---------------------------------------------------------------------------

def pack_replicas(planes01: jax.Array) -> jax.Array:
    """(32, N, C) 0/1 planes -> (N, C) uint32 words, bit r = replica r."""
    assert planes01.shape[0] == N_REPLICAS, planes01.shape
    shifts = jnp.arange(N_REPLICAS, dtype=jnp.uint32)[:, None, None]
    return jnp.sum(planes01.astype(jnp.uint32) << shifts, axis=0,
                   dtype=jnp.uint32)


def unpack_replicas(words: jax.Array) -> jax.Array:
    """(N, C) uint32 words -> (32, N, C) 0/1 uint32 planes."""
    shifts = jnp.arange(N_REPLICAS, dtype=jnp.uint32)[:, None, None]
    return (words[None] >> shifts) & jnp.uint32(1)


def pack_lattices(fulls_pm1: jax.Array):
    """(32, N, M) +-1 replica lattices -> (black_words, white_words)."""
    black, white = jax.vmap(lat.split_checkerboard)(fulls_pm1)
    return (pack_replicas(lat.to_binary(black)),
            pack_replicas(lat.to_binary(white)))


def unpack_lattices(black_words, white_words, dtype=jnp.int8) -> jax.Array:
    """(N, W) word planes -> (32, N, M) +-1 replica lattices."""
    black = lat.from_binary(unpack_replicas(black_words), dtype)
    white = lat.from_binary(unpack_replicas(white_words), dtype)
    return jax.vmap(lat.merge_checkerboard)(black, white)


def replica_lattice(black_words, white_words, r: int,
                    dtype=jnp.int8) -> jax.Array:
    """The (N, M) +-1 lattice of ONE replica (cheap single-bit extract)."""
    sh = jnp.uint32(r)
    black = lat.from_binary((black_words >> sh) & jnp.uint32(1), dtype)
    white = lat.from_binary((white_words >> sh) & jnp.uint32(1), dtype)
    return lat.merge_checkerboard(black, white)


def broadcast_plane(plane01: jax.Array) -> jax.Array:
    """0/1 plane -> word plane with all 32 replicas equal to it."""
    return plane01.astype(jnp.uint32) * _FULL


# ---------------------------------------------------------------------------
# bit-sliced neighbor counting
# ---------------------------------------------------------------------------

def bit_count_neighbors(up, down, center, side):
    """Carry-save 4-input adder: the 3-bit neighbor up-count of all 32
    replicas in 8 bitwise ops.

    Returns bitplanes ``(n0, n1, n2)`` with per-replica count
    ``n0 + 2*n1 + 4*n2`` in 0..4 (so n2 implies n0 = n1 = 0).
    """
    t = up ^ down
    s = t ^ center                      # low bit of up+down+center
    k = (up & down) | (center & t)      # carry of up+down+center
    n0 = s ^ side
    k2 = s & side
    n1 = k ^ k2
    n2 = k & k2
    return n0, n1, n2


def neighbor_counts(op_words: jax.Array, is_black: bool):
    """(n0, n1, n2) count bitplanes from the opposite color plane.

    Same neighbor geometry as the compact-plane engines (one word per
    site): up/down rolls plus the row-parity side tap
    (:func:`lattice.side_shift` operates bitwise-transparently on words).
    """
    up = jnp.roll(op_words, 1, axis=0)
    down = jnp.roll(op_words, -1, axis=0)
    side = lat.side_shift(op_words, is_black)
    return bit_count_neighbors(up, down, op_words, side)


# ---------------------------------------------------------------------------
# shared randomness: ONE uint32 per site
# ---------------------------------------------------------------------------

def site_randoms(seed, n_rows: int, n_cols: int, offset) -> jax.Array:
    """One uint32 draw per site, shared by all 32 replicas in the word.

    One Philox4x32 call serves FOUR sites: counter = (offset, 0,
    site_index // 4, 0), lane = site_index % 4 in row-major site order --
    the cuRAND-style skip-ahead scheme of DESIGN.md S4, so checkpoint
    restarts and the distributed step (which recomputes the same
    (group, lane) per global site) reproduce the stream exactly.
    """
    assert n_cols % 4 == 0, "bitplane planes need a multiple-of-4 width"
    k0, k1 = crng.seed_keys(seed)
    g = jnp.arange(n_rows * n_cols // 4, dtype=jnp.uint32)
    z = jnp.zeros_like(g)
    r = crng.philox4x32(jnp.asarray(offset, jnp.uint32), z, g, z, k0, k1)
    return jnp.stack(r, axis=-1).reshape(n_rows, n_cols)


# ---------------------------------------------------------------------------
# bit-parallel Metropolis accept
# ---------------------------------------------------------------------------

def flip_word_from_classes(target, counts, draws, thresholds) -> jax.Array:
    """``OR_c(class_mask_c & broadcast(u < t_c))`` over the 10 (s, nn)
    classes: the flip decision of all 32 replicas as pure boolean logic.

    ``thresholds`` is indexable by the static class id ``s * 5 + nn``
    (a (10,) uint32 array here; the Pallas kernel passes a list of SMEM
    scalar reads), so no gather ever materializes.
    """
    n0, n1, n2 = counts
    not_t, not_n0, not_n1, not_n2 = ~target, ~n0, ~n1, ~n2
    zero = np.uint32(0)
    flip = jnp.zeros_like(target)
    for s in (0, 1):
        s_mask = target if s else not_t
        for nn in range(5):
            mask = (s_mask
                    & (n0 if nn & 1 else not_n0)
                    & (n1 if nn & 2 else not_n1)
                    & (n2 if nn & 4 else not_n2))
            accept = jnp.where(draws < thresholds[s * 5 + nn], _FULL, zero)
            flip = flip | (mask & accept)
    return flip


def update_color_bitplane(target_words, op_words, inv_temp, is_black: bool,
                          seed, offset, thresholds=None) -> jax.Array:
    """One bitplane half-sweep of all 32 replicas.

    ``thresholds`` lets sweep loops hoist the acceptance table out of
    their ``fori_loop`` (H1.6); ``None`` computes it here.
    """
    if thresholds is None:
        thresholds = ms.acceptance_thresholds(inv_temp)
    counts = neighbor_counts(op_words, is_black)
    n, w = target_words.shape
    draws = site_randoms(seed, n, w, offset)
    return target_words ^ flip_word_from_classes(target_words, counts,
                                                 draws, thresholds)


@functools.partial(jax.jit, static_argnames=("n_sweeps", "seed"),
                   donate_argnums=(0, 1))
def run_sweeps_bitplane(black_words, white_words, inv_temp, n_sweeps: int,
                        seed: int = 0, start_offset=0):
    start_offset = jnp.uint32(start_offset)
    thresholds = ms.acceptance_thresholds(inv_temp)  # hoisted: once per call

    def body(i, carry):
        b, w = carry
        b = update_color_bitplane(b, w, inv_temp, True, seed,
                                  crng.half_sweep_offset(start_offset, i,
                                                         0), thresholds)
        w = update_color_bitplane(w, b, inv_temp, False, seed,
                                  crng.half_sweep_offset(start_offset, i,
                                                         1), thresholds)
        return (b, w)

    return jax.lax.fori_loop(0, n_sweeps, body,
                             (black_words, white_words))


# ---------------------------------------------------------------------------
# per-replica observables
# ---------------------------------------------------------------------------

def replica_observables(black_words, white_words) -> dict:
    """{"m": (32,), "e": (32,)} -- one value per replica lattice.

    Measurement path, not hot path: unpacks to the (32, N, M) replica
    stack and vmaps the layout-independent full-lattice observables, so
    each entry is bit-identical to measuring that replica's lattice alone.
    """
    fulls = unpack_lattices(black_words, white_words)
    return {"m": jax.vmap(obs.magnetization_full)(fulls),
            "e": jax.vmap(obs.energy_per_spin_full)(fulls)}
