"""Version-compat shims for JAX APIs that moved between releases.

The repo targets current JAX, but the tier-1 container pins an older
jaxlib; these shims keep both working.  Keep this module tiny: one
function per moved API, no behavior differences beyond the rename.
"""
from __future__ import annotations

import jax


def axis_size(axis_name):
    """``jax.lax.axis_size`` (new) or ``psum(1, axis)`` (old) -- both are
    static python ints inside shard_map, usable for ppermute tables."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` (jax >= 0.6) or the ``jax.experimental`` original
    (where ``check_vma`` was spelled ``check_rep``)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=check_vma)
