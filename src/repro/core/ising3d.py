"""3D Ising model -- the case the paper motivates in S2 ("the study of
spin systems in higher dimensions is by no means trivial" -- no analytical
solution; numerical simulation only; cubic-lattice Tc ~= 4.5115 J).

Same checkerboard idea, one more axis: color = (i+j+k) % 2, 6 neighbors.
Uses the H1.4 fused-stencil pattern (pad+slice shifts, mask select) so the
update stays a single fusion.  Distributed: slab over the leading axis
with ppermute halos (make_ising3d_step), same ring machinery as 2D.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import compat

T_CRITICAL_3D = 4.5115  # numerically known, J = 1


def neighbor_sums_3d(s):
    """6-neighbor sums with periodic wrap (single device)."""
    x = s.astype(jnp.int32)
    out = jnp.zeros_like(x)
    for axis in range(3):
        out = out + jnp.roll(x, 1, axis) + jnp.roll(x, -1, axis)
    return out


def _color_mask(shape, color):
    ii = jax.lax.broadcasted_iota(jnp.int32, shape, 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, shape, 1)
    kk = jax.lax.broadcasted_iota(jnp.int32, shape, 2)
    return ((ii + jj + kk) % 2) == color


def update_color_3d(full, uniforms, inv_temp, color: int):
    nn = neighbor_sums_3d(full)
    s = full.astype(jnp.int32)
    acc = jnp.exp(-2.0 * inv_temp * nn.astype(jnp.float32)
                  * s.astype(jnp.float32))
    flip = _color_mask(full.shape, color) & (uniforms < acc)
    return jnp.where(flip, -s, s).astype(full.dtype)


@functools.partial(jax.jit, static_argnames=("n_sweeps",))
def run_sweeps_3d(full, inv_temp, key, n_sweeps: int):
    def body(i, carry):
        f, k = carry
        k, k0, k1 = jax.random.split(k, 3)
        f = update_color_3d(f, jax.random.uniform(k0, f.shape), inv_temp, 0)
        f = update_color_3d(f, jax.random.uniform(k1, f.shape), inv_temp, 1)
        return (f, k)
    return jax.lax.fori_loop(0, n_sweeps, body, (full, key))


def magnetization_3d(full):
    return full.astype(jnp.float32).mean()


# ---------------------------------------------------------------------------
# distributed: slab over axis 0, ppermute halos (paper S4 in 3D)
# ---------------------------------------------------------------------------

def make_ising3d_step(mesh, *, n: int, seed: int = 0, n_sweeps: int = 1,
                      slab_axes=None):
    """Slab-decomposed 3D sweep over ``slab_axes`` (default: all mesh
    axes flattened into the leading lattice axis ring)."""
    from . import distributed as dist
    from . import rng as crng

    names = list(mesh.axis_names)
    slab_axes = tuple(slab_axes if slab_axes is not None else names)
    spec = P(slab_axes, None, None)

    def update(full, inv_temp, color, offset):
        top = dist.ring_shift(full[-1:], slab_axes, +1)
        bottom = dist.ring_shift(full[:1], slab_axes, -1)
        nl = full.shape[0]
        x = full.astype(jnp.int32)
        row_i = jax.lax.broadcasted_iota(jnp.int32, full.shape, 0)

        def shift0(v, d):
            padded = jnp.pad(v, ((1, 1), (0, 0), (0, 0)))
            return jax.lax.slice_in_dim(padded, 1 + d, 1 + d + nl, axis=0)

        nn = (jnp.where(row_i == 0, top.astype(jnp.int32), shift0(x, -1))
              + jnp.where(row_i == nl - 1, bottom.astype(jnp.int32),
                          shift0(x, 1)))
        for axis in (1, 2):
            nn = nn + jnp.roll(x, 1, axis) + jnp.roll(x, -1, axis)

        # global-position-keyed philox (grid independence, as in 2D)
        r0 = jnp.int32(0)
        for a in slab_axes:
            r0 = r0 * compat.axis_size(a) + jax.lax.axis_index(a)
        gi = (r0 * nl + row_i) * full.shape[1] * full.shape[2] \
            + jax.lax.broadcasted_iota(jnp.int32, full.shape, 1) \
            * full.shape[2] \
            + jax.lax.broadcasted_iota(jnp.int32, full.shape, 2)
        u = crng.uniforms(seed, gi.astype(jnp.uint32),
                          jnp.uint32(offset))[0]
        acc = jnp.exp(-2.0 * inv_temp * nn.astype(jnp.float32)
                      * x.astype(jnp.float32))
        ii = row_i + r0 * nl  # global parity along the sharded axis
        jj = jax.lax.broadcasted_iota(jnp.int32, full.shape, 1)
        kk = jax.lax.broadcasted_iota(jnp.int32, full.shape, 2)
        flip = (((ii + jj + kk) % 2) == color) & (u < acc)
        return jnp.where(flip, -x, x).astype(full.dtype)

    @functools.partial(compat.shard_map, mesh=mesh, in_specs=(spec, P(), P()),
                       out_specs=spec, check_vma=False)
    def sweeps(full, inv_temp, sweep0):
        def body(i, f):
            f = update(f, inv_temp, 0, crng.half_sweep_offset(sweep0, i, 0))
            f = update(f, inv_temp, 1, crng.half_sweep_offset(sweep0, i, 1))
            return f
        return jax.lax.fori_loop(0, n_sweeps, body, full)

    return jax.jit(sweeps), jax.sharding.NamedSharding(mesh, spec)
