"""Tensor-core (MXU) engine: neighbor sums as banded matmuls (paper S3.2).

The lattice is viewed as four interleaved planes ``sigma_xy[a,b] =
full[2a+x, 2b+y]`` (the right-most layout in paper Fig. 1; black = 00/11,
white = 01/10).  Sub-lattice-local neighbor sums are two batched
``B x B`` matmuls against the banded kernel matrix ``K`` (Eq. 2-6) --
executed on the MXU in bf16, the TPU analogue of cublasHgemmBatched on
tensor cores -- followed by a boundary correction for the block edges and
the Metropolis accept.

The paper's point, which we reproduce quantitatively in the roofline
analysis, is that only 2 of the B MACs per output contribute (useful-FLOP
fraction 2/B = 1/64 at B=128) and the extra HBM round-trips make this a
net loss; see ``repro/kernels/tensorcore`` for the beyond-paper fused
variant that removes the round-trips.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import rng as crng

BLOCK = 128  # paper: 256x256 sub-lattices = four 128x128 same-color blocks


def make_kernel_matrix(block: int = BLOCK, dtype=jnp.bfloat16) -> jax.Array:
    """Banded K: ones on the diagonal and superdiagonal (Eq. 2)."""
    k = jnp.eye(block, dtype=dtype)
    return k + jnp.eye(block, k=1, dtype=dtype)


def decompose(full: jax.Array):
    """(N, M) full lattice -> four (N/2, M/2) planes keyed '00','01','10','11'."""
    return {
        "00": full[0::2, 0::2], "01": full[0::2, 1::2],
        "10": full[1::2, 0::2], "11": full[1::2, 1::2],
    }


def recompose(planes) -> jax.Array:
    h, w = planes["00"].shape
    full = jnp.zeros((2 * h, 2 * w), planes["00"].dtype)
    full = full.at[0::2, 0::2].set(planes["00"])
    full = full.at[0::2, 1::2].set(planes["01"])
    full = full.at[1::2, 0::2].set(planes["10"])
    full = full.at[1::2, 1::2].set(planes["11"])
    return full


def _blk(p: jax.Array, b: int) -> jax.Array:
    """(H, W) -> (H/b, W/b, b, b) block view."""
    h, w = p.shape
    return p.reshape(h // b, b, w // b, b).transpose(0, 2, 1, 3)


def _unblk(p: jax.Array) -> jax.Array:
    nb, mb, b, _ = p.shape
    return p.transpose(0, 2, 1, 3).reshape(nb * b, mb * b)


def local_nn_sums(planes, block: int = BLOCK):
    """Sub-lattice-local neighbor sums for all four planes via batched GEMMs.

    nn(s00) = s01 K   + K^T s10        nn(s11) = s10 K^T + K s01
    nn(s10) = s11 K   + K s00          nn(s01) = s00 K^T + K^T s11
    """
    k = make_kernel_matrix(block)
    kt = k.T
    b = {key: _blk(v.astype(jnp.bfloat16), block) for key, v in planes.items()}

    def bmm_r(x, m):   # per-block x @ m
        return jnp.einsum("nmij,jk->nmik", x, m,
                          preferred_element_type=jnp.float32)

    def bmm_l(m, x):   # per-block m @ x
        return jnp.einsum("ij,nmjk->nmik", m, x,
                          preferred_element_type=jnp.float32)

    nn = {
        "00": bmm_r(b["01"], k) + bmm_l(kt, b["10"]),
        "11": bmm_r(b["10"], kt) + bmm_l(k, b["01"]),
        "10": bmm_r(b["11"], k) + bmm_l(k, b["00"]),
        "01": bmm_r(b["00"], kt) + bmm_l(kt, b["11"]),
    }
    return {key: _unblk(v) for key, v in nn.items()}


def boundary_corrections(planes, block: int = BLOCK):
    """Cross-block (and periodic-wrap) contributions missed by local sums.

    This is the paper's standalone boundary kernel: for each plane the
    block-edge rows/columns need one neighbor from the adjacent block.
    """
    f32 = {k: v.astype(jnp.float32) for k, v in planes.items()}
    h, w = f32["00"].shape
    col = jnp.arange(w) % block
    row = jnp.arange(h) % block
    first_c = (col == 0)[None, :]
    last_c = (col == block - 1)[None, :]
    first_r = (row == 0)[:, None]
    last_r = (row == block - 1)[:, None]

    def left(p):   # p[a, b-1] with wrap
        return jnp.roll(p, 1, axis=1)

    def right(p):
        return jnp.roll(p, -1, axis=1)

    def up(p):
        return jnp.roll(p, 1, axis=0)

    def down(p):
        return jnp.roll(p, -1, axis=0)

    return {
        "00": first_c * left(f32["01"]) + first_r * up(f32["10"]),
        "11": last_c * right(f32["10"]) + last_r * down(f32["01"]),
        "10": first_c * left(f32["11"]) + last_r * down(f32["00"]),
        "01": last_c * right(f32["00"]) + first_r * up(f32["11"]),
    }


def neighbor_sums_tc(planes, block: int = BLOCK):
    """Complete neighbor sums = local GEMM sums + boundary corrections."""
    nn = local_nn_sums(planes, block)
    bc = boundary_corrections(planes, block)
    return {k: nn[k] + bc[k] for k in nn}


_COLOR_PLANES = {"black": ("00", "11"), "white": ("01", "10")}


def update_color_tc(planes, color: str, inv_temp, key, block: int = BLOCK):
    """Metropolis half-sweep for one color using MXU neighbor sums."""
    nn = neighbor_sums_tc(planes, block)
    out = dict(planes)
    keys = jax.random.split(key, 2)
    for sub, k in zip(_COLOR_PLANES[color], keys):
        t = planes[sub].astype(jnp.float32)
        acc = jnp.exp(-2.0 * inv_temp * nn[sub] * t)
        u = jax.random.uniform(k, t.shape)
        out[sub] = jnp.where(u < acc, -t, t).astype(planes[sub].dtype)
    return out


@functools.partial(jax.jit, static_argnames=("n_sweeps", "block"))
def run_sweeps_tc(planes, inv_temp, key, n_sweeps: int, block: int = BLOCK):
    def body(i, carry):
        p, k = carry
        k, kb, kw = jax.random.split(k, 3)
        p = update_color_tc(p, "black", inv_temp, kb, block)
        p = update_color_tc(p, "white", inv_temp, kw, block)
        return (p, k)

    return jax.lax.fori_loop(0, n_sweeps, body, (planes, key))
