"""Checkerboard lattice (de)composition and multi-spin packing.

Follows the paper's data layout (Fig. 1 / Fig. 3):

* the abstract ``(N, M)`` lattice of spins sigma = +-1 is split into two
  color planes of shape ``(N, M/2)`` -- *black* cells are those with
  ``(i + j) % 2 == 0`` -- with each color compacted along rows;
* for the multi-spin engine, a color plane is packed 4 bits/spin into
  uint32 words (8 spins/word; the TPU VPU datapath is 32-bit, so uint32
  replaces the paper's 64-bit words), with the 0/1 encoding
  ``s = (sigma + 1) / 2`` that makes nibble-parallel neighbor sums exact.

Neighbor indexing in the compact planes (paper Fig. 2 / Fig. 3): for a
*black* target at ``(i, k)`` the four neighbors are the opposite plane's
``(i-1, k)``, ``(i, k)``, ``(i+1, k)`` and ``(i, k+1)`` on odd rows /
``(i, k-1)`` on even rows; the side offset parity flips for white targets.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

SPINS_PER_WORD = 8  # 4 bits/spin in uint32
NIBBLE_BITS = 4


def init_lattice(key, n: int, m: int, p_up: float = 0.5,
                 dtype=jnp.int8) -> jax.Array:
    """Random +-1 lattice of shape (n, m)."""
    u = jax.random.uniform(key, (n, m))
    return jnp.where(u < p_up, 1, -1).astype(dtype)


def split_checkerboard(lattice: jax.Array):
    """(N, M) full lattice -> (black, white) compact planes of (N, M/2).

    black[i, k] = lattice[i, 2k + i%2]; white[i, k] = lattice[i, 2k + (i+1)%2].
    """
    n, m = lattice.shape
    assert m % 2 == 0, "lattice width must be even"
    pairs = lattice.reshape(n, m // 2, 2)
    rows = jnp.arange(n) % 2
    black = jnp.take_along_axis(
        pairs, rows[:, None, None].astype(jnp.int32), axis=2)[..., 0]
    white = jnp.take_along_axis(
        pairs, (1 - rows)[:, None, None].astype(jnp.int32), axis=2)[..., 0]
    return black, white


def merge_checkerboard(black: jax.Array, white: jax.Array) -> jax.Array:
    """Inverse of :func:`split_checkerboard`."""
    n, half = black.shape
    rows = (jnp.arange(n) % 2)[:, None]
    even_pairs = jnp.stack([black, white], axis=-1)  # even rows: black first
    odd_pairs = jnp.stack([white, black], axis=-1)
    pairs = jnp.where(rows[..., None] == 0, even_pairs, odd_pairs)
    return pairs.reshape(n, 2 * half)


def side_shift(op_plane: jax.Array, is_black: bool) -> jax.Array:
    """The 4th (same-row) neighbor of every target cell, in target coords.

    For black targets: odd rows take (i, k+1), even rows (i, k-1); reversed
    for white targets. Periodic wrap via roll.
    """
    rows = (jnp.arange(op_plane.shape[0]) % 2)[:, None]
    plus = jnp.roll(op_plane, -1, axis=1)   # (i, k+1)
    minus = jnp.roll(op_plane, 1, axis=1)   # (i, k-1)
    if is_black:
        return jnp.where(rows == 1, plus, minus)
    return jnp.where(rows == 1, minus, plus)


# ---------------------------------------------------------------------------
# multi-spin packing: 0/1 spins, 4 bits each, 8 per uint32 word
# ---------------------------------------------------------------------------

def to_binary(plane_pm1: jax.Array) -> jax.Array:
    """+-1 int plane -> 0/1 uint32 plane."""
    return ((plane_pm1.astype(jnp.int32) + 1) // 2).astype(jnp.uint32)


def from_binary(plane01: jax.Array, dtype=jnp.int8) -> jax.Array:
    return (2 * plane01.astype(jnp.int32) - 1).astype(dtype)


def pack_nibbles(plane01: jax.Array) -> jax.Array:
    """(N, C) 0/1 plane -> (N, C/8) uint32, nibble n = column 8w + n."""
    n, c = plane01.shape
    assert c % SPINS_PER_WORD == 0, "columns must be a multiple of 8"
    grouped = plane01.astype(jnp.uint32).reshape(n, c // SPINS_PER_WORD,
                                                 SPINS_PER_WORD)
    shifts = (jnp.arange(SPINS_PER_WORD, dtype=jnp.uint32) * NIBBLE_BITS)
    return jnp.sum(grouped << shifts, axis=-1, dtype=jnp.uint32)


def unpack_nibbles(words: jax.Array) -> jax.Array:
    """(N, W) uint32 -> (N, 8W) nibble values (uint32)."""
    n, w = words.shape
    shifts = (jnp.arange(SPINS_PER_WORD, dtype=jnp.uint32) * NIBBLE_BITS)
    nib = (words[..., None] >> shifts) & jnp.uint32(0xF)
    return nib.reshape(n, w * SPINS_PER_WORD)


def align_side_word(center: jax.Array, is_black: bool) -> jax.Array:
    """Packed-word analogue of :func:`side_shift` (paper Fig. 3).

    For each target word, 7 of the 8 same-row neighbors live in the
    opposite plane's word at the same coordinates; the 8th is the edge
    nibble of the word to the left/right.  We build the fully aligned
    side word with two shifts and a splice, row-parity dependent.
    """
    rows = (jnp.arange(center.shape[0], dtype=jnp.uint32) % 2)[:, None]
    nxt = jnp.roll(center, -1, axis=1)
    prv = jnp.roll(center, 1, axis=1)
    # shift toward k+1: nibble n <- column c+1 == nibble n+1 (next word's
    # nibble 0 enters at the top)
    plus = (center >> NIBBLE_BITS) | (nxt << (32 - NIBBLE_BITS))
    # shift toward k-1
    minus = (center << NIBBLE_BITS) | (prv >> (32 - NIBBLE_BITS))
    if is_black:
        return jnp.where(rows == 1, plus, minus)
    return jnp.where(rows == 1, minus, plus)


def packed_neighbor_sums(op_words: jax.Array, is_black: bool) -> jax.Array:
    """Nibble-parallel 4-neighbor sums: THREE adds per 8 spins (paper S3.3).

    Each nibble sum is at most 4 < 16, so no carries cross nibbles.
    """
    up = jnp.roll(op_words, 1, axis=0)
    down = jnp.roll(op_words, -1, axis=0)
    side = align_side_word(op_words, is_black)
    return up + down + op_words + side
