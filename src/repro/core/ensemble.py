"""Batched ensemble driver: one compiled sweep for a whole phase diagram.

The TPU-cluster follow-up to the paper (Yang et al., "High Performance
Monte Carlo Simulation of Ising Model on TPU Clusters") batches many
replicas/temperatures through one update; this driver is that idea on top
of the engine registry.  Any *counter-based* engine (Philox randomness
addressed by (seed, position, offset) -- DESIGN.md S4) exposes a pure
``sweep_fn`` whose seed and temperature are traceable, so the whole
ensemble advances in ONE ``jax.vmap``-ed, jit-compiled call over a batch
axis of (temperature, seed) pairs: a phase-diagram scan or a replica set
costs one compilation and one device dispatch per measurement interval.

Key-based engines (``basic``, ``tensorcore``, ``wolff``, ``spinglass``)
are rejected: their randomness is not a pure function of traced inputs,
so members would not reproduce the single-simulation trajectories.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .engine import make_engine
from .sim import SimConfig


class Ensemble:
    """A batch of independent lattices, one (temperature, seed) each.

    Bit-exactness contract: member ``i`` of the ensemble follows exactly
    the trajectory of ``Simulation(SimConfig(temperature=temps[i],
    seed=seeds[i], ...))`` for seeds < 2**32 (tested in
    tests/test_ensemble.py).
    """

    def __init__(self, n: int, m: int, temperatures: Sequence[float],
                 seeds: Optional[Sequence[int]] = None,
                 engine: str = "multispin", init_p_up: float = 0.5):
        temps = np.asarray(temperatures, np.float32)
        assert temps.ndim == 1 and temps.size > 0, "need a 1-D temp batch"
        if seeds is None:
            seeds = np.arange(temps.size)
        seeds = np.asarray(seeds)
        assert seeds.shape == temps.shape, (seeds.shape, temps.shape)

        cfg = SimConfig(n=n, m=m, engine=engine, init_p_up=init_p_up)
        self.engine = make_engine(cfg)
        if not self.engine.counter_based:
            raise ValueError(
                f"engine {engine!r} is not counter-based; Ensemble needs a "
                "Philox engine whose sweep_fn is a pure function of "
                "(seed, offset) -- see DESIGN.md S3/S4")
        self.config = cfg
        self.temperatures = temps
        # invert in python-float precision exactly like SimConfig.inv_temp
        # (1.0/float32(T) can land 1 ulp off float32(1.0/T), which would
        # eventually fork a member from its Simulation trajectory)
        self.inv_temps = jnp.asarray(
            [1.0 / float(t) for t in np.asarray(temperatures).tolist()],
            jnp.float32)
        self.seeds = jnp.asarray(seeds.astype(np.int64) & 0xFFFFFFFF,
                                 jnp.uint32)
        self.step_count = 0
        self._jit_cache = {}

        keys = jax.vmap(jax.random.PRNGKey)(
            jnp.asarray(seeds, jnp.int32))
        self.states = jax.jit(jax.vmap(self.engine.init_state))(keys)
        # measurement wrappers jitted once (jit caches on the fn object)
        self._magnetizations = jax.jit(jax.vmap(self.engine.magnetization))
        self._full_lattices = jax.jit(jax.vmap(self.engine.full_lattice))

    @property
    def size(self) -> int:
        return int(self.temperatures.size)

    def _compiled(self, n_sweeps: int):
        fn = self._jit_cache.get(n_sweeps)
        if fn is None:
            def one(state, inv_temp, seed, start_offset):
                state = self.engine.sweep_fn(state, inv_temp, seed,
                                             start_offset, n_sweeps)
                return state, self.engine.magnetization(state)

            fn = jax.jit(jax.vmap(one, in_axes=(0, 0, 0, None)))
            self._jit_cache[n_sweeps] = fn
        return fn

    def run(self, n_sweeps: int) -> np.ndarray:
        """Advance every member ``n_sweeps`` sweeps in one vmapped call.

        Returns the (B,) per-member magnetizations after the sweeps -- at
        fixed seeds this IS the magnetization-vs-temperature curve.
        """
        self.states, mags = self._compiled(n_sweeps)(
            self.states, self.inv_temps, self.seeds,
            jnp.uint32(2 * self.step_count))
        self.step_count += n_sweeps
        return np.asarray(mags)

    def magnetizations(self) -> np.ndarray:
        """(B,) per-member magnetization of the current states."""
        return np.asarray(self._magnetizations(self.states))

    def full_lattices(self) -> np.ndarray:
        """(B, N, M) stacked +-1 lattices (measurement/debug view)."""
        return np.asarray(self._full_lattices(self.states))

    def measure(self, plan) -> dict:
        """Run a :class:`repro.analysis.MeasurementPlan` on every member
        in ONE vmapped, compiled dispatch (DESIGN.md S7).

        Returns ``{field: (n_measure, B) float32 ndarray}``.
        """
        from repro.analysis.measure import measure_scan_batched
        self.states, traj, self.step_count = measure_scan_batched(
            self.engine, self.states, self.inv_temps, self.seeds, plan,
            step_count=self.step_count)
        return traj

    def trajectory(self, n_measure: int, sweeps_between: int,
                   thermalize: int = 0) -> np.ndarray:
        """(n_measure, B) magnetization samples along the trajectory --
        the whole measured trajectory is one compiled dispatch."""
        from repro.analysis.measure import MeasurementPlan
        plan = MeasurementPlan(n_measure, sweeps_between, thermalize,
                               fields=("m",))
        return self.measure(plan)["m"]
