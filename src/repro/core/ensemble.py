"""Ensemble: compatibility shim over :class:`repro.api.Session`.

.. deprecated:: PR 5
   ``Ensemble`` remains fully supported, but it is now a thin façade
   over the unified ``repro.api`` entry point -- a ``RunSpec`` with a
   ``BatchSpec``, executed by ``Session``'s vmapped ensemble runner
   (the batched-update idea of the TPU-cluster follow-up paper, Yang et
   al.).  New code should build a ``RunSpec`` directly; this class is
   kept so existing call sites keep working bit-for-bit.

The shim also tightens two legacy sharp edges (PR 5 satellites):

* seeds >= 2**32 now raise instead of being silently masked with
  ``& 0xFFFFFFFF`` -- the vmapped Philox key is a traced uint32 lane
  (DESIGN.md S4), so a masked seed would *not* follow the 64-bit
  single-``Simulation`` stream its docstring promises;
* ``temperature``/``seed`` of member 0 now reach the internal engine
  config instead of being dropped on the floor (the old constructor
  pinned defaults ``temperature=2.0, seed=1234`` regardless of the
  members); ``tc_block``/``p_ferro`` are accepted and forwarded to any
  engine that declares them in ``param_fields`` -- today no
  counter-based engine does, so they are validated-but-inert
  future-proofing for batched tensorcore/spin-glass variants.
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np


class Ensemble:
    """A batch of independent lattices, one (temperature, seed) each.

    Bit-exactness contract: member ``i`` of the ensemble follows exactly
    the trajectory of ``Simulation(SimConfig(temperature=temps[i],
    seed=seeds[i], ...))`` (tested in tests/test_ensemble.py; seeds are
    validated < 2**32, where the contract provably holds).
    """

    def __init__(self, n: int, m: int, temperatures: Sequence[float],
                 seeds: Optional[Sequence[int]] = None,
                 engine: str = "multispin", init_p_up: float = 0.5,
                 tc_block: int = 128, p_ferro: float = 0.5):
        from repro.api import (BatchSpec, EngineSpec, LatticeSpec,
                               RunSpec, Session)
        temps = np.asarray(temperatures, np.float32)
        if temps.ndim != 1 or temps.size == 0:
            raise ValueError(f"need a 1-D temp batch, got shape "
                             f"{temps.shape}")
        if seeds is not None:
            seeds_arr = np.asarray(seeds)
            if seeds_arr.shape != temps.shape:
                raise ValueError(f"seeds/temps shape mismatch: "
                                 f"{seeds_arr.shape} vs {temps.shape}")
            seeds = tuple(int(s) for s in seeds_arr.tolist())
        params = {k: v for k, v in
                  (("tc_block", tc_block), ("p_ferro", p_ferro))
                  if k in _param_fields(engine)}
        spec = RunSpec(
            lattice=LatticeSpec(n=n, m=m, init_p_up=init_p_up),
            engine=EngineSpec(name=engine, params=params),
            batch=BatchSpec(
                temperatures=tuple(
                    float(t) for t in np.asarray(temperatures).tolist()),
                seeds=seeds))
        self._session = Session.open(spec)
        self.config = self._session._runner.cfg
        self.temperatures = self._session._runner.temperatures

    # -- delegated internals ----------------------------------------------
    @property
    def engine(self):
        return self._session._runner.engine

    @property
    def states(self):
        return self._session.state

    @states.setter
    def states(self, v):
        self._session.state = v

    @property
    def inv_temps(self):
        return self._session._runner.inv_temps

    @property
    def seeds(self):
        return self._session._runner.seeds

    @property
    def step_count(self) -> int:
        return self._session.step_count

    @step_count.setter
    def step_count(self, v: int) -> None:
        self._session.step_count = v

    @property
    def size(self) -> int:
        return self._session._runner.size

    def run(self, n_sweeps: int) -> np.ndarray:
        """Advance every member ``n_sweeps`` sweeps in one vmapped call.

        Returns the (B,) per-member magnetizations after the sweeps -- at
        fixed seeds this IS the magnetization-vs-temperature curve.
        """
        return self._session.run(n_sweeps)

    def magnetizations(self) -> np.ndarray:
        """(B,) per-member magnetization of the current states."""
        return self._session.magnetization()

    def full_lattices(self) -> np.ndarray:
        """(B, N, M) stacked +-1 lattices (measurement/debug view)."""
        return self._session.full_lattice()

    def measure(self, plan) -> dict:
        """Run a :class:`repro.analysis.MeasurementPlan` on every member
        in ONE vmapped, compiled dispatch (DESIGN.md S7).

        Returns ``{field: (n_measure, B) float32 ndarray}``.
        """
        return self._session.measure(plan)

    def trajectory(self, n_measure: int, sweeps_between: int,
                   thermalize: int = 0) -> np.ndarray:
        """(n_measure, B) magnetization samples along the trajectory --
        the whole measured trajectory is one compiled dispatch."""
        return self._session.trajectory(n_measure, sweeps_between,
                                        thermalize)

    # -- fault tolerance (PR 5 satellite: batched checkpoints) -------------
    def save(self, path: str) -> None:
        """Atomic checkpoint of ALL member states + step count + spec
        (the unified ``Session`` layout; restorable by either side)."""
        self._session.save(path)

    @classmethod
    def restore(cls, path: str) -> "Ensemble":
        from repro.api import Session
        session = Session.restore(path)
        if session.mode != "ensemble":
            raise ValueError(
                f"{path} holds a {session.mode!r} checkpoint; restore "
                "it with Simulation.restore or repro.api.Session")
        ens = cls.__new__(cls)
        ens._session = session
        ens.config = session._runner.cfg
        ens.temperatures = session._runner.temperatures
        return ens


def _param_fields(engine: str):
    from .engine import ENGINES
    cls = ENGINES.get(engine)
    return cls.param_fields if cls is not None else ()
