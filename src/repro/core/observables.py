"""Observables for the 2D Ising model (paper S5.3).

Magnetization, energy per spin, Onsager's exact magnetization (Eq. 7), the
critical temperature, and the Binder cumulant U_L.  The paper's Eq. for U_L
omits the conventional factor 3 in the denominator (typo); we use the
standard Binder definition U_L = 1 - <m^4> / (3 <m^2>^2), which crosses at
T_c with U -> 2/3 (T<Tc) and U -> 0 (T>Tc) as in the paper's Fig. 6.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

T_CRITICAL = 2.269185  # 2 / ln(1 + sqrt(2)), J = 1


def magnetization(black: jax.Array, white: jax.Array) -> jax.Array:
    """Mean spin over the full lattice from the compact +-1 color planes."""
    s = black.astype(jnp.float32).sum() + white.astype(jnp.float32).sum()
    return s / (black.size + white.size)


def magnetization_full(full: jax.Array) -> jax.Array:
    """Mean spin of an (N, M) +-1 lattice.

    Sums of +-1 are exact in float32 up to 2^24 spins, so this equals the
    plane-wise :func:`magnetization` bit-for-bit regardless of layout.
    """
    return full.astype(jnp.float32).sum() / full.size


def energy_per_spin_full(full: jax.Array) -> jax.Array:
    """H / (J N_spins) = -(1/N) sum_<ij> sigma_i sigma_j (each bond once).

    Layout-independent: one roll per axis counts every vertical and
    horizontal bond exactly once, so the same expression is correct for
    any engine's ``full_lattice`` view (the engine ``observables`` hook
    routes here -- DESIGN.md S7).
    """
    s = full.astype(jnp.float32)
    e = -(s * jnp.roll(s, 1, axis=0)).sum() - (s * jnp.roll(s, 1, axis=1)).sum()
    return e / full.size


def energy_per_spin(black, white) -> jax.Array:
    """Energy per spin from compact color planes (merges, then sums bonds)."""
    from . import lattice as lat
    return energy_per_spin_full(lat.merge_checkerboard(black, white))


def onsager_magnetization(temperature, j: float = 1.0):
    """Exact spontaneous magnetization (Eq. 7); 0 above T_c."""
    t = jnp.asarray(temperature, jnp.float32)
    m = (1.0 - jnp.sinh(2.0 * j / t) ** (-4.0)) ** 0.125
    return jnp.where(t < T_CRITICAL * j, m, 0.0)


def binder_cumulant(m_samples: jax.Array) -> jax.Array:
    """U_L from a trajectory of magnetization samples."""
    m2 = jnp.mean(m_samples.astype(jnp.float32) ** 2)
    m4 = jnp.mean(m_samples.astype(jnp.float32) ** 4)
    return 1.0 - m4 / (3.0 * m2 ** 2)
