"""On-device measurement & host-side analysis subsystem (DESIGN.md S7).

* ``measure``    -- MeasurementPlan / measure_scan: observables fused
  into one compiled ``lax.scan`` per trajectory segment;
* ``estimators`` -- Welford moments, blocking/jackknife error bars,
  tau_int, susceptibility, specific heat, Binder crossing;
* ``recorder``   -- RunRecorder: EXPERIMENTS.md CSV/JSON serialization.
"""
from .estimators import (Welford, autocorrelation, binder, binder_crossing,
                         blocking_error, blocking_sems, effective_samples,
                         jackknife, specific_heat, susceptibility, tau_int)
from .measure import MeasurementPlan, measure_scan, measure_scan_batched
from .recorder import RunRecorder, parse_derived

__all__ = [
    "MeasurementPlan", "measure_scan", "measure_scan_batched",
    "Welford", "autocorrelation", "binder", "binder_crossing",
    "blocking_error", "blocking_sems", "effective_samples", "jackknife",
    "specific_heat", "susceptibility", "tau_int",
    "RunRecorder", "parse_derived",
]
