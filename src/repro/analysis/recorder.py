"""RunRecorder: results in the EXPERIMENTS.md CSV schema, plus JSON.

One row per measurement: ``name,us_per_call,derived`` where ``derived``
is ``;``-separated ``key=value`` pairs (EXPERIMENTS.md S Bench).  The
recorder is the single serialization point shared by the benchmark
harness (``benchmarks/run.py``) and the figure reproduction
(``examples/figures.py``): rows can be echoed to stdout as they arrive,
written to a ``.csv``, and dumped as a machine-diffable JSON record
(``benchmarks/report.py diff`` consumes two of those).
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List, Optional, Sequence

HEADER = "name,us_per_call,derived"


def _percentile(sorted_vals: Sequence[float], q: float) -> float:
    """Linear-interpolation percentile of an already-sorted sequence."""
    n = len(sorted_vals)
    if n == 1:
        return float(sorted_vals[0])
    pos = q * (n - 1)
    lo = int(pos)
    hi = min(lo + 1, n - 1)
    frac = pos - lo
    return float(sorted_vals[lo] * (1.0 - frac) + sorted_vals[hi] * frac)


def timing_stats(times_us: Sequence[float]) -> Dict[str, object]:
    """``n_trials`` / ``median_us_per_call`` / ``iqr_us_per_call`` from
    per-trial wall-clock samples (microseconds).

    A single trial records ``n_trials=1`` with NO iqr field -- one
    sample says nothing about spread, and consumers (the perf gate)
    must fall back to their legacy tolerance rather than read a
    zero-IQR row as perfectly stable.
    """
    ts = sorted(float(t) for t in times_us)
    if not ts:
        return {}
    out: Dict[str, object] = {"n_trials": len(ts),
                              "median_us_per_call": _percentile(ts, 0.5)}
    if len(ts) >= 2:
        out["iqr_us_per_call"] = (_percentile(ts, 0.75)
                                  - _percentile(ts, 0.25))
    return out


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.6g}"
    return str(v)


def parse_derived(derived: str) -> Dict[str, object]:
    """'k1=v1;k2=v2' -> dict, floating values parsed where possible."""
    out: Dict[str, object] = {}
    for part in derived.split(";"):
        if not part or "=" not in part:
            continue
        k, v = part.split("=", 1)
        try:
            out[k] = float(v)
        except ValueError:
            out[k] = v
    return out


class RunRecorder:
    """Accumulates ``(name, us_per_call, derived)`` rows."""

    def __init__(self, echo: bool = False, meta: Optional[dict] = None):
        self.rows: List[dict] = []
        self.echo = echo
        self.meta = dict(meta or {})
        if echo:
            print(HEADER)

    def record(self, name: str, us_per_call: float = 0.0,
               spec: Optional[str] = None,
               times_us: Optional[Sequence[float]] = None,
               **derived) -> dict:
        """One row; ``spec`` (a serialized ``repro.api.RunSpec`` JSON
        string) rides along in the JSON record -- not the CSV -- so a
        perf row is replayable with ``python -m repro run`` from the
        record alone.  ``times_us`` (per-trial wall-clock samples)
        adds the noise-model fields ``n_trials`` / ``median_us_per_call``
        / ``iqr_us_per_call`` the statistical perf gate consumes
        (``repro.perf.gate``); rows without it stay in the legacy
        single-number format, which every consumer tolerates."""
        row = {"name": name, "us_per_call": float(us_per_call),
               "derived": {k: v for k, v in derived.items()}}
        if times_us is not None:
            row.update(timing_stats(times_us))
        if spec is not None:
            row["spec"] = spec
        self.rows.append(row)
        if self.echo:
            print(self.format_row(row))
        return row

    @staticmethod
    def format_row(row: dict) -> str:
        derived = ";".join(f"{k}={_fmt(v)}"
                           for k, v in row["derived"].items())
        return f"{row['name']},{row['us_per_call']:.1f},{derived}"

    def write_csv(self, path: str) -> str:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            f.write(HEADER + "\n")
            for row in self.rows:
                f.write(self.format_row(row) + "\n")
        return path

    def write_json(self, path: str) -> str:
        """Full record (meta + rows) as JSON.  Any path not ending in
        ``.json`` is treated as a directory (created if missing) and
        gets a ``BENCH_<stamp>.json`` filename, the perf-record
        convention -- so ``--json results`` works in a fresh checkout."""
        if not path.endswith(".json"):
            stamp = self.meta.get("stamp") or time.strftime(
                "%Y%m%d_%H%M%S")
            path = os.path.join(path, f"BENCH_{stamp}.json")
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            json.dump({"meta": self.meta, "rows": self.rows}, f,
                      indent=1, sort_keys=True)
        return path
