"""RunRecorder: results in the EXPERIMENTS.md CSV schema, plus JSON.

One row per measurement: ``name,us_per_call,derived`` where ``derived``
is ``;``-separated ``key=value`` pairs (EXPERIMENTS.md S Bench).  The
recorder is the single serialization point shared by the benchmark
harness (``benchmarks/run.py``) and the figure reproduction
(``examples/figures.py``): rows can be echoed to stdout as they arrive,
written to a ``.csv``, and dumped as a machine-diffable JSON record
(``benchmarks/report.py diff`` consumes two of those).
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List, Optional

HEADER = "name,us_per_call,derived"


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.6g}"
    return str(v)


def parse_derived(derived: str) -> Dict[str, object]:
    """'k1=v1;k2=v2' -> dict, floating values parsed where possible."""
    out: Dict[str, object] = {}
    for part in derived.split(";"):
        if not part or "=" not in part:
            continue
        k, v = part.split("=", 1)
        try:
            out[k] = float(v)
        except ValueError:
            out[k] = v
    return out


class RunRecorder:
    """Accumulates ``(name, us_per_call, derived)`` rows."""

    def __init__(self, echo: bool = False, meta: Optional[dict] = None):
        self.rows: List[dict] = []
        self.echo = echo
        self.meta = dict(meta or {})
        if echo:
            print(HEADER)

    def record(self, name: str, us_per_call: float = 0.0,
               spec: Optional[str] = None, **derived) -> dict:
        """One row; ``spec`` (a serialized ``repro.api.RunSpec`` JSON
        string) rides along in the JSON record -- not the CSV -- so a
        perf row is replayable with ``python -m repro run`` from the
        record alone."""
        row = {"name": name, "us_per_call": float(us_per_call),
               "derived": {k: v for k, v in derived.items()}}
        if spec is not None:
            row["spec"] = spec
        self.rows.append(row)
        if self.echo:
            print(self.format_row(row))
        return row

    @staticmethod
    def format_row(row: dict) -> str:
        derived = ";".join(f"{k}={_fmt(v)}"
                           for k, v in row["derived"].items())
        return f"{row['name']},{row['us_per_call']:.1f},{derived}"

    def write_csv(self, path: str) -> str:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            f.write(HEADER + "\n")
            for row in self.rows:
                f.write(self.format_row(row) + "\n")
        return path

    def write_json(self, path: str) -> str:
        """Full record (meta + rows) as JSON.  Any path not ending in
        ``.json`` is treated as a directory (created if missing) and
        gets a ``BENCH_<stamp>.json`` filename, the perf-record
        convention -- so ``--json results`` works in a fresh checkout."""
        if not path.endswith(".json"):
            stamp = self.meta.get("stamp") or time.strftime(
                "%Y%m%d_%H%M%S")
            path = os.path.join(path, f"BENCH_{stamp}.json")
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            json.dump({"meta": self.meta, "rows": self.rows}, f,
                      indent=1, sort_keys=True)
        return path
