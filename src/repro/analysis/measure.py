"""Fused on-device measurement: observables inside one compiled scan.

The legacy measurement path (``Simulation.trajectory``) is a Python loop
that issues one device dispatch per sample and round-trips every
observable to the host; the TPU-cluster follow-up to the paper (Yang et
al.) shows the measurement loop must be fused into the compiled update to
stay accelerator-bound.  :func:`measure_scan` is that fusion: a
``MeasurementPlan`` (how many samples, spaced how far apart) is compiled
into ONE ``jax.lax.scan`` whose body advances the engine by
``sweeps_between`` sweeps via the pure ``Engine.scan_step`` hook and
records ``Engine.observables`` -- one dispatch per trajectory segment
instead of one per sample, with bit-identical samples (DESIGN.md S7).

Two entry points share the compiled body:

* :func:`measure_scan`          -- single simulation; the seed is closed
  over as a python int (full 64-bit Philox keys, exactly like the
  stateful ``sweeps`` wrappers);
* :func:`measure_scan_batched`  -- ``vmap`` over (state, inv_temp, seed)
  for the :class:`~repro.core.ensemble.Ensemble` driver (counter-based
  engines only, traced uint32 seeds).

Each compiled-call invocation is accounted through ``repro.telemetry``
(one ``dispatches`` increment + a fenced ``measure_scan``/``dispatch``
span when tracing is on); tests and the fusion bench read the counter
to assert the one-dispatch contract.  The old module global
``DISPATCH_COUNT`` survives as a deprecated read-only alias of the
telemetry counter.

Resident-tier composition (DESIGN.md S9): the scan body advances each
measure interval through ``Engine.scan_step`` -> ``sweep_fn``, so on a
resident-capable engine whose lattice fits the VMEM plan every
``sweeps_between``-sized sweep block lowers to exactly ONE k-sweep
resident kernel call (k = ``sweeps_between``) inside the scan -- the
spins stay in VMEM for the whole interval and touch HBM once per
sample, instead of 2x per sweep.  No code here knows about the tier;
the mapping falls out of the registry dispatch, and bit-exactness of
the samples is guaranteed by the shared Philox counter layout
(``core.rng.half_sweep_offset``, tested in tests/test_resident.py).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

import repro.telemetry as tel


def __getattr__(name: str):
    # deprecation shim (PEP 562): the pre-telemetry mutable global is now
    # a read-only view of the process-global dispatch counter
    if name == "DISPATCH_COUNT":
        import warnings
        warnings.warn(
            "repro.analysis.measure.DISPATCH_COUNT is deprecated; read "
            "repro.telemetry.DISPATCHES.value (or snapshot counter "
            "deltas) instead", DeprecationWarning, stacklevel=2)
        return tel.DISPATCHES.value
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


@dataclasses.dataclass(frozen=True)
class MeasurementPlan:
    """A measurement schedule: ``n_measure`` samples, ``sweeps_between``
    sweeps apart, after ``thermalize`` equilibration sweeps.

    ``fields`` selects which keys of the engine ``observables`` hook are
    recorded ("m" mean spin, "e" energy per spin).  Frozen + hashable:
    the plan is the jit-cache key.
    """

    n_measure: int
    sweeps_between: int
    thermalize: int = 0
    fields: Tuple[str, ...] = ("m", "e")

    def __post_init__(self):
        assert self.n_measure > 0 and self.sweeps_between > 0, self
        assert self.thermalize >= 0, self
        assert len(self.fields) > 0, "need at least one observable field"
        object.__setattr__(self, "fields", tuple(self.fields))

    @property
    def total_sweeps(self) -> int:
        return self.thermalize + self.n_measure * self.sweeps_between


def _scan_body(engine, plan: MeasurementPlan):
    """The traced trajectory: thermalize, then scan measure intervals."""

    def run(state, inv_temp, seed, step0):
        if plan.thermalize:
            state = engine.scan_step(state, inv_temp, seed, step0,
                                     plan.thermalize)
            step0 = step0 + plan.thermalize

        def body(carry, _):
            st, step = carry
            st = engine.scan_step(st, inv_temp, seed, step,
                                  plan.sweeps_between)
            step = step + plan.sweeps_between
            o = engine.observables(st, inv_temp)
            missing = set(plan.fields) - set(o)
            if missing:
                raise ValueError(
                    f"plan fields {sorted(missing)} not in engine "
                    f"{engine.name!r} observables {sorted(o)}")
            sample = {k: jnp.asarray(o[k], jnp.float32)
                      for k in plan.fields}
            return (st, step), sample

        (state, _), traj = jax.lax.scan(body, (state, step0), None,
                                        length=plan.n_measure)
        return state, traj

    return run


def _compiled(engine, plan: MeasurementPlan, batched: bool):
    """Returns ``(fn, fresh)``: ``fresh`` marks a cache miss, i.e. the
    next invocation pays XLA compilation (the ``compile`` span attr)."""
    # cache lives on the engine instance (the CounterEngine._jit_cache
    # pattern) so compiled executables die with the engine
    cache = engine.__dict__.setdefault("_measure_scan_cache", {})
    fn = cache.get((plan, batched))
    fresh = fn is None
    if fn is None:
        run = _scan_body(engine, plan)
        if batched:
            # (states, inv_temps, seeds) carry the batch axis; the sweep
            # counter is shared -- every member is at the same step
            fn = jax.jit(jax.vmap(run, in_axes=(0, 0, 0, None)))
        else:
            # close the python-int seed over the trace so counter-based
            # engines keep full 64-bit Philox keys (same convention as
            # the stateful CounterEngine.sweeps wrapper)
            seed = engine.cfg.seed
            fn = jax.jit(lambda st, beta, step0: run(st, beta, seed,
                                                     step0))
        cache[(plan, batched)] = fn
    return fn, fresh


def _span(engine, plan: MeasurementPlan, fresh: bool, batch: int):
    return tel.span("measure_scan", engine=engine.name,
                    lattice=(engine.cfg.n, engine.cfg.m),
                    n_measure=plan.n_measure,
                    sweeps_between=plan.sweeps_between,
                    thermalize=plan.thermalize, batch=batch,
                    replicas=engine.replicas,
                    compile="first" if fresh else "steady")


def _account(engine, plan: MeasurementPlan, batch: int) -> None:
    tel.record_dispatch(n_sweeps=plan.total_sweeps,
                        sites=engine.cfg.n * engine.cfg.m,
                        replicas=engine.replicas, batch=batch,
                        counter_based=engine.counter_based)


def measure_scan(engine, state, plan: MeasurementPlan, step_count: int = 0):
    """Run ``plan`` on a single simulation state in one compiled dispatch.

    Returns ``(final_state, {field: (n_measure,) float32 ndarray},
    new_step_count)``.  Replicated engines (bitplane) append their
    per-replica axis: ``(n_measure, replicas)``.  Samples are
    bit-identical to the legacy python loop ``run(sweeps_between);
    measure()`` repeated ``n_measure`` times (tests/test_analysis.py).
    """
    fn, fresh = _compiled(engine, plan, batched=False)
    with _span(engine, plan, fresh, batch=1) as sp:
        with tel.span("dispatch", engine=engine.name,
                      k=plan.total_sweeps,
                      compile="first" if fresh else "steady") as dsp:
            state, traj = fn(state, jnp.float32(engine.cfg.inv_temp),
                             jnp.int32(step_count))
            dsp.fence(traj)
        _account(engine, plan, batch=1)
        sp.fence((state, traj))
    traj = {k: np.asarray(v) for k, v in traj.items()}
    return state, traj, step_count + plan.total_sweeps


def measure_scan_batched(engine, states, inv_temps, seeds,
                         plan: MeasurementPlan, step_count: int = 0):
    """Batched :func:`measure_scan` over (state, inv_temp, seed) members.

    Returns ``(final_states, {field: (n_measure, B) ndarray},
    new_step_count)`` -- trajectory-major, matching the legacy
    ``Ensemble.trajectory`` shape.
    """
    if not engine.counter_based:
        raise ValueError(
            f"engine {engine.name!r} is not counter-based; batched "
            "measurement needs a traceable-seed sweep (DESIGN.md S3/S4)")
    batch = int(np.shape(seeds)[0])
    fn, fresh = _compiled(engine, plan, batched=True)
    with _span(engine, plan, fresh, batch=batch) as sp:
        with tel.span("dispatch", engine=engine.name,
                      k=plan.total_sweeps, batch=batch,
                      compile="first" if fresh else "steady") as dsp:
            states, traj = fn(states, inv_temps, seeds,
                              jnp.int32(step_count))
            dsp.fence(traj)
        _account(engine, plan, batch=batch)
        sp.fence((states, traj))
    # (B, n, ...) -> (n, B, ...): moveaxis, not .T, so replicated engines'
    # per-replica observable vectors keep their trailing axis intact
    traj = {k: np.moveaxis(np.asarray(v), 0, 1) for k, v in traj.items()}
    return states, traj, step_count + plan.total_sweeps
