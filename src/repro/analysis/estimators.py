"""Streaming and resampling estimators for Monte Carlo observables.

The error-analysis machinery any credible Monte Carlo reproduction needs
(Weigel, "Simulating spin models on GPU"): Welford streaming moments,
Flyvbjerg-Petersen blocking, delete-one-block jackknife, the integrated
autocorrelation time tau_int with Sokal's automatic windowing, and the
paper's S5.3 physics estimators -- susceptibility, specific heat, Binder
cumulant, and the Binder-crossing T_c estimator (DESIGN.md S7).

Everything here is host-side numpy post-processing of the (already
device-fused) sample trajectories from ``repro.analysis.measure``; all
functions accept any array-like and compute in float64.

Conventions:

* ``tau_int = 1 + 2 sum_{t>=1} rho(t)`` -- iid data gives tau_int = 1 and
  the effective sample size is ``N / tau_int``; an AR(1) series with
  coefficient ``phi`` has ``tau_int = (1 + phi) / (1 - phi)``.
* ``chi = beta * N * (<m^2> - <|m|>^2)`` (per-spin m; paper Fig. 5 regime)
  and ``C_v = beta^2 * N * (<e^2> - <e>^2)`` (per-spin e) -- both are
  variances scaled by positive factors, hence non-negative.
"""
from __future__ import annotations

import math
from typing import Callable, Optional, Sequence, Tuple

import numpy as np


# ---------------------------------------------------------------------------
# streaming moments
# ---------------------------------------------------------------------------

class Welford:
    """Streaming mean/variance plus the |x|, x^2, x^4 moment means.

    Classic Welford/Chan update: ``push`` accepts scalars or arrays (any
    shape; flattened), ``merge`` combines two accumulators exactly as if
    their streams were concatenated -- so per-shard accumulators can be
    reduced across a fleet.  The higher moments feed the Binder cumulant
    and susceptibility without a second pass over the samples.
    """

    __slots__ = ("n", "mean", "_m2", "abs_mean", "sq_mean", "quad_mean")

    def __init__(self):
        self.n = 0
        self.mean = 0.0
        self._m2 = 0.0          # sum of squared deviations from the mean
        self.abs_mean = 0.0     # <|x|>
        self.sq_mean = 0.0      # <x^2>
        self.quad_mean = 0.0    # <x^4>

    def push(self, x) -> "Welford":
        x = np.asarray(x, np.float64).ravel()
        if x.size == 0:
            return self
        other = Welford()
        other.n = int(x.size)
        other.mean = float(x.mean())
        other._m2 = float(((x - x.mean()) ** 2).sum())
        other.abs_mean = float(np.abs(x).mean())
        other.sq_mean = float((x ** 2).mean())
        other.quad_mean = float((x ** 4).mean())
        return self.merge(other)

    def merge(self, other: "Welford") -> "Welford":
        """Chan's parallel combine; returns self for chaining."""
        if other.n == 0:
            return self
        if self.n == 0:
            for s in self.__slots__:
                setattr(self, s, getattr(other, s))
            return self
        n = self.n + other.n
        delta = other.mean - self.mean
        self._m2 += other._m2 + delta * delta * self.n * other.n / n
        w_self, w_other = self.n / n, other.n / n
        self.mean = self.mean * w_self + other.mean * w_other
        self.abs_mean = self.abs_mean * w_self + other.abs_mean * w_other
        self.sq_mean = self.sq_mean * w_self + other.sq_mean * w_other
        self.quad_mean = (self.quad_mean * w_self
                          + other.quad_mean * w_other)
        self.n = n
        return self

    @property
    def var(self) -> float:
        """Sample variance (ddof=1)."""
        return self._m2 / (self.n - 1) if self.n > 1 else 0.0

    @property
    def std(self) -> float:
        return math.sqrt(self.var)

    @property
    def sem(self) -> float:
        """Naive standard error of the mean (iid assumption)."""
        return self.std / math.sqrt(self.n) if self.n else 0.0

    def binder(self) -> float:
        """U = 1 - <x^4> / (3 <x^2>^2) from the streamed moments."""
        return binder_from_moments(self.sq_mean, self.quad_mean)

    def susceptibility(self, temperature: float, n_spins: int) -> float:
        """chi = beta N (<x^2> - <|x|>^2) from the streamed moments."""
        return (n_spins / temperature
                * max(self.sq_mean - self.abs_mean ** 2, 0.0))


# ---------------------------------------------------------------------------
# autocorrelation
# ---------------------------------------------------------------------------

def autocorrelation(x, max_lag: Optional[int] = None) -> np.ndarray:
    """Normalized autocorrelation rho(t), t = 0..max_lag (FFT, O(N log N))."""
    x = np.asarray(x, np.float64).ravel()
    n = x.size
    if max_lag is None:
        max_lag = n - 1
    max_lag = min(max_lag, n - 1)
    x = x - x.mean()
    f = np.fft.rfft(x, 2 * n)
    acov = np.fft.irfft(f * np.conj(f))[:max_lag + 1] / n
    if acov[0] <= 0:
        return np.concatenate([[1.0], np.zeros(max_lag)])
    return acov / acov[0]


def tau_int(x, c: float = 6.0) -> float:
    """Integrated autocorrelation time with Sokal's automatic window.

    ``tau(W) = 1 + 2 sum_{t=1}^{W} rho(t)``, evaluated at the smallest
    window ``W >= c * tau(W)`` (c ~ 6 balances truncation bias against
    the noise of summing long-lag rho).  iid -> 1; AR(1)(phi) ->
    (1 + phi) / (1 - phi).
    """
    x = np.asarray(x, np.float64).ravel()
    if x.size < 4 or np.ptp(x) == 0:
        return 1.0
    rho = autocorrelation(x)
    tau = 1.0
    for w in range(1, rho.size):
        tau += 2.0 * rho[w]
        if w >= c * max(tau, 1e-12):
            break
    return max(tau, 1e-12)


def effective_samples(x, c: float = 6.0) -> float:
    """N_eff = N / tau_int: the iid-equivalent sample count."""
    x = np.asarray(x, np.float64).ravel()
    return x.size / tau_int(x, c)


# ---------------------------------------------------------------------------
# error bars: blocking + jackknife
# ---------------------------------------------------------------------------

def blocking_sems(x) -> np.ndarray:
    """Flyvbjerg-Petersen blocking: naive SEM at each pair-halving level.

    Level 0 is the raw (iid-assumption) SEM; each level averages adjacent
    pairs, halving the series.  For correlated data the SEM grows with
    level until blocks exceed the correlation length, then plateaus.
    """
    x = np.asarray(x, np.float64).ravel()
    sems = []
    while x.size >= 2:
        sems.append(x.std(ddof=1) / math.sqrt(x.size))
        x = (x[: 2 * (x.size // 2)].reshape(-1, 2)).mean(axis=1)
    return np.asarray(sems)


def blocking_error(x, min_blocks: int = 16) -> float:
    """Blocking SEM: the plateau (max) over levels with >= min_blocks
    blocks -- levels with fewer blocks are too noisy to trust."""
    x = np.asarray(x, np.float64).ravel()
    sems = blocking_sems(x)
    if sems.size == 0:
        return 0.0
    # level l has n / 2^l blocks
    usable = [s for l, s in enumerate(sems)
              if x.size / (1 << l) >= min_blocks]
    return float(max(usable) if usable else sems[-1])


def jackknife(x, stat: Callable[[np.ndarray], float] = np.mean,
              n_blocks: int = 20) -> Tuple[float, float]:
    """Delete-one-block jackknife estimate and error of ``stat``.

    Blocking absorbs autocorrelation (choose blocks >> tau_int);
    jackknifing propagates errors through *nonlinear* statistics (Binder
    cumulant, chi) where naive SEM formulas do not apply.  Returns
    ``(stat(x), err)``.
    """
    x = np.asarray(x, np.float64).ravel()
    full = float(stat(x))  # the point estimate uses every sample
    nb = max(2, min(n_blocks, x.size))
    m = nb * (x.size // nb)
    if m < nb:  # fewer samples than blocks
        return full, 0.0
    blocks = x[:m].reshape(nb, -1)  # only the error bar truncates to blocks
    mask = ~np.eye(nb, dtype=bool)
    theta = np.array([float(stat(blocks[mask[i]].ravel()))
                      for i in range(nb)])
    err = math.sqrt((nb - 1) / nb * ((theta - theta.mean()) ** 2).sum())
    return full, err


# ---------------------------------------------------------------------------
# physics estimators (paper S5.3)
# ---------------------------------------------------------------------------

def binder_from_moments(m2: float, m4: float) -> float:
    """U = 1 - <m^4> / (3 <m^2>^2)."""
    return 1.0 - m4 / (3.0 * m2 * m2) if m2 > 0 else 0.0


def binder(m_samples) -> float:
    m = np.asarray(m_samples, np.float64).ravel()
    return binder_from_moments(float((m ** 2).mean()),
                               float((m ** 4).mean()))


def susceptibility(m_samples, temperature: float, n_spins: int) -> float:
    """chi = beta N (<m^2> - <|m|>^2) >= 0 (per-spin magnetization)."""
    m = np.asarray(m_samples, np.float64).ravel()
    var_abs = float((m ** 2).mean() - np.abs(m).mean() ** 2)
    return n_spins / temperature * max(var_abs, 0.0)


def specific_heat(e_samples, temperature: float, n_spins: int) -> float:
    """C_v = beta^2 N (<e^2> - <e>^2) >= 0 (per-spin energy)."""
    e = np.asarray(e_samples, np.float64).ravel()
    return n_spins / temperature ** 2 * max(float(e.var()), 0.0)


def binder_crossing(temps: Sequence[float], u_small: Sequence[float],
                    u_large: Sequence[float]) -> Optional[float]:
    """T_c from the crossing of two lattice sizes' Binder curves.

    Below T_c the larger lattice's U is higher (closer to 2/3), above it
    lower (closer to 0), so ``d = U_large - U_small`` crosses zero from
    above at T_c.  Linear interpolation at every +- sign change of d;
    multiple (noise-induced) crossings average.  None if no crossing.
    """
    t = np.asarray(temps, np.float64)
    d = np.asarray(u_large, np.float64) - np.asarray(u_small, np.float64)
    assert t.ndim == 1 and t.shape == d.shape, (t.shape, d.shape)
    order = np.argsort(t)
    t, d = t[order], d[order]
    crossings = []
    for i in range(t.size - 1):
        if d[i] > 0.0 >= d[i + 1]:
            frac = d[i] / (d[i] - d[i + 1])
            crossings.append(t[i] + frac * (t[i + 1] - t[i]))
    return float(np.mean(crossings)) if crossings else None
