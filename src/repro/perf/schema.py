"""Schema for BENCH_<stamp>.json perf records (EXPERIMENTS.md S Bench).

Two row formats are valid and must both stay readable forever -- the
committed baselines are history, not fixtures to regenerate:

* legacy (pre-noise-model): ``{"name", "us_per_call", "derived"}``;
* noise-model rows additionally carry ``n_trials`` (>= 1) and
  ``median_us_per_call``, plus ``iqr_us_per_call`` when ``n_trials >=
  2`` -- a single trial must NOT record an IQR (one sample says nothing
  about spread; recording 0 would read as "perfectly stable" to the
  gate, the exact bug this schema exists to prevent).

``benchmarks/run.py --json`` validates every record through
:func:`validate_record` before writing it; the committed baselines are
golden-file checked in ``tests/test_bench_schema.py``.
"""
from __future__ import annotations

import json
import math
from typing import Dict

#: meta keys every record must carry (run provenance)
REQUIRED_META = ("stamp", "backend", "device_count")

#: the full set of keys a row may carry
ROW_KEYS = frozenset({"name", "us_per_call", "derived", "spec",
                      "n_trials", "median_us_per_call",
                      "iqr_us_per_call"})

#: derived keys that, when present, must be finite non-negative numbers
#: (they are rates/percentages -- a negative one is always a harness bug)
NONNEG_DERIVED = ("flips_per_ns", "replica_flips_per_ns",
                  "pct_of_roofline", "dispatches", "us_per_sample")


class SchemaError(ValueError):
    """A BENCH record violates the perf-record schema."""


def _fail(ctx: str, msg: str) -> None:
    raise SchemaError(f"{ctx}: {msg}")


def _check_num(ctx: str, key: str, v, *, nonneg: bool = True) -> float:
    if isinstance(v, bool) or not isinstance(v, (int, float)):
        _fail(ctx, f"{key} must be a number, got {type(v).__name__}")
    f = float(v)
    if not math.isfinite(f):
        _fail(ctx, f"{key} must be finite, got {v!r}")
    if nonneg and f < 0:
        _fail(ctx, f"{key} must be >= 0, got {v!r}")
    return f


def validate_row(row: dict, ctx: str = "row") -> None:
    """Raise :class:`SchemaError` unless ``row`` is a valid perf row."""
    if not isinstance(row, dict):
        _fail(ctx, f"row must be a dict, got {type(row).__name__}")
    name = row.get("name")
    if not isinstance(name, str) or not name:
        _fail(ctx, f"name must be a non-empty string, got {name!r}")
    ctx = f"{ctx} {name!r}"
    extra = set(row) - ROW_KEYS
    if extra:
        _fail(ctx, f"unknown row keys {sorted(extra)}")
    for req in ("us_per_call", "derived"):
        if req not in row:
            _fail(ctx, f"missing required key {req!r}")
    _check_num(ctx, "us_per_call", row["us_per_call"])
    derived = row["derived"]
    if not isinstance(derived, dict):
        _fail(ctx, "derived must be a dict")
    for k, v in derived.items():
        if not isinstance(k, str):
            _fail(ctx, f"derived key {k!r} must be a string")
        if not isinstance(v, (str, int, float)) or isinstance(v, bool):
            _fail(ctx, f"derived[{k!r}] must be str or number")
        if k in NONNEG_DERIVED:
            _check_num(ctx, f"derived[{k!r}]", v)
    # noise-model fields: all-or-nothing, and IQR only with n >= 2
    if "n_trials" in row:
        n = row["n_trials"]
        if isinstance(n, bool) or not isinstance(n, int) or n < 1:
            _fail(ctx, f"n_trials must be an int >= 1, got {n!r}")
        if "median_us_per_call" not in row:
            _fail(ctx, "n_trials without median_us_per_call")
        _check_num(ctx, "median_us_per_call", row["median_us_per_call"])
        if n >= 2:
            if "iqr_us_per_call" not in row:
                _fail(ctx, f"n_trials={n} requires iqr_us_per_call")
            _check_num(ctx, "iqr_us_per_call", row["iqr_us_per_call"])
        elif "iqr_us_per_call" in row:
            _fail(ctx, "iqr_us_per_call recorded from a single trial")
    else:
        for k in ("median_us_per_call", "iqr_us_per_call"):
            if k in row:
                _fail(ctx, f"{k} without n_trials")
    if "spec" in row:
        spec = row["spec"]
        if not isinstance(spec, str):
            _fail(ctx, "spec must be a JSON string")
        try:
            parsed = json.loads(spec)
        except json.JSONDecodeError as e:
            _fail(ctx, f"spec is not valid JSON: {e}")
        if not isinstance(parsed, dict):
            _fail(ctx, "spec JSON must be an object")
        # full RunSpec round-trip (DESIGN.md S10): a recorded spec that
        # does not parse back is an unreplayable perf number
        from repro.api import RunSpec
        try:
            RunSpec.from_json(spec)
        except Exception as e:
            _fail(ctx, f"spec does not parse as a RunSpec: {e}")


def validate_record(record: dict, ctx: str = "record") -> None:
    """Raise :class:`SchemaError` unless ``record`` is a valid
    BENCH_<stamp>.json perf record (meta + non-empty uniquely-named
    rows)."""
    if not isinstance(record, dict):
        _fail(ctx, f"record must be a dict, got {type(record).__name__}")
    extra = set(record) - {"meta", "rows"}
    if extra:
        _fail(ctx, f"unknown top-level keys {sorted(extra)}")
    meta = record.get("meta")
    if not isinstance(meta, dict):
        _fail(ctx, "missing/invalid meta")
    for k in REQUIRED_META:
        if k not in meta:
            _fail(ctx, f"meta missing {k!r}")
    rows = record.get("rows")
    if not isinstance(rows, list) or not rows:
        _fail(ctx, "rows must be a non-empty list")
    seen: Dict[str, int] = {}
    for i, row in enumerate(rows):
        validate_row(row, ctx=f"{ctx} rows[{i}]")
        name = row["name"]
        if name in seen:
            _fail(ctx, f"duplicate row name {name!r} "
                       f"(rows {seen[name]} and {i})")
        seen[name] = i
