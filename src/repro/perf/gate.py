"""Statistical perf gate: candidate BENCH record vs baseline + budgets.

The old CI perf check was a flat "any row > 25% slower -> warn (never
fail)".  This gate replaces it with a noise model (EXPERIMENTS.md
S Perf-gate):

* Per shared row, the baseline's recorded spread sets the tolerance:
  ``tol = clamp(noise_mult * IQR/median, rel_floor, rel_cap)``.  A
  candidate median outside ``[median/(1+tol), median*(1+tol)]`` is a
  statistically real change -- slower fails the gate, faster is flagged
  as a suspicious improvement (advisory: refresh the baseline so the
  gate keeps teeth against the new level).  Legacy baseline rows with
  no recorded spread fall back to ``legacy_rel_tol`` (the old flat
  25%).
* ``benchmarks/budgets.json`` adds absolute per-row flips/ns floors
  (``min_flips_per_ns``), so a slow regression that creeps in across
  several baseline refreshes still trips the gate.
* Baseline rows missing from an unfiltered candidate run fail (a bench
  silently dropped is a regression in coverage); a filtered run
  (``--only``/``--engines`` in the candidate's meta) skips them, so
  the CI smoke subset gates cleanly against the full committed
  baseline.  Candidate rows with no baseline (new engines) are
  advisory ``new`` -- they need a baseline refresh, not a red build.

CLI::

    python -m repro.perf.gate BASELINE.json CANDIDATE.json \
        --budgets benchmarks/budgets.json [--advisory] [--out gate.md]
    python -m repro.perf.gate --init-budgets benchmarks/budgets.json \
        BASELINE.json [--safety 0.4]
"""
from __future__ import annotations

import argparse
import json
import os
from dataclasses import asdict, dataclass, field
from typing import Dict, List, Optional, Tuple

DEFAULT_BUDGETS_PATH = os.path.join("benchmarks", "budgets.json")


@dataclass(frozen=True)
class GateConfig:
    """Noise-model knobs (persisted in budgets.json under "gate")."""

    #: tolerance = noise_mult * (baseline IQR / baseline median) ...
    noise_mult: float = 4.0
    #: ... floored (quiet rows still get slack for scheduler jitter) ...
    rel_floor: float = 0.10
    #: ... and capped (a wildly noisy baseline row must not disable
    #: the gate outright)
    rel_cap: float = 0.75
    #: tolerance for legacy baseline rows with no recorded spread --
    #: the old flat 25% threshold, now only a fallback
    legacy_rel_tol: float = 0.25

    @classmethod
    def from_dict(cls, d: dict) -> "GateConfig":
        known = {k: float(v) for k, v in d.items()
                 if k in cls.__dataclass_fields__}
        unknown = set(d) - set(known)
        if unknown:
            raise ValueError(f"unknown gate config keys {sorted(unknown)}")
        return cls(**known)


@dataclass
class RowVerdict:
    name: str
    status: str                 # ok|regression|improvement|missing|new|budget
    base_us: Optional[float] = None
    cand_us: Optional[float] = None
    ratio: Optional[float] = None   # cand/base median time (>1 = slower)
    tol: Optional[float] = None
    detail: str = ""

    @property
    def fails(self) -> bool:
        return self.status in ("regression", "missing", "budget")


@dataclass
class GateResult:
    baseline: str
    candidate: str
    filtered: bool
    rows: List[RowVerdict] = field(default_factory=list)

    def by_status(self, *statuses: str) -> List[RowVerdict]:
        return [r for r in self.rows if r.status in statuses]

    @property
    def failed(self) -> bool:
        return any(r.fails for r in self.rows)

    def to_markdown(self) -> str:
        out = [f"### Perf gate — {self.baseline} → {self.candidate}"
               + (" (filtered candidate: unselected baseline rows "
                  "skipped)" if self.filtered else ""), ""]
        out.append("| row | status | base us | cand us | ratio | tol |"
                   " detail |")
        out.append("|---|---|---|---|---|---|---|")

        def fmt(v, spec="{:.1f}"):
            return "-" if v is None else spec.format(v)

        order = {"regression": 0, "budget": 1, "missing": 2,
                 "improvement": 3, "new": 4, "ok": 5}
        for r in sorted(self.rows, key=lambda r: (order[r.status],
                                                  r.name)):
            mark = {"regression": "**REGRESSION**", "budget": "**BUDGET**",
                    "missing": "**MISSING**",
                    "improvement": "improvement?"}.get(r.status, r.status)
            out.append(f"| {r.name} | {mark} | {fmt(r.base_us)} |"
                       f" {fmt(r.cand_us)} | {fmt(r.ratio, '{:.3f}')} |"
                       f" {fmt(r.tol, '{:.3f}')} | {r.detail} |")
        n_fail = sum(r.fails for r in self.rows)
        n_imp = len(self.by_status("improvement"))
        out.append("")
        out.append(f"**{'FAIL' if self.failed else 'PASS'}** — "
                   f"{len(self.rows)} rows checked, {n_fail} failing, "
                   f"{n_imp} suspicious improvements"
                   + (" (refresh the baseline: EXPERIMENTS.md "
                      "S Perf-gate)" if n_imp else ""))
        return "\n".join(out)


def row_stats(row: dict) -> Tuple[float, Optional[float], int]:
    """(median_us, iqr_us or None, n_trials) tolerating both formats.

    Legacy rows (and single-trial rows, which record no IQR) return
    ``iqr=None`` -- the caller must fall back to ``legacy_rel_tol``,
    never treat the absence of spread as zero spread.
    """
    if "n_trials" in row:
        return (float(row["median_us_per_call"]),
                (float(row["iqr_us_per_call"])
                 if "iqr_us_per_call" in row else None),
                int(row["n_trials"]))
    return float(row["us_per_call"]), None, 1


def tolerance(base_row: dict, cfg: GateConfig) -> float:
    """Relative tolerance band for one baseline row."""
    median, iqr, _ = row_stats(base_row)
    if iqr is None or median <= 0.0:
        return cfg.legacy_rel_tol
    rel = iqr / median
    return min(max(cfg.noise_mult * rel, cfg.rel_floor), cfg.rel_cap)


def classify(ratio: float, tol: float) -> str:
    """'regression' | 'improvement' | 'ok' for a cand/base time ratio.

    The band is multiplicative-symmetric: ``[1/(1+tol), 1+tol]`` --
    so ``classify(r, t) == 'regression'`` iff ``classify(1/r, t) ==
    'improvement'`` (property-tested)."""
    if ratio > 1.0 + tol:
        return "regression"
    if ratio < 1.0 / (1.0 + tol):
        return "improvement"
    return "ok"


def throughput(row: dict) -> Tuple[Optional[str], Optional[float]]:
    d = row.get("derived", {})
    for key in ("replica_flips_per_ns", "flips_per_ns"):
        v = d.get(key)
        if isinstance(v, (int, float)) and v > 0:
            return key, float(v)
    return None, None


def _is_filtered(record: dict) -> bool:
    meta = record.get("meta", {})
    return bool(meta.get("only") or meta.get("engines")
                or meta.get("spec_file"))


def gate(baseline: dict, candidate: dict,
         budgets: Optional[dict] = None,
         cfg: Optional[GateConfig] = None) -> GateResult:
    """Compare two BENCH records (parsed JSON) under the noise model."""
    if cfg is None:
        cfg = GateConfig.from_dict((budgets or {}).get("gate", {}))
    base_rows = {r["name"]: r for r in baseline["rows"]}
    cand_rows = {r["name"]: r for r in candidate["rows"]}
    filtered = _is_filtered(candidate)
    res = GateResult(baseline=str(baseline.get("meta", {}).get("stamp")),
                     candidate=str(candidate.get("meta", {}).get("stamp")),
                     filtered=filtered)
    floors = (budgets or {}).get("rows", {})

    for name in sorted(set(base_rows) | set(cand_rows)):
        b, c = base_rows.get(name), cand_rows.get(name)
        if c is None:
            if not filtered:
                res.rows.append(RowVerdict(
                    name, "missing", base_us=row_stats(b)[0],
                    detail="baseline row absent from unfiltered "
                           "candidate run"))
            continue
        if b is None:
            res.rows.append(RowVerdict(
                name, "new", cand_us=row_stats(c)[0],
                detail="no baseline row (new engine/bench?) -- refresh "
                       "the baseline to start gating it"))
            continue
        b_med, _, _ = row_stats(b)
        c_med, _, _ = row_stats(c)
        tol = tolerance(b, cfg)
        if b_med <= 0.0:
            res.rows.append(RowVerdict(name, "ok", b_med, c_med,
                                       detail="untimed row"))
            continue
        ratio = c_med / b_med
        status = classify(ratio, tol)
        detail = ""
        if status == "regression":
            detail = (f"median {ratio:+.1%} vs baseline, outside the "
                      f"±{tol:.0%} noise band")
        elif status == "improvement":
            detail = (f"median {ratio - 1.0:+.1%} -- faster than the "
                      f"noise band; real win or broken bench?")
        res.rows.append(RowVerdict(name, status, b_med, c_med,
                                   ratio=ratio, tol=tol, detail=detail))

    # absolute throughput floors (survive baseline refreshes)
    for name, budget in sorted(floors.items()):
        c = cand_rows.get(name)
        if c is None:
            continue
        floor = budget.get("min_flips_per_ns")
        if floor is None:
            continue
        key, measured = throughput(c)
        if measured is None:
            res.rows.append(RowVerdict(
                name, "budget", detail="budget row carries no "
                "flips/ns metric in candidate"))
        elif measured < float(floor):
            res.rows.append(RowVerdict(
                name, "budget",
                detail=f"{key}={measured:.4g} below budget floor "
                       f"{floor:.4g}"))
    return res


# ---------------------------------------------------------------------------
# budgets file
# ---------------------------------------------------------------------------

def load_budgets(path: str) -> dict:
    with open(path) as f:
        budgets = json.load(f)
    extra = set(budgets) - {"gate", "rows"}
    if extra:
        raise ValueError(f"budgets {path}: unknown keys {sorted(extra)}")
    GateConfig.from_dict(budgets.get("gate", {}))  # validate
    return budgets


def dump_budgets(budgets: dict, path: str) -> str:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(budgets, f, indent=1, sort_keys=True)
        f.write("\n")
    return path


def make_budgets(baseline: dict, safety: float = 0.4,
                 cfg: Optional[GateConfig] = None) -> dict:
    """Budgets from a baseline record: per-row flips/ns floors at
    ``safety`` x the measured value (generous on purpose -- the floor
    catches slow multi-refresh creep, the noise band catches per-PR
    regressions), plus the gate config so CI and dev runs share one
    noise model."""
    cfg = cfg or GateConfig()
    rows = {}
    for row in baseline["rows"]:
        _, measured = throughput(row)
        if measured is not None:
            rows[row["name"]] = {
                "min_flips_per_ns": float(f"{measured * safety:.4g}")}
    return {"gate": asdict(cfg), "rows": rows}


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.perf.gate", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("baseline", help="baseline BENCH_<stamp>.json")
    ap.add_argument("candidate", nargs="?", default=None,
                    help="candidate BENCH_<stamp>.json (omit with "
                         "--init-budgets)")
    ap.add_argument("--budgets", default=None,
                    help=f"budgets file (e.g. {DEFAULT_BUDGETS_PATH})")
    ap.add_argument("--advisory", action="store_true",
                    help="report but exit 0 -- the escape hatch for "
                         "intentional perf changes pending a baseline "
                         "refresh")
    ap.add_argument("--out", default=None,
                    help="also write the markdown report here")
    ap.add_argument("--init-budgets", default=None, metavar="PATH",
                    help="write a budgets file derived from BASELINE "
                         "and exit")
    ap.add_argument("--safety", type=float, default=0.4,
                    help="--init-budgets floor = safety * measured "
                         "flips/ns (default 0.4)")
    args = ap.parse_args(argv)

    with open(args.baseline) as f:
        baseline = json.load(f)

    if args.init_budgets:
        budgets = make_budgets(baseline, safety=args.safety)
        path = dump_budgets(budgets, args.init_budgets)
        print(f"# wrote {path}: {len(budgets['rows'])} row floors at "
              f"{args.safety}x baseline")
        return 0

    if args.candidate is None:
        ap.error("candidate record required (or use --init-budgets)")
    with open(args.candidate) as f:
        candidate = json.load(f)
    budgets = load_budgets(args.budgets) if args.budgets else None

    result = gate(baseline, candidate, budgets=budgets)
    report = result.to_markdown()
    print(report)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            f.write(report + "\n")
    if result.failed and args.advisory:
        print("\n(advisory mode: failures reported, exit 0)")
        return 0
    return 1 if result.failed else 0


if __name__ == "__main__":
    raise SystemExit(main())
