"""repro.perf -- the performance contract (DESIGN.md S11).

Turns ``benchmarks/BENCH_*.json`` from a pile of snapshots into an
enforced contract:

* :mod:`repro.perf.schema` -- what a valid perf record looks like
  (every ``benchmarks/run.py --json`` emission is validated before it
  is written; the committed baselines are golden-file tested);
* :mod:`repro.perf.gate` -- the statistical regression gate: candidate
  vs baseline per row using the baseline's *recorded* noise band
  (median +- noise_mult * IQR, floored) instead of a flat threshold,
  plus absolute flips/ns floors from ``benchmarks/budgets.json``.

CLI: ``python -m repro.perf.gate BASELINE CANDIDATE --budgets
benchmarks/budgets.json`` (exit 1 on a statistically real regression;
``--advisory`` reports without failing).
"""
_GATE = ("GateConfig", "GateResult", "RowVerdict", "classify", "gate",
         "load_budgets", "make_budgets", "row_stats", "throughput",
         "tolerance")
_SCHEMA = ("SchemaError", "validate_record", "validate_row")

__all__ = list(_GATE + _SCHEMA)


def __getattr__(name):
    # lazy re-exports: `python -m repro.perf.gate` must not trigger an
    # eager package-level import of the same module (runpy warning)
    if name in _GATE:
        from . import gate as _g
        return getattr(_g, name)
    if name in _SCHEMA:
        from . import schema as _s
        return getattr(_s, name)
    raise AttributeError(name)
