from .base import ArchConfig, ShapeConfig, SHAPES, shape_applicable  # noqa: F401
from .registry import ARCH_IDS, get_config, get_smoke_config  # noqa: F401
