"""internvl2-26b [vlm]: InternViT frontend (STUB: precomputed patch
embeddings) + InternLM2-style 48L backbone [arXiv:2404.16821; hf]."""
from .base import ArchConfig

ARCH = ArchConfig(
    name="internvl2-26b", family="vlm", n_layers=48, d_model=6144,
    n_heads=48, n_kv_heads=8, head_dim=128, d_ff=16384, vocab=92553,
    prefix_len=256,
)

def smoke_config():
    return ARCH.with_overrides(n_layers=2, d_model=64, n_heads=4,
                               n_kv_heads=2, head_dim=16, d_ff=128,
                               vocab=257, prefix_len=4)
