"""deepseek-v2-lite-16b [moe]: MLA (kv_lora=512) + fine-grained MoE
[arXiv:2405.04434; hf].  2 shared + 64 routed experts, top-6 (the task
header says "MoE 64e top-6"; the inline "160 routed" matches full V2, not
Lite -- we follow the 64e header; see DESIGN.md S4)."""
from .base import ArchConfig

ARCH = ArchConfig(
    name="deepseek-v2-lite-16b", family="moe", n_layers=27, d_model=2048,
    n_heads=16, n_kv_heads=16, head_dim=128, d_ff=1408, vocab=102400,
    moe=True, n_routed=64, n_shared=2, top_k=6, d_ff_expert=1408,
    first_dense=1, mla=True, kv_lora=512, qk_nope=128, qk_rope=64,
)

def smoke_config():
    return ARCH.with_overrides(n_layers=3, d_model=64, n_heads=4,
                               n_kv_heads=4, head_dim=16, d_ff=128,
                               vocab=256, n_routed=8, n_shared=1, top_k=2,
                               d_ff_expert=32, kv_lora=32, qk_nope=16,
                               qk_rope=8)
