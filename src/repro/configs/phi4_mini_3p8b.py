"""phi4-mini-3.8b [dense]: RoPE + SwiGLU + GQA(kv=8) [arXiv:2412.08905; hf]."""
from .base import ArchConfig

ARCH = ArchConfig(
    name="phi4-mini-3.8b", family="dense", n_layers=32, d_model=3072,
    n_heads=24, n_kv_heads=8, head_dim=128, d_ff=8192, vocab=200064,
)

def smoke_config():
    return ARCH.with_overrides(n_layers=2, d_model=64, n_heads=4,
                               n_kv_heads=2, head_dim=16, d_ff=128,
                               vocab=256)
