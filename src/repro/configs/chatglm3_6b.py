"""chatglm3-6b [dense]: 2d RoPE (half-dim rotary), GQA kv=2, QKV bias
[arXiv:2406.12793; hf]."""
from .base import ArchConfig

ARCH = ArchConfig(
    name="chatglm3-6b", family="dense", n_layers=28, d_model=4096,
    n_heads=32, n_kv_heads=2, head_dim=128, d_ff=13696, vocab=65024,
    rotary_frac=0.5, attn_bias=True,
)

def smoke_config():
    return ARCH.with_overrides(n_layers=2, d_model=64, n_heads=4,
                               n_kv_heads=2, head_dim=16, d_ff=128,
                               vocab=256)
