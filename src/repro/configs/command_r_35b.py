"""command-r-35b [dense]: GQA(kv=8), no biases
[hf:CohereForAI/c4ai-command-r-v01; unverified]."""
from .base import ArchConfig

ARCH = ArchConfig(
    name="command-r-35b", family="dense", n_layers=40, d_model=8192,
    n_heads=64, n_kv_heads=8, head_dim=128, d_ff=22528, vocab=256000,
    attn_bias=False,
)

def smoke_config():
    return ARCH.with_overrides(n_layers=2, d_model=64, n_heads=8,
                               n_kv_heads=2, head_dim=16, d_ff=160,
                               vocab=256)
