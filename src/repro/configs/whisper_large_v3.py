"""whisper-large-v3 [audio]: encoder-decoder; conv frontend STUB --
input_specs() provides precomputed 1500-frame embeddings
[arXiv:2212.04356; unverified].  Sinusoidal positions (no RoPE), LayerNorm,
plain GELU MLP, attention biases; architectural max decode context 448,
so decode shapes lower structurally with the full requested cache and the
long_500k cell is skipped (DESIGN.md S4)."""
from .base import ArchConfig

ARCH = ArchConfig(
    name="whisper-large-v3", family="audio", n_layers=32, d_model=1280,
    n_heads=20, n_kv_heads=20, head_dim=64, d_ff=5120, vocab=51866,
    norm="layer", gated_mlp=False, act="gelu", attn_bias=True,
    enc_layers=32, enc_seq=1500, use_rope=False, max_decode_len=448,
)

def smoke_config():
    return ARCH.with_overrides(n_layers=2, d_model=64, n_heads=4,
                               n_kv_heads=4, head_dim=16, d_ff=128,
                               vocab=256, enc_layers=2, enc_seq=8)
