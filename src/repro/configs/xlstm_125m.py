"""xlstm-125m [ssm]: sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].
Every 4th block is sLSTM (scalar memory); the rest are mLSTM (matrix
memory, chunk-parallel linear attention).  long_500k runs (O(1) state)."""
from .base import ArchConfig

ARCH = ArchConfig(
    name="xlstm-125m", family="ssm", n_layers=12, d_model=768,
    n_heads=4, n_kv_heads=4, head_dim=192, d_ff=0, vocab=50304,
    slstm_every=4, long_context_ok=True, gated_mlp=False,
)

def smoke_config():
    return ARCH.with_overrides(n_layers=4, d_model=64, n_heads=4,
                               n_kv_heads=4, head_dim=16, vocab=256,
                               slstm_every=2)
