"""deepseek-moe-16b [moe]: fine-grained expert segmentation + shared expert
isolation [arXiv:2401.06066; hf].  GQA attention, 2 shared + 64 routed
top-6, first layer dense."""
from .base import ArchConfig

ARCH = ArchConfig(
    name="deepseek-moe-16b", family="moe", n_layers=28, d_model=2048,
    n_heads=16, n_kv_heads=16, head_dim=128, d_ff=1408, vocab=102400,
    moe=True, n_routed=64, n_shared=2, top_k=6, d_ff_expert=1408,
    first_dense=1,
)

def smoke_config():
    return ARCH.with_overrides(n_layers=3, d_model=64, n_heads=4,
                               n_kv_heads=4, head_dim=16, d_ff=128,
                               vocab=256, n_routed=8, n_shared=1, top_k=2,
                               d_ff_expert=32)
