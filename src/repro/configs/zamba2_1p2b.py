"""zamba2-1.2b [hybrid]: Mamba2 backbone + shared attention block
[arXiv:2411.15242; hf].  38 Mamba2 layers, one SHARED attn+MLP block invoked
every 6 blocks (weight reuse, the Zamba signature).  long_500k runs: SSM
state is O(1); the shared attn uses a sliding window at 500k (DESIGN.md S4).
"""
from .base import ArchConfig

ARCH = ArchConfig(
    name="zamba2-1.2b", family="hybrid", n_layers=38, d_model=2048,
    n_heads=32, n_kv_heads=32, head_dim=64, d_ff=8192, vocab=32000,
    ssm_state=64, mamba_head_dim=64, mamba_expand=2, attn_every=6,
    long_context_ok=True, long_sliding_window=4096,
)

def smoke_config():
    return ARCH.with_overrides(n_layers=4, d_model=64, n_heads=4,
                               n_kv_heads=4, head_dim=16, d_ff=128,
                               vocab=256, ssm_state=16, mamba_head_dim=16,
                               attn_every=2)
