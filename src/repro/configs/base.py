"""ArchConfig: one declarative description drives init, apply, sharding,
input specs, and the dry-run for every assigned architecture."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str              # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    norm: str = "rms"        # rms | layer
    gated_mlp: bool = True
    act: str = "silu"
    rotary_frac: float = 1.0
    rope_theta: float = 10000.0
    attn_bias: bool = False
    # --- MoE ---
    moe: bool = False
    n_routed: int = 0
    n_shared: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    first_dense: int = 1
    # --- MLA ---
    mla: bool = False
    kv_lora: int = 512
    qk_nope: int = 128
    qk_rope: int = 64
    # --- SSM / hybrid ---
    ssm_state: int = 0
    mamba_head_dim: int = 64
    mamba_expand: int = 2
    attn_every: int = 0      # hybrid: shared attn block after every k blocks
    slstm_every: int = 0     # xlstm: every k-th block is sLSTM
    # --- encoder-decoder (audio) ---
    enc_layers: int = 0
    enc_seq: int = 0
    use_rope: bool = True    # whisper uses learned/sinusoidal abs positions
    # --- VLM ---
    prefix_len: int = 0      # patch-embedding prefix from the stub frontend
    # --- long context ---
    long_context_ok: bool = False
    long_sliding_window: int = 4096
    max_decode_len: int = 0  # 0 = unrestricted

    def with_overrides(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                # train | prefill | decode


SHAPES: dict = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether (arch, shape) is a well-defined cell (DESIGN.md S4)."""
    if shape.name == "long_500k" and not cfg.long_context_ok:
        return False, "full-attention arch: 500k decode is quadratic-infeasible"
    if cfg.max_decode_len and shape.kind == "decode" \
            and shape.seq_len > cfg.max_decode_len and not cfg.long_context_ok:
        return False, f"architectural max context {cfg.max_decode_len}"
    return True, ""
