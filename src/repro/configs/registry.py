"""Architecture registry: --arch <id> -> ArchConfig."""
from __future__ import annotations

import importlib

_MODULES = {
    "zamba2-1.2b": "zamba2_1p2b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "phi4-mini-3.8b": "phi4_mini_3p8b",
    "command-r-35b": "command_r_35b",
    "chatglm3-6b": "chatglm3_6b",
    "internlm2-1.8b": "internlm2_1p8b",
    "internvl2-26b": "internvl2_26b",
    "xlstm-125m": "xlstm_125m",
    "whisper-large-v3": "whisper_large_v3",
}

ARCH_IDS = tuple(_MODULES)


def _mod(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[name]}")


def get_config(name: str):
    return _mod(name).ARCH


def get_smoke_config(name: str):
    return _mod(name).smoke_config()
