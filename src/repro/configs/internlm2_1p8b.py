"""internlm2-1.8b [dense]: GQA kv=8 [arXiv:2403.17297; hf]."""
from .base import ArchConfig

ARCH = ArchConfig(
    name="internlm2-1.8b", family="dense", n_layers=24, d_model=2048,
    n_heads=16, n_kv_heads=8, head_dim=128, d_ff=8192, vocab=92544,
)

def smoke_config():
    return ARCH.with_overrides(n_layers=2, d_model=64, n_heads=4,
                               n_kv_heads=2, head_dim=16, d_ff=128,
                               vocab=256)
