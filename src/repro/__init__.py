"""repro: multi-pod JAX framework around the 2D Ising GPU performance study."""
__version__ = "1.0.0"
