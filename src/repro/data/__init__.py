from .pipeline import DataConfig, DataIterator, make_batch  # noqa: F401
