"""Deterministic synthetic data pipeline with restart-exact skip-ahead.

Batches are pure functions of (seed, step) via counter-based Philox --
the same scheme the paper uses for simulation RNG (DESIGN.md S4): a
restarted job passes the checkpointed step and receives bit-identical
batches with no state replay.  Per-shape batch builders also serve as the
dry-run's input factories (real arrays for execution, ShapeDtypeStructs
via ``abstract=True``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core import rng as crng


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    # synthetic stream: tokens ~ philox(step, position) % vocab


def _tokens(seed: int, step: int, shape, vocab: int):
    n = int(np.prod(shape))
    idx = jnp.arange(n, dtype=jnp.uint32)
    bits = crng.philox4x32(jnp.uint32(step), jnp.uint32(0), idx,
                           jnp.uint32(1), jnp.uint32(seed),
                           jnp.uint32(0))[0]
    return (bits % jnp.uint32(max(vocab - 1, 1))).astype(
        jnp.int32).reshape(shape)


def make_batch(cfg: ArchConfig, shape: ShapeConfig, *, step: int = 0,
               seed: int = 0, abstract: bool = False,
               batch_override: int = 0, seq_override: int = 0) -> Dict:
    """One training/prefill batch for (arch, shape) at ``step``."""
    b = batch_override or shape.global_batch
    s = seq_override or shape.seq_len
    out: Dict = {}

    if cfg.family == "audio":
        if abstract:
            out["frames"] = jax.ShapeDtypeStruct((b, cfg.enc_seq,
                                                  cfg.d_model), jnp.bfloat16)
            out["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
            out["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
            return out
        key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
        out["frames"] = jax.random.normal(
            key, (b, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
        out["tokens"] = _tokens(seed, 2 * step, (b, s), cfg.vocab)
        out["labels"] = _tokens(seed, 2 * step + 1, (b, s), cfg.vocab)
        return out

    text_len = s - cfg.prefix_len if cfg.family == "vlm" else s
    if abstract:
        out["tokens"] = jax.ShapeDtypeStruct((b, text_len), jnp.int32)
        out["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        if cfg.family == "vlm":
            out["patch_emb"] = jax.ShapeDtypeStruct(
                (b, cfg.prefix_len, cfg.d_model), jnp.bfloat16)
        return out

    out["tokens"] = _tokens(seed, 2 * step, (b, text_len), cfg.vocab)
    out["labels"] = _tokens(seed, 2 * step + 1, (b, s), cfg.vocab)
    if cfg.family == "vlm":
        key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
        out["patch_emb"] = jax.random.normal(
            key, (b, cfg.prefix_len, cfg.d_model), jnp.bfloat16)
    return out


class DataIterator:
    """Stateful wrapper: next() yields (step, batch); skip(step) restores."""

    def __init__(self, cfg: ArchConfig, shape: ShapeConfig, seed: int = 0,
                 batch_override: int = 0, seq_override: int = 0):
        self.cfg, self.shape, self.seed = cfg, shape, seed
        self.step = 0
        self._b, self._s = batch_override, seq_override

    def skip_to(self, step: int) -> None:
        self.step = step

    def __next__(self):
        batch = make_batch(self.cfg, self.shape, step=self.step,
                           seed=self.seed, batch_override=self._b,
                           seq_override=self._s)
        out = (self.step, batch)
        self.step += 1
        return out
