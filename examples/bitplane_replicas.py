"""32 replicas from ONE simulation: the bitplane engine (DESIGN.md S8).

One `bitplane` session advances 32 independent replica lattices packed
1 bit/spin into each uint32 word, drawing ONE shared Philox uint32 per
site (1/32 of the nibble engine's randomness per replica-spin).  The
measured trajectory is `(n_measure, 32)`: 32 per-replica magnetization
series from a single fused `measure_scan` dispatch.

Two shared-randoms facts this example demonstrates (Block, Virnau &
Preis, arXiv:1007.3726; DESIGN.md S8):

* **Above/near T_c** the 32 chains stay distinct and the per-time-sample
  replica average genuinely reduces variance -- but the chains are
  *correlated* through the shared stream, so the error bar must come
  from a block jackknife over TIME, never from treating the replicas as
  32 independent measurements.
* **Below T_c** shared-randomness coupling *coalesces* chains: replicas
  falling into the same magnetization well merge into bit-identical
  configurations within a few hundred sweeps (at most the two +-m wells
  survive).  The replica multiplier is void there -- use an `Ensemble`
  of distinct seeds for ordered-phase statistics instead.

Run:  PYTHONPATH=src python examples/bitplane_replicas.py
"""
import numpy as np

from repro.analysis import jackknife, tau_int
from repro.api import EngineSpec, LatticeSpec, RunSpec, Session, SweepSpec

L = 48


def distinct_replicas(sim):
    black, white = (np.asarray(p) for p in sim.state)
    return len({(((black >> r) & 1).tobytes(), ((white >> r) & 1).tobytes())
                for r in range(sim.engine.replicas)})


def bitplane_spec(temp, sweep=None):
    return RunSpec(lattice=LatticeSpec(n=L, m=L),
                   engine=EngineSpec("bitplane"),
                   temperature=temp, seed=11, sweep=sweep)


# -- disordered side: 32 live chains, replica averaging works ---------------
TEMP = 2.5
sim = Session.open(bitplane_spec(TEMP, SweepSpec(thermalize=300,
                                                 measure_every=2,
                                                 n_measure=120)))
traj = sim.measure()
m = np.abs(traj["m"])                        # (120, 32) per-replica series
print(f"T={TEMP} (> Tc): trajectory {traj['m'].shape}, "
      f"{distinct_replicas(sim)}/32 distinct replica configs")

per_rep = np.array([jackknife(m[:, r])[0] for r in range(m.shape[1])])
print(f"  per-replica <|m|>: min {per_rep.min():.4f} max {per_rep.max():.4f}"
      f" spread {per_rep.std():.4f}")

series = m.mean(axis=1)                      # replica-average per sample...
est, err = jackknife(series)                 # ...then error-bar over time
_, err_single = jackknife(m[:, 0])
print(f"  replica-averaged <|m|> = {est:.4f} +- {err:.4f} "
      f"(single chain +- {err_single:.4f}, tau_int {tau_int(series):.2f})")
assert err < err_single                      # shared draws still help

# -- ordered side: shared randoms coalesce the chains -----------------------
TEMP = 2.0
sim = Session.open(bitplane_spec(TEMP))
sim.run(400)
k = distinct_replicas(sim)
print(f"T={TEMP} (< Tc): {k}/32 distinct replica configs after 400 sweeps "
      f"-- coalesced into the +-m wells; use Ensemble seeds below Tc")
assert k <= 4
