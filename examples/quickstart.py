"""Quickstart: simulate a 2D Ising lattice with every engine, validate
against Onsager's exact solution, and show the Pallas kernel path.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lattice as lat, multispin as ms, observables as obs
from repro.core.sim import SimConfig, Simulation
from repro.kernels.multispin.ops import run_sweeps_multispin

T = 1.8  # below Tc = 2.269: the lattice must order

print(f"== engines at T={T} (Onsager |m| = "
      f"{float(obs.onsager_magnetization(T)):.4f}) ==")
for engine in ("basic", "basic_philox", "multispin", "tensorcore"):
    sim = Simulation(SimConfig(n=64, m=64, temperature=T, seed=3,
                               engine=engine, tc_block=8))
    sim.run(300)
    print(f"  {engine:14s} |m| = {abs(sim.magnetization()):.4f}")

print("== Pallas multispin kernel (interpret=True on CPU) ==")
# start from the ground state: cold random starts can fall into the
# striped metastable states the paper reports in S5.3
full = jnp.ones((64, 64), jnp.int8)
bw, ww = ms.pack_lattice(*lat.split_checkerboard(full))
bw, ww = run_sweeps_multispin(bw, ww, jnp.float32(1 / T), 100, seed=5,
                              block_rows=8, interpret=True)
b, w = ms.unpack_lattice(bw, ww)
m = float(abs(b.astype(jnp.float32).mean() + w.astype(jnp.float32).mean()) / 2)
print(f"  kernel steady-state |m| = {m:.4f} "
      f"(Onsager {float(obs.onsager_magnetization(T)):.4f})")
print("ok")
