"""Quickstart: one typed `RunSpec` + `Session` drives every engine
(DESIGN.md S10), validated against Onsager's exact solution, plus the
raw Pallas kernel path.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp

from repro.api import EngineSpec, LatticeSpec, RunSpec, Session
from repro.core import lattice as lat, multispin as ms, observables as obs
from repro.kernels.multispin.ops import run_sweeps_multispin

T = 1.8  # below Tc = 2.269: the lattice must order

print(f"== engines at T={T} (Onsager |m| = "
      f"{float(obs.onsager_magnetization(T)):.4f}) ==")
for engine in ("basic", "basic_philox", "multispin", "tensorcore"):
    params = {"tc_block": 8} if engine == "tensorcore" else {}
    spec = RunSpec(lattice=LatticeSpec(n=64, m=64),
                   engine=EngineSpec(engine, params=params),
                   temperature=T, seed=3)
    session = Session.open(spec)
    session.run(300)
    print(f"  {engine:14s} |m| = {abs(session.magnetization()):.4f}")

# the spec is one serializable blob: the same JSON drives
# `python -m repro run` and rides inside every checkpoint
print("== spec round trip ==")
print(f"  {spec.to_json()[:72]}...")
assert RunSpec.from_json(spec.to_json()) == spec

print("== Pallas multispin kernel (interpret=True on CPU) ==")
# start from the ground state: cold random starts can fall into the
# striped metastable states the paper reports in S5.3
full = jnp.ones((64, 64), jnp.int8)
bw, ww = ms.pack_lattice(*lat.split_checkerboard(full))
bw, ww = run_sweeps_multispin(bw, ww, jnp.float32(1 / T), 100, seed=5,
                              block_rows=8, interpret=True)
b, w = ms.unpack_lattice(bw, ww)
m = float(abs(b.astype(jnp.float32).mean() + w.astype(jnp.float32).mean()) / 2)
print(f"  kernel steady-state |m| = {m:.4f} "
      f"(Onsager {float(obs.onsager_magnetization(T)):.4f})")
print("ok")
