"""Reproduce the paper's validation figures end-to-end with error bars.

Fig. 5: |m|(T) against Onsager's exact curve, with jackknife error bars,
susceptibility chi, specific heat C_v, and tau_int per temperature.
Fig. 6: Binder cumulant U_L(T) per lattice size and the U_L-crossing
estimate of T_c (exact: 2/ln(1+sqrt(2)) = 2.269185).

Every lattice size runs its whole temperature scan as ONE ensemble-mode
``RunSpec`` whose measured trajectory is ONE fused ``measure_scan``
dispatch (observables inside the compiled scan -- repro.analysis,
DESIGN.md S7; dispatch via repro.api.Session, S10).  Results are
serialized by ``RunRecorder`` to the EXPERIMENTS.md CSV schema with the
serialized per-size specs in the metadata, so every figure is
replayable from its record.

Run:    PYTHONPATH=src python examples/figures.py [--smoke] [--out DIR]
Smoke:  small lattices / short runs; asserts the Binder-crossing T_c
        lands within 2% of the exact value (the CI physics gate).
"""
import argparse
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.analysis import (RunRecorder, binder, binder_crossing,
                            jackknife, specific_heat, susceptibility,
                            tau_int)
from repro.api import (BatchSpec, EngineSpec, LatticeSpec, RunSpec,
                       Session, SweepSpec)
from repro.core import observables as obs

TEMPS = [1.5, 1.8, 2.0, 2.1, 2.15, 2.2, 2.25, 2.3, 2.35, 2.4, 2.5, 2.7,
         3.0]


def size_spec(L, temps, sweep, engine, seed0) -> RunSpec:
    """The ensemble-mode spec of one lattice size's temperature scan."""
    return RunSpec(
        lattice=LatticeSpec(n=L, m=L, init_p_up=1.0),
        engine=EngineSpec(engine),
        batch=BatchSpec(temperatures=tuple(temps),
                        seeds=tuple(seed0 + i
                                    for i in range(len(temps)))),
        sweep=sweep)


def scan_size(spec, recorder):
    """One lattice size: batched Session, fused measurement, rows."""
    L = spec.lattice.n
    temps = spec.batch.temperatures
    session = Session.open(spec)
    t0 = time.perf_counter()
    traj = session.measure()                 # {"m","e"}: (n_measure, B)
    us = (time.perf_counter() - t0) * 1e6 / len(temps)
    n_spins = L * L
    binders = []
    for i, T in enumerate(temps):
        m, e = traj["m"][:, i], traj["e"][:, i]
        m_abs, m_err = jackknife(np.abs(m))
        u, u_err = jackknife(m, stat=binder)
        binders.append(u)
        recorder.record(
            f"fig5_L{L}_T{T:.3f}", us,
            m=m_abs, m_err=m_err,
            onsager=float(obs.onsager_magnetization(T)),
            chi=susceptibility(m, T, n_spins),
            cv=specific_heat(e, T, n_spins),
            tau_int=tau_int(m))
        recorder.record(f"fig6_L{L}_T{T:.3f}", us, binder=u,
                        binder_err=u_err)
    return np.asarray(binders)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small/fast run; assert T_c within 2% of exact")
    ap.add_argument("--out", default="results", help="output directory")
    ap.add_argument("--engine", default="multispin")
    ap.add_argument("--sizes", type=int, nargs="*", default=None)
    args = ap.parse_args(argv)

    if args.smoke:
        sizes = args.sizes or [16, 32]
        sweep = SweepSpec(thermalize=400, measure_every=2,
                          n_measure=400)
    else:
        sizes = args.sizes or [32, 64]
        sweep = SweepSpec(thermalize=1500, measure_every=4,
                          n_measure=2000)

    specs = {L: size_spec(L, TEMPS, sweep, args.engine,
                          seed0=101 + 1000 * k)
             for k, L in enumerate(sizes)}
    # the recorder metadata IS the serialized specs: the whole figure
    # reproduces from this record alone (DESIGN.md S10)
    rec = RunRecorder(echo=True, meta={
        "figure": "fig5+fig6",
        "specs": {str(L): s.to_dict() for L, s in specs.items()}})

    u_by_size = {L: scan_size(specs[L], recorder=rec) for L in sizes}

    tc = binder_crossing(TEMPS, u_by_size[min(sizes)],
                         u_by_size[max(sizes)])
    rel = (abs(tc - obs.T_CRITICAL) / obs.T_CRITICAL
           if tc is not None else float("nan"))
    rec.record("fig6_tc_estimate", 0.0,
               tc=float("nan") if tc is None else tc,
               exact=obs.T_CRITICAL, rel_err=rel)

    os.makedirs(args.out, exist_ok=True)
    csv = rec.write_csv(os.path.join(args.out, "fig5_fig6.csv"))
    print(f"# wrote {csv}")
    print(f"# T_c estimate {tc} (exact {obs.T_CRITICAL}, "
          f"rel err {rel:.4f})")
    if args.smoke:
        assert tc is not None and rel < 0.02, (
            f"Binder-crossing T_c {tc} deviates {rel:.1%} from "
            f"{obs.T_CRITICAL} (>2%)")
        print("# smoke OK: T_c within 2% of exact")


if __name__ == "__main__":
    main()
