"""Distributed Ising on every local device: the paper's multi-GPU slab
decomposition as shard_map + ppermute halos, with bit-exactness vs the
single-device engine demonstrated.

Run:  PYTHONPATH=src XLA_FLAGS=--xla_force_host_platform_device_count=8 \
          python examples/multipod_sim.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distributed as dist, lattice as lat, \
    metropolis as metro, rng as crng

N = 64
nd = len(jax.devices())
shape, axes = ((2, nd // 4, 2), ("pod", "data", "model")) if nd >= 8 \
    else ((nd, 1), ("data", "model"))
from repro.launch.mesh import make_mesh
mesh = make_mesh(shape, axes)
print(f"devices={nd} mesh={dict(mesh.shape)}")

full = lat.init_lattice(jax.random.PRNGKey(7), N, N)
b, w = lat.split_checkerboard(full)
beta = jnp.float32(1 / 2.0)

step, sh = dist.make_ising_step(mesh, n=N, m=N, seed=5, n_sweeps=50)
# the step donates its plane buffers (EXPERIMENTS.md H1.8); hand it
# copies -- device_put alone may alias b/w on a single-device mesh
b1, w1 = step(jax.device_put(b.copy(), sh), jax.device_put(w.copy(), sh),
              beta, jnp.uint32(0))
mag = dist.magnetization_dist(mesh)
print(f"distributed m after 50 sweeps: {float(mag(b1, w1)):+.4f}")

# single-device reference, same Philox stream -> identical trajectory
from repro.core.metropolis import run_sweeps_philox
br, wr = run_sweeps_philox(b, w, beta, 50, seed=5)
same = (np.asarray(b1) == np.asarray(br)).all() \
    and (np.asarray(w1) == np.asarray(wr)).all()
print(f"bit-exact vs single device: {bool(same)}")
assert same
