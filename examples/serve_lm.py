"""Batched serving example: KV-cached greedy decode with slot recycling.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import sys

from repro.launch.serve import main

sys.exit(main(["--arch", "internlm2-1.8b", "--smoke", "--requests", "6",
               "--batch", "3", "--max-new", "8", "--max-len", "48"]))
