"""RETIRED seed-era example -- see the sweep-farm service instead.

The LLM token-decode serving scaffold this example drove is gone
(``repro.launch.serve`` is a deprecation stub).  The serving surface
of this repo is the fault-tolerant sweep farm:

    PYTHONPATH=src python -m repro serve results/farm
    # then submit RunSpec JSON jobs with repro.serve.ServeClient

See README "Sweep-farm service" and DESIGN.md S14.
"""
import sys

from repro.launch.serve import main

sys.exit(main())
