"""Reproduce the paper's validation figures (Fig. 5 magnetization curve,
Fig. 6 Binder cumulant) on small lattices.

Run:  PYTHONPATH=src python examples/phase_transition.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import observables as obs
from repro.core.sim import SimConfig, Simulation

temps = [1.5, 1.8, 2.0, 2.1, 2.2, 2.27, 2.35, 2.5, 3.0]
sizes = [32, 48]

print("T      " + "".join(f"  L={L}:m,U_L   " for L in sizes) + " onsager")
for T in temps:
    row = f"{T:5.2f} "
    for L in sizes:
        # ordered start below Tc: avoids the striped metastable states
        # the paper reports in S5.3 for cold random starts
        sim = Simulation(SimConfig(n=L, m=L, temperature=T, seed=11,
                                   engine="multispin", init_p_up=1.0))
        sim.run(400)
        samples = sim.trajectory(40, 5)
        m = float(np.abs(samples).mean())
        u = float(obs.binder_cumulant(jnp.asarray(samples)))
        row += f"  {m:.3f},{u:+.3f} "
    row += f"   {float(obs.onsager_magnetization(T)):.4f}"
    print(row)
print(f"Tc = {obs.T_CRITICAL}")
