"""Reproduce the paper's validation figures (Fig. 5 magnetization curve,
Fig. 6 Binder cumulant) on small lattices -- batched, from one spec.

The whole temperature scan per lattice size is ONE ensemble-mode
``RunSpec``: every (temperature, seed) member advances inside a single
vmapped, jit-compiled sweep (repro.api.Session dispatching the batched
runner, DESIGN.md S3/S10), instead of one Simulation + one compilation
per temperature.

Run:  PYTHONPATH=src python examples/phase_transition.py
"""
import numpy as np
import jax.numpy as jnp

from repro.api import (BatchSpec, EngineSpec, LatticeSpec, RunSpec,
                       Session, SweepSpec)
from repro.core import observables as obs

temps = [1.5, 1.8, 2.0, 2.1, 2.2, 2.27, 2.35, 2.5, 3.0]
sizes = [32, 48]

results = {}
for L in sizes:
    # ordered start below Tc: avoids the striped metastable states the
    # paper reports in S5.3 for cold random starts
    spec = RunSpec(
        lattice=LatticeSpec(n=L, m=L, init_p_up=1.0),
        engine=EngineSpec("multispin"),
        batch=BatchSpec(temperatures=tuple(temps),
                        seeds=tuple(11 + i for i in range(len(temps)))),
        sweep=SweepSpec(thermalize=400, measure_every=5, n_measure=40,
                        fields=("m",)))
    session = Session.open(spec)
    samples = session.measure()["m"]             # (40, len(temps))
    m = np.abs(samples).mean(axis=0)
    u = [float(obs.binder_cumulant(jnp.asarray(samples[:, i])))
         for i in range(len(temps))]
    results[L] = (m, u)

print("T      " + "".join(f"  L={L}:m,U_L   " for L in sizes) + " onsager")
for t_idx, T in enumerate(temps):
    row = f"{T:5.2f} "
    for L in sizes:
        m, u = results[L]
        row += f"  {m[t_idx]:.3f},{u[t_idx]:+.3f} "
    row += f"   {float(obs.onsager_magnetization(T)):.4f}"
    print(row)
print(f"Tc = {obs.T_CRITICAL}")
