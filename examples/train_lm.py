"""End-to-end LM training example: train a reduced config for a few
hundred steps with checkpoint/restart fault tolerance.

Run:  PYTHONPATH=src python examples/train_lm.py
(~100M-param configurations train identically via
 python -m repro.launch.train --arch internlm2-1.8b --smoke --steps 300)
"""
import sys

from repro.launch.train import main

sys.exit(main(["--arch", "internlm2-1.8b", "--smoke", "--steps", "60",
               "--batch", "8", "--seq", "64", "--lr", "3e-3",
               "--ckpt-dir", "/tmp/repro_train_ck", "--ckpt-every", "25",
               "--log-every", "10"]))
