"""Checkerboard (de)composition and multi-spin packing properties."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import lattice as lat

dims = st.tuples(st.integers(1, 8).map(lambda x: 2 * x),
                 st.integers(1, 8).map(lambda x: 16 * x))


@given(dims=dims, seed=st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_checkerboard_roundtrip(dims, seed):
    n, m = dims
    full = lat.init_lattice(jax.random.PRNGKey(seed), n, m)
    b, w = lat.split_checkerboard(full)
    assert (lat.merge_checkerboard(b, w) == full).all()


def test_checkerboard_coloring_convention():
    full = jnp.arange(4 * 4).reshape(4, 4).astype(jnp.int8)
    b, w = lat.split_checkerboard(full)
    # black[i,k] = full[i, 2k + i%2]  ((i+j) even)
    expect_b = np.array([[0, 2], [5, 7], [8, 10], [13, 15]])
    assert (np.asarray(b) == expect_b).all()


@given(dims=dims, seed=st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_pack_unpack_roundtrip(dims, seed):
    n, m = dims
    plane = (jax.random.uniform(jax.random.PRNGKey(seed), (n, m))
             < 0.5).astype(jnp.uint32)
    assert (lat.unpack_nibbles(lat.pack_nibbles(plane)) == plane).all()


@given(dims=dims, seed=st.integers(0, 2**31 - 1),
       is_black=st.booleans())
@settings(max_examples=25, deadline=None)
def test_packed_sums_equal_unpacked(dims, seed, is_black):
    """Nibble-parallel neighbor sums == per-spin sums (paper S3.3 claim)."""
    n, m = dims
    plane01 = (jax.random.uniform(jax.random.PRNGKey(seed), (n, m))
               < 0.5).astype(jnp.uint32)
    words = lat.pack_nibbles(plane01)
    packed = lat.unpack_nibbles(lat.packed_neighbor_sums(words, is_black))
    up = jnp.roll(plane01, 1, 0)
    down = jnp.roll(plane01, -1, 0)
    side = lat.side_shift(plane01, is_black)
    assert (packed == up + down + plane01 + side).all()


def test_side_shift_parity():
    plane = jnp.arange(4 * 4, dtype=jnp.int32).reshape(4, 4)
    s_b = lat.side_shift(plane, is_black=True)
    # even rows: k-1 (roll +1); odd rows: k+1 (roll -1)
    assert (np.asarray(s_b)[0] == np.roll(np.arange(4), 1)).all()
    assert (np.asarray(s_b)[1] == np.roll(np.arange(4, 8), -1)).all()
    s_w = lat.side_shift(plane, is_black=False)
    assert (np.asarray(s_w)[0] == np.roll(np.arange(4), -1)).all()
