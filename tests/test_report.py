"""benchmarks/report.py: diff logic (Δ%, added/removed, filtered-run
skip, the >25% warn path), the trend timeline + CSV artifact, and the
dryrun render with the rewired per-engine flip-cost model."""
import json
import os
import sys

import pytest

sys.path.insert(0, os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))
from benchmarks import report  # noqa: E402

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _row(name, us, flips=None, median=None, iqr=None, n=5):
    r = {"name": name, "us_per_call": us, "derived": {}}
    if flips is not None:
        r["derived"] = {"flips_per_ns": flips, "engine": "multispin"}
    if median is not None:
        r["n_trials"] = n
        r["median_us_per_call"] = median
        if n >= 2:
            r["iqr_us_per_call"] = 0.1 * median if iqr is None else iqr
    return r


def _record(rows, stamp="20260807_000001", **meta):
    m = {"stamp": stamp, "backend": "cpu", "device_count": 1,
         "only": "", "engines": ""}
    m.update(meta)
    return {"meta": m, "rows": rows}


def _write(tmp_path, name, rec):
    p = tmp_path / name
    p.write_text(json.dumps(rec))
    return str(p)


# ---------------------------------------------------------------------------
# diff
# ---------------------------------------------------------------------------

def test_diff_pct_math_and_rows(tmp_path, capsys):
    old = _write(tmp_path, "old.json", _record([
        _row("a", 100.0, flips=1.0), _row("b", 50.0)]))
    new = _write(tmp_path, "new.json", _record([
        _row("a", 110.0, flips=0.9), _row("b", 50.0)],
        stamp="20260807_000002"))
    out = report.diff(old, new)
    txt = capsys.readouterr().out
    by_name = {r["name"]: r for r in out["rows"]}
    assert by_name["a"]["pct"] == pytest.approx(10.0)
    assert by_name["b"]["pct"] == pytest.approx(0.0)
    assert out["warnings"] == []          # +10% is under the 25% warn
    assert "| a | 100.0 | 110.0 | +10.0% | 1.0 | 0.9 |" in txt


def test_diff_warns_past_threshold(tmp_path, capsys):
    old = _write(tmp_path, "old.json", _record([_row("a", 100.0)]))
    new = _write(tmp_path, "new.json", _record([_row("a", 130.0)]))
    out = report.diff(old, new)
    assert out["warnings"] == ["a"]
    assert "# WARNING: a more than 25% slower" in capsys.readouterr().out
    # custom threshold: +30% under a 40% bar is clean
    assert report.diff(old, new, warn_threshold=0.4)["warnings"] == []


def test_diff_added_and_removed_markers(tmp_path, capsys):
    old = _write(tmp_path, "old.json", _record([_row("gone", 10.0)]))
    new = _write(tmp_path, "new.json", _record([_row("born", 20.0)]))
    out = report.diff(old, new)
    status = {r["name"]: r["status"] for r in out["rows"]}
    assert status == {"gone": "removed", "born": "added"}
    txt = capsys.readouterr().out
    assert "| gone (removed) |" in txt and "| born (added) |" in txt


def test_diff_filtered_run_skips_unselected_baseline_rows(tmp_path,
                                                          capsys):
    old = _write(tmp_path, "old.json", _record([
        _row("a", 10.0), _row("unselected", 99.0)]))
    new = _write(tmp_path, "new.json", _record([_row("a", 10.0)],
                                               only="a"))
    out = report.diff(old, new)
    assert [r["name"] for r in out["rows"]] == ["a"]
    assert "filtered run" in capsys.readouterr().out


def test_diff_uses_median_for_noise_model_rows(tmp_path, capsys):
    # mixed formats: old legacy mean vs new recorded median
    old = _write(tmp_path, "old.json", _record([_row("a", 100.0)]))
    new = _write(tmp_path, "new.json", _record([
        _row("a", 300.0, median=100.0)]))   # mean is an outlier; median flat
    out = report.diff(old, new)
    assert out["rows"][0]["pct"] == pytest.approx(0.0)
    assert out["warnings"] == []
    capsys.readouterr()


# ---------------------------------------------------------------------------
# trend
# ---------------------------------------------------------------------------

def _two_stamps(tmp_path):
    _write(tmp_path, "BENCH_20260101_000000.json", _record([
        _row("t1_x", 100.0, flips=1.0, median=100.0),
        _row("untimed", 0.0)],                      # no metric: excluded
        stamp="20260101_000000"))
    # written out of stamp order on purpose -- trend must sort by stamp
    _write(tmp_path, "BENCH_20260301_000000.json", _record([
        _row("t1_x", 50.0, flips=2.0, median=50.0)],
        stamp="20260301_000000"))
    return str(tmp_path)


def test_trend_timeline_and_delta(tmp_path, capsys):
    out = report.trend(paths=(_two_stamps(tmp_path),))
    txt = capsys.readouterr().out
    assert out["stamps"] == ["20260101_000000", "20260301_000000"]
    assert out["series"]["t1_x"] == {"20260101_000000": 1.0,
                                     "20260301_000000": 2.0}
    assert "untimed" not in out["series"]
    assert "| multispin | t1_x | 1.0000 | 2.0000 | +100.0% |" in txt


def test_trend_writes_csv_artifact(tmp_path, capsys):
    d = _two_stamps(tmp_path)
    csv_path = str(tmp_path / "artifact" / "trend.csv")
    report.trend(paths=(d,), csv_path=csv_path)
    capsys.readouterr()
    lines = open(csv_path).read().strip().split("\n")
    assert lines[0].startswith("stamp,backend,name,engine,metric,")
    assert len(lines) == 3                 # header + 2 timed points
    assert lines[1].split(",")[:5] == [
        "20260101_000000", "cpu", "t1_x", "multispin", "flips_per_ns"]


def test_trend_dedupes_repeated_paths(tmp_path, capsys):
    d = _two_stamps(tmp_path)
    out = report.trend(paths=(d, d))
    capsys.readouterr()
    assert len(out["stamps"]) == 2


def test_trend_single_record_prints_hint(tmp_path, capsys):
    _write(tmp_path, "BENCH_20260101_000000.json",
           _record([_row("t1_x", 100.0, flips=1.0)],
                   stamp="20260101_000000"))
    report.trend(paths=(str(tmp_path),))
    assert "commit or generate more" in capsys.readouterr().out


def test_trend_over_committed_history(capsys):
    """The acceptance criterion: `report trend` renders a timeline over
    the >= 2 committed BENCH records."""
    out = report.trend(paths=(os.path.join(REPO, "benchmarks"),))
    txt = capsys.readouterr().out
    assert len(out["stamps"]) >= 2
    assert out["series"], "no throughput series in committed history"
    assert "### Bench trend" in txt


def test_cli_trend_spelling(tmp_path, capsys):
    d = _two_stamps(tmp_path)
    assert report.cli(["trend", d]) == 0
    assert "Bench trend" in capsys.readouterr().out
    assert report.cli(["--trend", d]) == 0
    assert "Bench trend" in capsys.readouterr().out


def test_cli_diff_spelling(tmp_path, capsys):
    old = _write(tmp_path, "old.json", _record([_row("a", 10.0)]))
    new = _write(tmp_path, "new.json", _record([_row("a", 11.0)]))
    assert report.cli(["diff", old, new]) == 0
    assert "Bench diff" in capsys.readouterr().out


# ---------------------------------------------------------------------------
# dryrun render: the rewired ising flip-cost model
# ---------------------------------------------------------------------------

def test_model_flops_ratio_uses_engine_flip_cost():
    from repro.launch.roofline import flip_cost
    spins = 1 << 20
    flops = 5.0e7
    r = {"arch": "ising-multispin", "shape": "x", "mesh": "1x1",
         "chips": 1, "spins": spins, "flops": flops}
    expect = (flip_cost("multispin").flops_per_flip * spins) / flops
    assert report._model_flops_ratio(r) == pytest.approx(expect)
    # bitplane carries 32 replicas per word -> 32x the useful work
    r["arch"] = "ising-bitplane"
    got = report._model_flops_ratio(r)
    assert got == pytest.approx(
        flip_cost("bitplane").flops_per_flip * 32 * spins / flops)


def test_main_renders_ising_cell(tmp_path, capsys):
    cells = [{"arch": "ising-multispin", "shape": "n4096", "mesh": "1x1",
              "status": "ok", "chips": 1, "spins": 4096 * 4096,
              "compile_s": 1.0, "flops": 1e9, "bytes": 1e8,
              "coll_bytes": 0, "memory": {"temp_size_in_bytes": 0},
              "t_compute_s": 0.1, "t_memory_s": 0.2,
              "t_collective_s": 0.0, "dominant": "memory"},
             {"arch": "ising-multispin", "shape": "n8192", "mesh": "1x1",
              "status": "skipped", "skip_reason": "too big"}]
    path = tmp_path / "dryrun.json"
    path.write_text(json.dumps(cells))
    report.main(str(path))
    txt = capsys.readouterr().out
    assert "### Dry-run status" in txt and "### Roofline terms" in txt
    assert "SKIP: too big" in txt
    assert "**memory**" in txt
    # MODEL/HLO column rendered as a number, not the "-" fallback
    from repro.launch.roofline import flip_cost
    expect = flip_cost("multispin").flops_per_flip * 4096 * 4096 / 1e9
    assert f"{expect:.3f}" in txt
