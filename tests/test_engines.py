"""Ising engine correctness: cross-engine agreement + physics validation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import lattice as lat
from repro.core import metropolis as metro
from repro.core import multispin as ms
from repro.core import observables as obs
from repro.core import tensorcore as tc
from repro.core.engine import ENGINES, make_engine
from repro.core.sim import SimConfig, Simulation

ALL_ENGINES = sorted(ENGINES)


def _direct_nn(full, i, j):
    n, m = full.shape
    return (full[(i - 1) % n, j] + full[(i + 1) % n, j]
            + full[i, (j - 1) % m] + full[i, (j + 1) % m])


@pytest.mark.parametrize("n,m", [(8, 8), (16, 32), (12, 24)])
def test_neighbor_sums_basic_vs_direct(n, m):
    full = lat.init_lattice(jax.random.PRNGKey(0), n, m)
    b, w = lat.split_checkerboard(full)
    nn_b = np.asarray(metro.neighbor_sums(w, is_black=True))
    fn = np.asarray(full, np.int32)
    for i in range(n):
        for k in range(m // 2):
            j = 2 * k + i % 2
            assert nn_b[i, k] == _direct_nn(fn, i, j), (i, k)


def test_packed_sums_match_basic():
    full = lat.init_lattice(jax.random.PRNGKey(1), 16, 32)
    b, w = lat.split_checkerboard(full)
    bw, ww = ms.pack_lattice(b, w)
    nn_basic = metro.neighbor_sums(w, is_black=True)      # in +-1 units
    nn_pack = lat.unpack_nibbles(lat.packed_neighbor_sums(ww, True))
    assert (nn_basic == 2 * nn_pack.astype(jnp.int32) - 4).all()


def test_tensorcore_sums_exact():
    full = lat.init_lattice(jax.random.PRNGKey(2), 16, 16)
    nn = tc.neighbor_sums_tc(tc.decompose(full), block=4)
    fn = np.asarray(full, np.int32)
    for a in range(8):
        for b in range(8):
            assert int(nn["00"][a, b]) == _direct_nn(fn, 2 * a, 2 * b)
            assert int(nn["11"][a, b]) == _direct_nn(fn, 2 * a + 1,
                                                     2 * b + 1)


def test_kernel_matrix_banded():
    k = np.asarray(tc.make_kernel_matrix(8), np.float32)
    assert (np.diag(k) == 1).all() and (np.diag(k, 1) == 1).all()
    assert k.sum() == 8 + 7


def test_acceptance_table_values():
    beta = 0.5
    table = np.asarray(ms.acceptance_table(jnp.float32(beta)))
    for s in range(2):
        for nn in range(5):
            expect = np.exp(-2 * beta * (2 * s - 1) * (2 * nn - 4))
            np.testing.assert_allclose(table[s * 5 + nn], expect,
                                       rtol=1e-5)


@pytest.mark.parametrize("engine", ["basic", "basic_philox", "multispin",
                                    "tensorcore", "stencil_pallas",
                                    "bitplane"])
def test_low_temperature_orders(engine):
    """T=1.5 < Tc: |m| must stay at Onsager's 0.9865 on every engine.

    Ordered start per the paper's S5.3 guidance: cold random starts can
    fall into long-lived striped metastable states (the basic engine
    does exactly that with seed 3), which tests metastability, not the
    engine's accept dynamics."""
    sim = Simulation(SimConfig(n=64, m=64, temperature=1.5, seed=3,
                               engine=engine, tc_block=8, init_p_up=1.0))
    sim.run(300)
    m = abs(sim.magnetization())
    assert m > 0.93, (engine, m)


@pytest.mark.parametrize("engine", ["basic_philox", "multispin",
                                    "bitplane"])
def test_high_temperature_disorders(engine):
    """T=5 >> Tc: |m| ~ 0."""
    sim = Simulation(SimConfig(n=64, m=64, temperature=5.0, seed=4,
                               engine=engine))
    sim.run(200)
    assert abs(sim.magnetization()) < 0.1


def test_energy_ground_state():
    """All-up lattice: E/spin = -2 (each spin has 4 aligned bonds / 2)."""
    full = jnp.ones((16, 16), jnp.int8)
    b, w = lat.split_checkerboard(full)
    assert float(obs.energy_per_spin(b, w)) == -2.0


def test_onsager_curve():
    assert float(obs.onsager_magnetization(1.5)) == pytest.approx(0.9865,
                                                                  abs=1e-3)
    assert float(obs.onsager_magnetization(3.0)) == 0.0
    assert float(obs.onsager_magnetization(obs.T_CRITICAL + 1e-4)) == 0.0


def test_binder_limits():
    m_const = jnp.ones(100) * 0.8
    assert float(obs.binder_cumulant(m_const)) == pytest.approx(2.0 / 3.0)


# -- registry-driven cross-engine contracts ---------------------------------

def test_registry_contains_all_engines():
    assert set(ALL_ENGINES) >= {"basic", "basic_philox", "multispin",
                                "tensorcore", "stencil_pallas", "wolff",
                                "spinglass", "bitplane", "bitplane_pallas"}


def test_unknown_engine_rejected():
    with pytest.raises(ValueError, match="unknown engine"):
        make_engine(SimConfig(engine="nope"))


@pytest.mark.parametrize("engine", ALL_ENGINES)
def test_full_lattice_agrees_at_init(engine):
    """After 0 sweeps from a shared seed every engine holds the same
    lattice: the engine-native state layouts are pure re-encodings."""
    cfg = dict(n=16, m=16, temperature=2.0, seed=5, tc_block=4)
    ref = Simulation(SimConfig(engine="basic", **cfg))
    sim = Simulation(SimConfig(engine=engine, **cfg))
    np.testing.assert_array_equal(np.asarray(ref.full_lattice()),
                                  np.asarray(sim.full_lattice()))


@pytest.mark.parametrize("engine", ALL_ENGINES)
def test_registry_checkpoint_roundtrip_bitexact(engine, tmp_path):
    """save -> restore reproduces config, step count, and state bits."""
    sim = Simulation(SimConfig(n=16, m=16, temperature=2.1, seed=9,
                               engine=engine, tc_block=4))
    sim.run(2)
    path = str(tmp_path / f"{engine}.npz")
    sim.save(path)
    back = Simulation.restore(path)
    assert back.config == sim.config
    assert back.step_count == sim.step_count
    np.testing.assert_array_equal(np.asarray(sim.full_lattice()),
                                  np.asarray(back.full_lattice()))
    for k, v in sim.engine.state_arrays(sim.state).items():
        np.testing.assert_array_equal(
            v, back.engine.state_arrays(back.state)[k], err_msg=k)
    # restored sims keep running (engine-native state restored intact)
    back.run(1)


def test_counter_engines_match_legacy_wrappers():
    """The registry sweep path and the standalone run_sweeps_* wrappers
    share one Philox offset scheme (same stream, same checkpoints)."""
    from repro.core import bitplane as bp
    full = lat.init_lattice(jax.random.PRNGKey(4), 16, 32)
    b, w = lat.split_checkerboard(full)
    packed = ms.pack_lattice(b, w)  # before the donating wrapper calls
    beta = jnp.float32(1 / 2.1)
    cfg = SimConfig(n=16, m=32, temperature=2.1, seed=3)

    eng = ENGINES["basic_philox"](cfg)
    be, we = eng.sweep_fn((b, w), beta, 3, 0, 4)
    bw_ref, ww_ref = metro.run_sweeps_philox(b, w, beta, 4, seed=3)
    np.testing.assert_array_equal(np.asarray(be), np.asarray(bw_ref))
    np.testing.assert_array_equal(np.asarray(we), np.asarray(ww_ref))

    eng = ENGINES["multispin"](cfg)
    be, we = eng.sweep_fn(packed, beta, 3, 0, 4)
    bp_ref, wp_ref = ms.run_sweeps_packed(*packed, beta, 4, seed=3)
    np.testing.assert_array_equal(np.asarray(be), np.asarray(bp_ref))
    np.testing.assert_array_equal(np.asarray(we), np.asarray(wp_ref))

    eng = ENGINES["bitplane"](cfg)
    state = eng.init_state(jax.random.PRNGKey(3))
    be, we = eng.sweep_fn(state, beta, 3, 0, 4)
    bb_ref, wb_ref = bp.run_sweeps_bitplane(*state, beta, 4, seed=3)
    np.testing.assert_array_equal(np.asarray(be), np.asarray(bb_ref))
    np.testing.assert_array_equal(np.asarray(we), np.asarray(wb_ref))


@pytest.mark.parametrize("engine", ALL_ENGINES)
def test_donated_sweep_rebinding(engine):
    """H1.8: the jitted ``sweeps`` paths donate their state buffers, so
    the public contract is rebinding (``state = engine.sweeps(state,
    ...)``).  Two consecutive rebinding calls must hit no stale-buffer
    error (the second call reuses the cached executable with a fresh
    donated buffer) and must equal the same chunking through the pure,
    non-donating ``scan_step`` evaluated eagerly."""
    cfg = SimConfig(n=16, m=16, temperature=2.1, seed=6, engine=engine,
                    tc_block=4)
    eng = make_engine(cfg)
    state = eng.init_state(jax.random.PRNGKey(cfg.seed))
    state = eng.sweeps(state, 2, 0)
    state = eng.sweeps(state, 2, 2)  # cached executable, donated again

    ref_eng = make_engine(cfg)
    ref_state = ref_eng.init_state(jax.random.PRNGKey(cfg.seed))
    beta = jnp.float32(cfg.inv_temp)
    ref_state = ref_eng.scan_step(ref_state, beta, cfg.seed, 0, 2)
    ref_state = ref_eng.scan_step(ref_state, beta, cfg.seed, 2, 2)
    np.testing.assert_array_equal(
        np.asarray(eng.full_lattice(state)),
        np.asarray(ref_eng.full_lattice(ref_state)))


def test_restore_rejects_pre_registry_checkpoint(tmp_path):
    path = str(tmp_path / "legacy.npz")
    np.savez(path, step_count=10, engine="multispin", n=16, m=16,
             temperature=2.0, seed=1, s0=np.zeros((16, 1), np.uint32),
             s1=np.zeros((16, 1), np.uint32))
    with pytest.raises(ValueError, match="pre-registry"):
        Simulation.restore(path)


def test_stencil_engine_matches_basic_philox():
    """The Pallas stencil engine is bit-for-bit its pure-jnp oracle."""
    cfg = dict(n=32, m=32, temperature=2.2, seed=7)
    a = Simulation(SimConfig(engine="basic_philox", **cfg))
    b = Simulation(SimConfig(engine="stencil_pallas", **cfg))
    a.run(5)
    b.run(5)
    np.testing.assert_array_equal(np.asarray(a.full_lattice()),
                                  np.asarray(b.full_lattice()))


def test_spinglass_couplings_are_quenched_and_checkpointed(tmp_path):
    sim = Simulation(SimConfig(n=16, m=16, temperature=1.0, seed=3,
                               engine="spinglass"))
    _, j_up, j_left = sim.state
    sim.run(3)
    assert (np.asarray(sim.state[1]) == np.asarray(j_up)).all()
    path = str(tmp_path / "sg.npz")
    sim.save(path)
    back = Simulation.restore(path)
    np.testing.assert_array_equal(np.asarray(back.state[1]),
                                  np.asarray(j_up))
    np.testing.assert_array_equal(np.asarray(back.state[2]),
                                  np.asarray(j_left))


def test_wolff_engine_flips_clusters():
    sim = Simulation(SimConfig(n=16, m=16, temperature=2.0, seed=8,
                               engine="wolff"))
    before = np.asarray(sim.full_lattice())
    sim.run(5)
    after = np.asarray(sim.full_lattice())
    assert (before != after).any()
    assert sim.step_count == 5


def test_checkpoint_restart_bitexact(tmp_path):
    """Philox skip-ahead: save at 10 sweeps + 10 more == straight 20."""
    for engine in ("basic_philox", "multispin", "stencil_pallas"):
        a = Simulation(SimConfig(n=32, m=32, temperature=2.2, seed=7,
                                 engine=engine))
        a.run(10)
        p = str(tmp_path / f"{engine}.npz")
        a.save(p)
        a.run(10)
        b = Simulation.restore(p)
        b.run(10)
        assert (np.asarray(a.full_lattice())
                == np.asarray(b.full_lattice())).all(), engine
