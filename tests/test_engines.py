"""Ising engine correctness: cross-engine agreement + physics validation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import lattice as lat
from repro.core import metropolis as metro
from repro.core import multispin as ms
from repro.core import observables as obs
from repro.core import tensorcore as tc
from repro.core.sim import SimConfig, Simulation


def _direct_nn(full, i, j):
    n, m = full.shape
    return (full[(i - 1) % n, j] + full[(i + 1) % n, j]
            + full[i, (j - 1) % m] + full[i, (j + 1) % m])


@pytest.mark.parametrize("n,m", [(8, 8), (16, 32), (12, 24)])
def test_neighbor_sums_basic_vs_direct(n, m):
    full = lat.init_lattice(jax.random.PRNGKey(0), n, m)
    b, w = lat.split_checkerboard(full)
    nn_b = np.asarray(metro.neighbor_sums(w, is_black=True))
    fn = np.asarray(full, np.int32)
    for i in range(n):
        for k in range(m // 2):
            j = 2 * k + i % 2
            assert nn_b[i, k] == _direct_nn(fn, i, j), (i, k)


def test_packed_sums_match_basic():
    full = lat.init_lattice(jax.random.PRNGKey(1), 16, 32)
    b, w = lat.split_checkerboard(full)
    bw, ww = ms.pack_lattice(b, w)
    nn_basic = metro.neighbor_sums(w, is_black=True)      # in +-1 units
    nn_pack = lat.unpack_nibbles(lat.packed_neighbor_sums(ww, True))
    assert (nn_basic == 2 * nn_pack.astype(jnp.int32) - 4).all()


def test_tensorcore_sums_exact():
    full = lat.init_lattice(jax.random.PRNGKey(2), 16, 16)
    nn = tc.neighbor_sums_tc(tc.decompose(full), block=4)
    fn = np.asarray(full, np.int32)
    for a in range(8):
        for b in range(8):
            assert int(nn["00"][a, b]) == _direct_nn(fn, 2 * a, 2 * b)
            assert int(nn["11"][a, b]) == _direct_nn(fn, 2 * a + 1,
                                                     2 * b + 1)


def test_kernel_matrix_banded():
    k = np.asarray(tc.make_kernel_matrix(8), np.float32)
    assert (np.diag(k) == 1).all() and (np.diag(k, 1) == 1).all()
    assert k.sum() == 8 + 7


def test_acceptance_table_values():
    beta = 0.5
    table = np.asarray(ms.acceptance_table(jnp.float32(beta)))
    for s in range(2):
        for nn in range(5):
            expect = np.exp(-2 * beta * (2 * s - 1) * (2 * nn - 4))
            np.testing.assert_allclose(table[s * 5 + nn], expect,
                                       rtol=1e-5)


@pytest.mark.parametrize("engine", ["basic", "basic_philox", "multispin",
                                    "tensorcore"])
def test_low_temperature_orders(engine):
    """T=1.5 < Tc: |m| must approach Onsager's 0.9865 on every engine."""
    sim = Simulation(SimConfig(n=64, m=64, temperature=1.5, seed=3,
                               engine=engine, tc_block=8))
    sim.run(300)
    m = abs(sim.magnetization())
    assert m > 0.93, (engine, m)


@pytest.mark.parametrize("engine", ["basic_philox", "multispin"])
def test_high_temperature_disorders(engine):
    """T=5 >> Tc: |m| ~ 0."""
    sim = Simulation(SimConfig(n=64, m=64, temperature=5.0, seed=4,
                               engine=engine))
    sim.run(200)
    assert abs(sim.magnetization()) < 0.1


def test_energy_ground_state():
    """All-up lattice: E/spin = -2 (each spin has 4 aligned bonds / 2)."""
    full = jnp.ones((16, 16), jnp.int8)
    b, w = lat.split_checkerboard(full)
    assert float(obs.energy_per_spin(b, w)) == -2.0


def test_onsager_curve():
    assert float(obs.onsager_magnetization(1.5)) == pytest.approx(0.9865,
                                                                  abs=1e-3)
    assert float(obs.onsager_magnetization(3.0)) == 0.0
    assert float(obs.onsager_magnetization(obs.T_CRITICAL + 1e-4)) == 0.0


def test_binder_limits():
    m_const = jnp.ones(100) * 0.8
    assert float(obs.binder_cumulant(m_const)) == pytest.approx(2.0 / 3.0)


def test_checkpoint_restart_bitexact(tmp_path):
    """Philox skip-ahead: save at 10 sweeps + 10 more == straight 20."""
    for engine in ("basic_philox", "multispin"):
        a = Simulation(SimConfig(n=32, m=32, temperature=2.2, seed=7,
                                 engine=engine))
        a.run(10)
        p = str(tmp_path / f"{engine}.npz")
        a.save(p)
        a.run(10)
        b = Simulation.restore(p)
        b.run(10)
        assert (np.asarray(a.full_lattice())
                == np.asarray(b.full_lattice())).all(), engine
