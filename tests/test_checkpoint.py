"""Checkpointer: atomicity, GC, async writes, elastic reshard."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import Checkpointer, CheckpointError
from repro.resilience import integrity


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (8, 4)),
            "nested": {"b": jnp.arange(6).reshape(2, 3),
                       "c": [jnp.ones(3), jnp.zeros(2)]}}


def test_save_restore_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    t = _tree()
    ck.save(3, t)
    step, r = ck.restore(t)
    assert step == 3
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_partial_checkpoint_ignored(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _tree())
    # simulate a crash mid-write: step dir without DONE marker
    bad = tmp_path / "step_0000000002"
    bad.mkdir()
    (bad / "arrays.npz").write_bytes(b"garbage")
    assert ck.latest_step() == 1  # the torn write is invisible


def test_gc_keeps_latest(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in range(5):
        ck.save(s, _tree(s))
    assert ck.all_steps() == [3, 4]


def test_async_save(tmp_path):
    ck = Checkpointer(str(tmp_path))
    t = _tree()
    ck.save_async(7, t)
    ck.wait()
    step, r = ck.restore(t)
    assert step == 7


def test_manifest_committed_under_done(tmp_path):
    """Every save writes a CRC32C manifest BEFORE the DONE marker, so
    the atomic rename commits payload and checksums together."""
    ck = Checkpointer(str(tmp_path))
    ck.save(4, _tree())
    step_dir = tmp_path / "step_0000000004"
    manifest = integrity.load_manifest(str(step_dir))
    assert manifest["step"] == 4
    assert "arrays.npz" in manifest["files"]
    assert set(manifest["arrays"]) == {"a", "nested/b", "nested/c/0",
                                       "nested/c/1"}
    assert ck.validate_step(4) == []


def test_async_writer_error_rethrown(tmp_path, monkeypatch):
    """A save_async worker failure must surface on the NEXT call into
    the checkpointer (store-and-rethrow), not vanish with the daemon
    thread."""
    ck = Checkpointer(str(tmp_path))

    def boom(*a, **k):
        raise OSError("disk full (injected)")

    monkeypatch.setattr(np, "savez", boom)
    ck.save_async(1, _tree())
    with pytest.raises(CheckpointError, match="disk full"):
        ck.wait()
    monkeypatch.undo()
    # the error is consumed: the checkpointer keeps working after
    ck.save(2, _tree())
    assert ck.latest_step() == 2
    ck.close()


def test_restore_shape_mismatch_typed_error(tmp_path):
    """Load-path guards are typed errors naming key and shapes, not
    bare asserts (which vanish under python -O)."""
    ck = Checkpointer(str(tmp_path))
    ck.save(1, {"a": np.zeros((4, 4))})
    with pytest.raises(CheckpointError) as ei:
        ck.restore({"a": np.zeros((8, 2))})
    assert "'a'" in str(ei.value)
    assert "(4, 4)" in str(ei.value) and "(8, 2)" in str(ei.value)
    with pytest.raises(CheckpointError, match="missing array"):
        ck.restore({"other": np.zeros((4, 4))})


def test_missing_checkpoint_typed_error(tmp_path):
    ck = Checkpointer(str(tmp_path))
    with pytest.raises(CheckpointError, match="no checkpoint"):
        ck.restore({"a": np.zeros(2)})
    with pytest.raises(CheckpointError, match="no valid checkpoint"):
        ck.read_spec()


def test_restore_falls_back_past_corrupt_step(tmp_path):
    """Byte corruption under a valid DONE marker: restore verifies the
    CRC manifest, quarantines the bad step, and restores the previous
    good one."""
    ck = Checkpointer(str(tmp_path), keep=0)
    t = _tree()
    ck.save(1, t)
    ck.save(2, t)
    from repro.resilience import faults
    faults.flip_byte(str(tmp_path), 2)
    step, r = ck.restore(t)
    assert step == 1
    assert (tmp_path / "quarantine_step_0000000002").exists()


def test_elastic_reshard(tmp_path):
    """A checkpoint restores under a different sharding (device_put)."""
    ck = Checkpointer(str(tmp_path))
    t = _tree()
    ck.save(1, t)
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1,), ("data",))
    sh = jax.tree.map(
        lambda _: jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec()), t)
    step, r = ck.restore(t, shardings=sh)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_elastic_reshard_across_device_counts():
    """A checkpoint written under an 8-device mesh restores onto a
    4-device mesh (subprocess: save sharded, restore resharded)."""
    import subprocess
    import sys
    import textwrap
    script = textwrap.dedent("""
        import os, tempfile
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.ckpt import Checkpointer

        d = tempfile.mkdtemp()
        from repro.launch.mesh import make_mesh
        mesh8 = make_mesh((8,), ("data",))
        t = {"w": jax.device_put(jnp.arange(64.0).reshape(8, 8),
                                 NamedSharding(mesh8, P("data", None)))}
        ck = Checkpointer(d)
        ck.save(1, t)

        mesh4 = make_mesh((4, 2), ("data", "model"))
        sh = {"w": NamedSharding(mesh4, P("model", "data"))}
        step, r = ck.restore(t, shardings=sh)
        assert step == 1
        np.testing.assert_array_equal(np.asarray(r["w"]),
                                      np.arange(64.0).reshape(8, 8))
        assert r["w"].sharding == sh["w"]
        print("OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "OK" in proc.stdout
