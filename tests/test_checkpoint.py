"""Checkpointer: atomicity, GC, async writes, elastic reshard."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import Checkpointer


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (8, 4)),
            "nested": {"b": jnp.arange(6).reshape(2, 3),
                       "c": [jnp.ones(3), jnp.zeros(2)]}}


def test_save_restore_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    t = _tree()
    ck.save(3, t)
    step, r = ck.restore(t)
    assert step == 3
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_partial_checkpoint_ignored(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _tree())
    # simulate a crash mid-write: step dir without DONE marker
    bad = tmp_path / "step_0000000002"
    bad.mkdir()
    (bad / "arrays.npz").write_bytes(b"garbage")
    assert ck.latest_step() == 1  # the torn write is invisible


def test_gc_keeps_latest(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in range(5):
        ck.save(s, _tree(s))
    assert ck.all_steps() == [3, 4]


def test_async_save(tmp_path):
    ck = Checkpointer(str(tmp_path))
    t = _tree()
    ck.save_async(7, t)
    ck.wait()
    step, r = ck.restore(t)
    assert step == 7


def test_elastic_reshard(tmp_path):
    """A checkpoint restores under a different sharding (device_put)."""
    ck = Checkpointer(str(tmp_path))
    t = _tree()
    ck.save(1, t)
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1,), ("data",))
    sh = jax.tree.map(
        lambda _: jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec()), t)
    step, r = ck.restore(t, shardings=sh)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_elastic_reshard_across_device_counts():
    """A checkpoint written under an 8-device mesh restores onto a
    4-device mesh (subprocess: save sharded, restore resharded)."""
    import subprocess
    import sys
    import textwrap
    script = textwrap.dedent("""
        import os, tempfile
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.ckpt import Checkpointer

        d = tempfile.mkdtemp()
        from repro.launch.mesh import make_mesh
        mesh8 = make_mesh((8,), ("data",))
        t = {"w": jax.device_put(jnp.arange(64.0).reshape(8, 8),
                                 NamedSharding(mesh8, P("data", None)))}
        ck = Checkpointer(d)
        ck.save(1, t)

        mesh4 = make_mesh((4, 2), ("data", "model"))
        sh = {"w": NamedSharding(mesh4, P("model", "data"))}
        step, r = ck.restore(t, shardings=sh)
        assert step == 1
        np.testing.assert_array_equal(np.asarray(r["w"]),
                                      np.arange(64.0).reshape(8, 8))
        assert r["w"].sharding == sh["w"]
        print("OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "OK" in proc.stdout
