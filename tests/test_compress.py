"""int8 error-feedback gradient compression: bounds + convergence."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.train import compress


@given(seed=st.integers(0, 2**31 - 1), scale=st.floats(1e-3, 1e3))
@settings(max_examples=50, deadline=None)
def test_quantize_error_bound(seed, scale):
    x = jax.random.normal(jax.random.PRNGKey(seed), (64,)) * scale
    q, s = compress.quantize(x)
    err = np.abs(np.asarray(compress.dequantize(q, s) - x))
    assert err.max() <= float(s) / 2 + 1e-6


def test_error_feedback_accumulates_residual():
    g = jnp.array([1.0, 1e-4, -1e-4, 0.5])
    err = jnp.zeros(4)
    q, s, new_err = compress.compress_leaf(g, err)
    # residual == what dequantization lost
    np.testing.assert_allclose(
        np.asarray(new_err),
        np.asarray(g - compress.dequantize(q, s)), atol=1e-7)


def test_compressed_sgd_converges_like_exact():
    """Least squares via GD: int8+error-feedback reaches the same loss."""
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (32, 8))
    x_true = jax.random.normal(jax.random.fold_in(key, 1), (8,))
    y = a @ x_true

    def loss(x):
        return 0.5 * jnp.mean((a @ x - y) ** 2)

    gfn = jax.grad(loss)

    def run(compressed: bool, steps=300, lr=0.1):
        x = jnp.zeros(8)
        err = jnp.zeros(8)
        for _ in range(steps):
            g = gfn(x)
            if compressed:
                q, s, err = compress.compress_leaf(g, err)
                g = compress.dequantize(q, s)
            x = x - lr * g
        return float(loss(x))

    exact = run(False)
    comp = run(True)
    assert comp < 1e-4, comp
    assert comp < max(exact * 50, 1e-5)
