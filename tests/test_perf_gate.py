"""repro.perf.gate: the statistical perf gate (EXPERIMENTS.md
S Perf-gate) -- noise-band classification, legacy fallback, filtered
runs, budgets round-trip, CLI exit codes, and the property suite
(tolerance monotonicity, band symmetry)."""
import json
import os
import sys

import pytest

from repro.perf.gate import (GateConfig, classify, dump_budgets, gate,
                             load_budgets, main, make_budgets,
                             row_stats, throughput, tolerance)

sys.path.insert(0, os.path.dirname(__file__))
from _hypothesis_compat import given, settings, st  # noqa: E402

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# fixtures: synthetic BENCH records in both formats
# ---------------------------------------------------------------------------

def _row(name, median, iqr=None, n=5, flips=None, legacy=False):
    derived = {} if flips is None else {"flips_per_ns": flips}
    if legacy:
        return {"name": name, "us_per_call": median, "derived": derived}
    row = {"name": name, "us_per_call": median, "derived": derived,
           "n_trials": n, "median_us_per_call": median}
    if n >= 2:
        row["iqr_us_per_call"] = median * 0.02 if iqr is None else iqr
    return row


def _record(rows, **meta):
    m = {"stamp": "20260807_000000", "backend": "cpu",
         "device_count": 1, "only": "", "engines": ""}
    m.update(meta)
    return {"meta": m, "rows": rows}


def _base():
    return _record([
        _row("t1_a", 100.0, iqr=2.0, flips=10.0),
        _row("t1_b", 50.0, iqr=1.0, flips=4.0),
        _row("t1_legacy", 200.0, legacy=True, flips=1.0),
    ])


# ---------------------------------------------------------------------------
# gate(): classification against the noise band
# ---------------------------------------------------------------------------

def test_identical_records_pass():
    res = gate(_base(), _base())
    assert not res.failed
    assert {r.status for r in res.rows} == {"ok"}


def test_injected_regression_fails():
    # the acceptance-criteria scenario: one row degraded 2x must fail
    cand = _base()
    cand["rows"][0]["us_per_call"] *= 2.0
    cand["rows"][0]["median_us_per_call"] *= 2.0
    res = gate(_base(), cand)
    assert res.failed
    (bad,) = res.by_status("regression")
    assert bad.name == "t1_a"
    assert bad.ratio == pytest.approx(2.0)
    assert bad.fails
    assert "noise band" in bad.detail


def test_within_noise_band_is_ok():
    # IQR 2/100 -> tol = clamp(4*0.02, 0.10, 0.75) = 0.10; +8% is noise
    cand = _base()
    cand["rows"][0]["median_us_per_call"] = 108.0
    res = gate(_base(), cand)
    assert not res.failed
    assert res.rows[0].status == "ok"
    assert res.rows[0].tol == pytest.approx(0.10)


def test_improvement_flagged_but_not_failing():
    cand = _base()
    cand["rows"][0]["median_us_per_call"] = 50.0   # 2x faster
    res = gate(_base(), cand)
    assert not res.failed
    (imp,) = res.by_status("improvement")
    assert imp.name == "t1_a" and not imp.fails
    # the report nudges toward a baseline refresh
    assert "refresh the baseline" in res.to_markdown()


def test_legacy_row_falls_back_to_flat_25pct():
    # +20% on a spread-less baseline row passes, +30% fails
    for pct, ok in ((1.20, True), (1.30, False)):
        cand = _base()
        cand["rows"][2]["us_per_call"] = 200.0 * pct
        res = gate(_base(), cand)
        verdict = [r for r in res.rows if r.name == "t1_legacy"][0]
        assert verdict.tol == pytest.approx(0.25)
        assert (verdict.status == "ok") is ok


def test_missing_row_fails_unfiltered_run():
    cand = _base()
    cand["rows"] = cand["rows"][1:]       # t1_a silently dropped
    res = gate(_base(), cand)
    assert res.failed
    (miss,) = res.by_status("missing")
    assert miss.name == "t1_a"


def test_missing_row_skipped_for_filtered_run():
    cand = _base()
    cand["rows"] = cand["rows"][:1]
    cand["meta"]["only"] = "t1_a"
    res = gate(_base(), cand)
    assert res.filtered and not res.failed
    assert [r.name for r in res.rows] == ["t1_a"]


def test_spec_file_meta_counts_as_filtered():
    cand = _base()
    cand["rows"] = cand["rows"][:1]
    cand["meta"]["spec_file"] = "spec.json"
    assert not gate(_base(), cand).failed


def test_new_row_is_advisory():
    cand = _base()
    cand["rows"].append(_row("t1_new_engine", 10.0, flips=99.0))
    res = gate(_base(), cand)
    assert not res.failed
    (new,) = res.by_status("new")
    assert new.name == "t1_new_engine" and not new.fails


def test_untimed_row_is_ok():
    base, cand = _base(), _base()
    base["rows"].append({"name": "untimed", "us_per_call": 0.0,
                         "derived": {}})
    cand["rows"].append({"name": "untimed", "us_per_call": 0.0,
                         "derived": {}})
    res = gate(base, cand)
    assert not res.failed


# ---------------------------------------------------------------------------
# budgets: absolute flips/ns floors + round-trip
# ---------------------------------------------------------------------------

def test_budget_floor_violation_fails():
    budgets = make_budgets(_base(), safety=0.4)
    assert budgets["rows"]["t1_a"]["min_flips_per_ns"] == pytest.approx(
        4.0)
    cand = _base()
    cand["rows"][0]["derived"]["flips_per_ns"] = 3.0   # below 0.4 * 10
    # keep the timing in-band so only the budget trips
    res = gate(_base(), cand, budgets=budgets)
    assert res.failed
    (bud,) = res.by_status("budget")
    assert bud.name == "t1_a" and "below budget floor" in bud.detail


def test_budget_row_without_metric_fails():
    budgets = {"rows": {"t1_a": {"min_flips_per_ns": 1.0}}}
    cand = _base()
    del cand["rows"][0]["derived"]["flips_per_ns"]
    res = gate(_base(), cand, budgets=budgets)
    assert res.by_status("budget")


def test_budgets_dump_load_round_trip(tmp_path):
    budgets = make_budgets(_base(), safety=0.5)
    path = dump_budgets(budgets, str(tmp_path / "budgets.json"))
    assert load_budgets(path) == budgets


def test_load_budgets_rejects_unknown_keys(tmp_path):
    path = tmp_path / "bad.json"
    path.write_text(json.dumps({"rows": {}, "typo": 1}))
    with pytest.raises(ValueError, match="unknown keys"):
        load_budgets(str(path))


def test_gate_config_from_dict_rejects_unknown_keys():
    with pytest.raises(ValueError, match="unknown gate config"):
        GateConfig.from_dict({"noise_mult": 3.0, "nose_mult": 1.0})


def test_gate_config_comes_from_budgets():
    budgets = {"gate": {"noise_mult": 100.0, "rel_cap": 5.0}, "rows": {}}
    cand = _base()
    cand["rows"][0]["median_us_per_call"] = 300.0   # 3x slower
    assert gate(_base(), cand).failed                # default config
    assert not gate(_base(), cand, budgets=budgets).failed  # huge band


# ---------------------------------------------------------------------------
# the committed baseline gates cleanly against itself
# ---------------------------------------------------------------------------

def test_committed_baseline_self_gate_passes():
    import glob
    paths = sorted(glob.glob(os.path.join(REPO, "benchmarks",
                                          "BENCH_*.json")))
    assert paths, "no committed baseline"
    with open(paths[-1]) as f:
        baseline = json.load(f)
    budgets = load_budgets(os.path.join(REPO, "benchmarks",
                                        "budgets.json"))
    res = gate(baseline, baseline, budgets=budgets)
    assert not res.failed, res.to_markdown()


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def _write(tmp_path, name, record):
    p = tmp_path / name
    p.write_text(json.dumps(record))
    return str(p)


def test_cli_pass_and_fail_exit_codes(tmp_path, capsys):
    base = _write(tmp_path, "base.json", _base())
    good = _write(tmp_path, "good.json", _base())
    bad_rec = _base()
    bad_rec["rows"][0]["median_us_per_call"] *= 3.0
    bad = _write(tmp_path, "bad.json", bad_rec)
    assert main([base, good]) == 0
    assert "**PASS**" in capsys.readouterr().out
    assert main([base, bad]) == 1
    assert "**FAIL**" in capsys.readouterr().out


def test_cli_advisory_reports_but_exits_zero(tmp_path, capsys):
    base = _write(tmp_path, "base.json", _base())
    bad_rec = _base()
    bad_rec["rows"][0]["median_us_per_call"] *= 3.0
    bad = _write(tmp_path, "bad.json", bad_rec)
    out_md = str(tmp_path / "gate.md")
    assert main([base, bad, "--advisory", "--out", out_md]) == 0
    assert "advisory mode" in capsys.readouterr().out
    assert "**FAIL**" in open(out_md).read()


def test_cli_init_budgets(tmp_path):
    base = _write(tmp_path, "base.json", _base())
    out = str(tmp_path / "budgets.json")
    assert main(["--init-budgets", out, base, "--safety", "0.5"]) == 0
    budgets = load_budgets(out)
    assert budgets["rows"]["t1_b"]["min_flips_per_ns"] == pytest.approx(
        2.0)
    assert budgets["gate"]["legacy_rel_tol"] == pytest.approx(0.25)


# ---------------------------------------------------------------------------
# helpers: row_stats / throughput
# ---------------------------------------------------------------------------

def test_row_stats_both_formats():
    assert row_stats(_row("x", 10.0, iqr=1.0)) == (10.0, 1.0, 5)
    assert row_stats(_row("x", 10.0, legacy=True)) == (10.0, None, 1)
    # single-trial noise-model row: median, no IQR
    assert row_stats(_row("x", 10.0, n=1)) == (10.0, None, 1)


def test_throughput_prefers_replica_metric():
    row = {"name": "x", "us_per_call": 1.0,
           "derived": {"flips_per_ns": 2.0,
                       "replica_flips_per_ns": 64.0}}
    assert throughput(row) == ("replica_flips_per_ns", 64.0)
    assert throughput({"name": "x", "us_per_call": 1.0,
                       "derived": {}}) == (None, None)


# ---------------------------------------------------------------------------
# properties (hypothesis when installed, seeded fallback otherwise)
# ---------------------------------------------------------------------------

@settings(max_examples=60)
@given(rel=st.floats(min_value=0.0, max_value=2.0),
       floor=st.floats(min_value=0.01, max_value=0.5))
def test_tolerance_monotone_and_clamped(rel, floor):
    cfg = GateConfig(noise_mult=4.0, rel_floor=floor, rel_cap=0.75)
    base = _row("x", 100.0, iqr=100.0 * rel)
    tol = tolerance(base, cfg)
    assert floor <= tol <= max(0.75, floor)
    # monotone in the relative spread
    wider = tolerance(_row("x", 100.0, iqr=100.0 * (rel + 0.1)), cfg)
    assert wider >= tol


@settings(max_examples=60)
@given(ratio=st.floats(min_value=0.05, max_value=20.0),
       tol=st.floats(min_value=0.01, max_value=0.75))
def test_classify_band_is_multiplicatively_symmetric(ratio, tol):
    a, b = classify(ratio, tol), classify(1.0 / ratio, tol)
    flip = {"regression": "improvement", "improvement": "regression",
            "ok": "ok"}
    assert b == flip[a]


@settings(max_examples=40)
@given(median=st.floats(min_value=1.0, max_value=1e6),
       n=st.integers(min_value=2, max_value=50),
       safety=st.floats(min_value=0.1, max_value=0.9))
def test_make_budgets_round_trips_and_floors_below_measured(
        median, n, safety):
    import tempfile
    flips = 1e3 / median
    base = _record([_row("t1_p", median, n=n, flips=flips)])
    budgets = make_budgets(base, safety=safety)
    floor = budgets["rows"]["t1_p"]["min_flips_per_ns"]
    assert floor <= flips            # the floor never exceeds measured
    with tempfile.TemporaryDirectory() as tmp:
        path = dump_budgets(budgets, os.path.join(tmp, "b.json"))
        assert load_budgets(path) == budgets
    # the baseline itself always passes its own budgets
    assert not gate(base, base, budgets=budgets).failed
