"""Optional-algorithm extensions: Heat Bath rule, Wolff cluster updates."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import lattice as lat
from repro.core import metropolis as metro
from repro.core import observables as obs
from repro.core.wolff import run_wolff, wolff_step


def test_heatbath_acceptance_is_sigmoid():
    full = jnp.ones((8, 8), jnp.int8)
    b, w = lat.split_checkerboard(full)
    # all-up lattice, nn=+4, sigma=+1 -> p_flip = sigmoid(-8 beta)
    u = jnp.full(b.shape, 0.5)
    beta = 0.5
    out = metro.update_color(b, w, u, jnp.float32(beta), True,
                             rule="heatbath")
    p = float(jax.nn.sigmoid(jnp.float32(-8 * beta)))
    assert p < 0.5  # no flips at u=0.5
    assert (np.asarray(out) == 1).all()


def test_heatbath_converges_to_onsager():
    key = jax.random.PRNGKey(0)
    full = jnp.ones((48, 48), jnp.int8)
    b, w = lat.split_checkerboard(full)
    beta = jnp.float32(1 / 1.8)
    for i in range(150):
        key, kb, kw = jax.random.split(key, 3)
        b = metro.update_color(b, w, jax.random.uniform(kb, b.shape),
                               beta, True, rule="heatbath")
        w = metro.update_color(w, b, jax.random.uniform(kw, w.shape),
                               beta, False, rule="heatbath")
    m = abs(float(obs.magnetization(b, w)))
    assert abs(m - float(obs.onsager_magnetization(1.8))) < 0.05


def test_wolff_cluster_properties():
    key = jax.random.PRNGKey(1)
    full = lat.init_lattice(key, 16, 16)
    new, size = wolff_step(jax.random.fold_in(key, 1), full, 2.0)
    assert 1 <= int(size) <= 16 * 16
    diff = np.asarray(new) != np.asarray(full)
    assert diff.sum() == int(size)           # exactly the cluster flipped
    # all flipped sites had the same original spin
    assert len(set(np.asarray(full)[diff].tolist())) == 1


def test_wolff_cluster_size_grows_at_low_temperature():
    key = jax.random.PRNGKey(2)
    full = jnp.ones((24, 24), jnp.int8)
    _, size_cold = run_wolff(key, full, 1.0, 20)
    _, size_hot = run_wolff(key, full, 10.0, 20)
    assert float(size_cold) > 10 * float(size_hot)


def test_wolff_preserves_equilibrium():
    """Wolff at T=1.8 keeps an ordered lattice at the Onsager value."""
    key = jax.random.PRNGKey(3)
    full = jnp.ones((32, 32), jnp.int8)
    out, _ = run_wolff(key, full, 1.8, 60)
    m = abs(float(out.astype(jnp.float32).mean()))
    # Wolff flips whole clusters: |m| stays at the spontaneous value
    assert m > 0.80
