"""Dry-run harness smoke: lower+compile a reduced config on the REAL
512-device production mesh in a subprocess (the full-config 88-cell sweep
is run via `python -m repro.launch.dryrun`; artifacts in results/)."""
import json
import os
import subprocess
import sys

import pytest


@pytest.mark.parametrize("arch,shape", [("internlm2-1.8b", "train_4k"),
                                        ("xlstm-125m", "decode_32k")])
def test_dryrun_smoke_cell(tmp_path, arch, shape):
    out = tmp_path / "dr.json"
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    env.pop("XLA_FLAGS", None)  # dryrun sets its own 512-device flag
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--smoke",
         "--arch", arch, "--shape", shape, "--mesh", "single",
         "--out", str(out)],
        env=env, capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]
    cells = json.loads(out.read_text())
    assert len(cells) == 1
    rec = cells[0]
    assert rec["status"] == "ok", rec.get("error")
    assert rec["chips"] == 256
    assert rec["flops"] > 0 and rec["bytes"] > 0
    assert rec["dominant"] in ("compute", "memory", "collective")


def test_production_mesh_shapes():
    """Mesh factory contract (no device allocation: function, not const)."""
    import repro.launch.mesh as mesh_mod
    import inspect
    src = inspect.getsource(mesh_mod)
    assert "def make_production_mesh" in src
    # the module must not build a mesh at import time
    assert not any(line.strip().startswith("MESH") for line in
                   src.splitlines())
