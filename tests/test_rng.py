"""Philox4x32-10 correctness: known-answer vectors + limb-multiply property."""
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import rng


def test_philox_kat_zero():
    out = rng.philox4x32(*[jnp.uint32(0)] * 6)
    assert [int(x) for x in out] == [0x6627E8D5, 0xE169C58D, 0xBC57AC4C,
                                     0x9B00DBD8]


def test_philox_counter_sensitivity():
    a = rng.philox4x32(jnp.uint32(0), jnp.uint32(0), jnp.uint32(1),
                       jnp.uint32(0), jnp.uint32(0), jnp.uint32(0))
    b = rng.philox4x32(*[jnp.uint32(0)] * 6)
    assert not all(int(x) == int(y) for x, y in zip(a, b))


@given(a=st.integers(0, 2**32 - 1), b=st.integers(0, 2**32 - 1))
@settings(max_examples=200, deadline=None)
def test_mulhilo_matches_uint64(a, b):
    hi, lo = rng._mulhilo32(jnp.uint32(a), jnp.uint32(b))
    full = np.uint64(a) * np.uint64(b)
    assert int(hi) == int(full >> np.uint64(32))
    assert int(lo) == int(full & np.uint64(0xFFFFFFFF))


def test_uniforms_in_range_and_deterministic():
    seq = jnp.arange(4096, dtype=jnp.uint32)
    u1 = rng.uniforms(123, seq, jnp.uint32(7))[0]
    u2 = rng.uniforms(123, seq, jnp.uint32(7))[0]
    assert (u1 == u2).all()
    assert float(u1.min()) >= 0.0 and float(u1.max()) < 1.0
    # mean of 4096 uniforms within 5 sigma
    assert abs(float(u1.mean()) - 0.5) < 5 * 0.2887 / 64


def test_uniforms_offset_advances_stream():
    seq = jnp.arange(64, dtype=jnp.uint32)
    u1 = rng.uniforms(1, seq, jnp.uint32(0))[0]
    u2 = rng.uniforms(1, seq, jnp.uint32(1))[0]
    assert not bool((u1 == u2).all())
