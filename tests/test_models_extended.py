"""Spin glass + 3D Ising extensions (paper S2/S6) and extra model cells."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ising3d, spinglass
from repro.core import lattice as lat


# ---------------------------------------------------------------------------
# spin glass
# ---------------------------------------------------------------------------

def test_spinglass_ferromagnetic_limit_matches_ising():
    """p_ferro=1 (all J=+1) reduces to the plain Ising model."""
    key = jax.random.PRNGKey(0)
    full = lat.init_lattice(key, 16, 16)
    j_up = jnp.ones((16, 16), jnp.int8)
    j_left = jnp.ones((16, 16), jnp.int8)
    nn = spinglass.weighted_neighbor_sums(full, j_up, j_left)
    from repro.core import metropolis as metro
    b, w = lat.split_checkerboard(full)
    nn_b = metro.neighbor_sums(w, is_black=True)
    # compare on black sites
    fn = np.asarray(nn)
    for i in range(16):
        for k in range(8):
            j = 2 * k + i % 2
            assert fn[i, j] == int(nn_b[i, k])


def test_spinglass_bond_symmetry():
    """Derived opposite-direction bonds are consistent (J_ij == J_ji)."""
    key = jax.random.PRNGKey(1)
    j_up, j_left = spinglass.init_couplings(key, 8, 8)
    full = lat.init_lattice(key, 8, 8)
    # energy computed from (up,left) must equal the neighbor-sum identity:
    # sum_i s_i * (sum_j J_ij s_j) = 2 * sum_<ij> J_ij s_i s_j
    nn = spinglass.weighted_neighbor_sums(full, j_up, j_left)
    lhs = float((full.astype(jnp.float32)
                 * nn.astype(jnp.float32)).sum())
    e = float(spinglass.energy_per_spin(full, j_up, j_left)) * full.size
    assert lhs == pytest.approx(-2.0 * e, rel=1e-5)


def test_spinglass_quench_lowers_energy():
    key = jax.random.PRNGKey(2)
    j_up, j_left = spinglass.init_couplings(key, 32, 32)
    full = lat.init_lattice(key, 32, 32)
    e0 = float(spinglass.energy_per_spin(full, j_up, j_left))
    out, _ = spinglass.run_sweeps(full, j_up, j_left, jnp.float32(2.0),
                                  key, 200)
    e1 = float(spinglass.energy_per_spin(out, j_up, j_left))
    assert e1 < e0 - 0.3  # frustrated ground state is above -2 but << e0


def test_spinglass_frustration_keeps_m_small():
    """+-J glass at low T: energy drops but |m| stays small (no ferro
    order) -- the qualitative signature vs the pure model."""
    key = jax.random.PRNGKey(3)
    j_up, j_left = spinglass.init_couplings(key, 32, 32)
    full = lat.init_lattice(key, 32, 32)
    out, _ = spinglass.run_sweeps(full, j_up, j_left, jnp.float32(2.0),
                                  key, 300)
    assert abs(float(out.astype(jnp.float32).mean())) < 0.25


# ---------------------------------------------------------------------------
# 3D Ising
# ---------------------------------------------------------------------------

def test_3d_orders_below_tc_disorders_above():
    key = jax.random.PRNGKey(4)
    full = jnp.ones((16, 16, 16), jnp.int8)
    cold, _ = ising3d.run_sweeps_3d(full, jnp.float32(1 / 3.5), key, 60)
    assert abs(float(ising3d.magnetization_3d(cold))) > 0.85
    hot, _ = ising3d.run_sweeps_3d(full, jnp.float32(1 / 8.0), key, 60)
    assert abs(float(ising3d.magnetization_3d(hot))) < 0.2


def test_3d_neighbor_sums():
    full = jnp.ones((4, 4, 4), jnp.int8)
    assert (ising3d.neighbor_sums_3d(full) == 6).all()


def test_3d_distributed_matches_physics():
    """Slab-decomposed 3D engine on 8 host devices stays ordered at low T
    (subprocess; exercises ring halos along the sharded axis)."""
    import os
    import subprocess
    import sys
    import textwrap
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from repro.core import ising3d
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((4, 2), ("data", "model"))
        step, sh = ising3d.make_ising3d_step(mesh, n=16, seed=3, n_sweeps=40)
        full = jax.device_put(jnp.ones((16, 16, 16), jnp.int8), sh)
        out = step(full, jnp.float32(1 / 3.5), jnp.uint32(0))
        m = abs(float(out.astype(jnp.float32).mean()))
        assert m > 0.85, m
        print("OK", m)
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "OK" in proc.stdout
