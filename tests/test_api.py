"""repro.api: spec validation, JSON round-trips, Session dispatch,
unified checkpoints, shim equivalence, and the CLI (DESIGN.md S10)."""
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (BatchSpec, EngineSpec, LatticeSpec, MeshSpec,
                       RunSpec, Session, SweepSpec, describe)
from repro.core.engine import ENGINES, make_engine
from repro.core.ensemble import Ensemble
from repro.core.sim import SimConfig, Simulation

sys.path.insert(0, os.path.dirname(__file__))
from _hypothesis_compat import given, settings, st  # noqa: E402

#: acceptance-criteria engines: one per family, single + ensemble mode
ACCEPT_ENGINES = ("stencil_pallas", "multispin", "bitplane")


# ---------------------------------------------------------------------------
# construction-time validation
# ---------------------------------------------------------------------------

def test_engine_spec_rejects_unknown_engine():
    with pytest.raises(ValueError, match="unknown engine"):
        EngineSpec("nope")


def test_engine_spec_rejects_undeclared_params():
    with pytest.raises(ValueError, match="takes no params"):
        EngineSpec("multispin", params={"tc_block": 64})
    with pytest.raises(ValueError, match="takes no params"):
        EngineSpec("tensorcore", params={"p_ferro": 0.5})
    # declared params pass and normalize to a sorted tuple
    assert EngineSpec("tensorcore",
                      params={"tc_block": 64}).param_dict == {
                          "tc_block": 64}
    with pytest.raises(ValueError, match="tc_block"):
        EngineSpec("tensorcore", params={"tc_block": -1})
    with pytest.raises(ValueError, match="p_ferro"):
        EngineSpec("spinglass", params={"p_ferro": 1.5})


def test_batch_requires_counter_based_engine():
    for engine in ("basic", "tensorcore", "wolff", "spinglass"):
        with pytest.raises(ValueError, match="not counter-based"):
            RunSpec(lattice=LatticeSpec(16, 16),
                    engine=EngineSpec(engine),
                    batch=BatchSpec(temperatures=(2.0,)))


def test_mesh_requires_distributable_engine():
    with pytest.raises(ValueError, match="no distributed step"):
        RunSpec(lattice=LatticeSpec(16, 16), engine=EngineSpec("wolff"),
                mesh=MeshSpec((1, 1), ("data", "model")))


def test_batch_plus_mesh_unsupported():
    with pytest.raises(ValueError, match="batch \\+ mesh"):
        RunSpec(lattice=LatticeSpec(16, 16),
                engine=EngineSpec("multispin"),
                batch=BatchSpec(temperatures=(2.0,)),
                mesh=MeshSpec((1, 1), ("data", "model")))


def test_batch_seeds_over_32_bits_raise():
    """The legacy Ensemble silently masked seeds with & 0xFFFFFFFF; the
    spec rejects them up front (they cannot match the 64-bit
    single-simulation Philox stream)."""
    with pytest.raises(ValueError, match="2\\*\\*32"):
        BatchSpec(temperatures=(2.0,), seeds=(2 ** 32,))
    with pytest.raises(ValueError, match="2\\*\\*32"):
        Ensemble(16, 16, [2.0], seeds=[2 ** 32 + 5])
    # boundary value passes
    BatchSpec(temperatures=(2.0,), seeds=(2 ** 32 - 1,))


def test_lattice_constraints_validated_at_construction():
    with pytest.raises(ValueError, match="even"):
        LatticeSpec(15, 16)
    # multispin packs 8 spins/word: m/2 % 8 != 0 fails at spec time,
    # not deep inside a trace
    with pytest.raises(ValueError, match="multiple of 8"):
        RunSpec(lattice=LatticeSpec(16, 10),
                engine=EngineSpec("multispin"))
    with pytest.raises(ValueError, match="multiple of 4"):
        RunSpec(lattice=LatticeSpec(16, 10),
                engine=EngineSpec("bitplane"))
    # basic has no packing constraint: m=10 is fine
    RunSpec(lattice=LatticeSpec(16, 10), engine=EngineSpec("basic"))


def test_batch_grid_cross_product():
    b = BatchSpec(temperatures=(1.5, 2.5), seeds=(7, 8, 9), grid=True)
    assert b.size == 6
    assert b.members[:3] == ((1.5, 7), (1.5, 8), (1.5, 9))
    z = BatchSpec(temperatures=(1.5, 2.5))
    assert z.member_seeds == (0, 1)
    with pytest.raises(ValueError, match="len\\(seeds\\)"):
        BatchSpec(temperatures=(1.5, 2.5), seeds=(1,))


# ---------------------------------------------------------------------------
# JSON round-trips (every engine's param set)
# ---------------------------------------------------------------------------

def _spec_params_for(engine):
    if engine == "tensorcore":
        return {"tc_block": 8}
    if engine == "spinglass":
        return {"p_ferro": 0.25}
    return {}


@pytest.mark.parametrize("engine", sorted(ENGINES))
def test_runspec_json_roundtrip_every_engine(engine):
    spec = RunSpec(lattice=LatticeSpec(16, 16, init_p_up=0.75),
                   engine=EngineSpec(engine,
                                     params=_spec_params_for(engine)),
                   temperature=2.125, seed=(1 << 40) + 3,
                   sweep=SweepSpec(thermalize=5, measure_every=2,
                                   n_measure=7, fields=("m",)))
    back = RunSpec.from_json(spec.to_json())
    assert back == spec
    assert json.loads(back.to_json()) == json.loads(spec.to_json())


@settings(max_examples=30)
@given(cfg=st.tuples(
    st.integers(1, 8),                  # lattice half-rows
    st.integers(1, 4),                  # lattice m/16
    st.floats(0.5, 5.0),                # temperature
    st.integers(0, 2 ** 32 - 1),        # seed
    st.floats(0.0, 1.0),                # init_p_up
    st.booleans(),                      # with sweep?
    st.booleans(),                      # with batch?
    st.integers(0, 7),                  # engine pick (counter-based set)
))
def test_runspec_json_roundtrip_property(cfg):
    """Lossless to_json/from_json over randomized spec trees."""
    rows, mdiv, temp, seed, p_up, with_sweep, with_batch, pick = cfg
    counter = sorted(n for n, c in ENGINES.items() if c.counter_based)
    engine = counter[pick % len(counter)]
    sweep = SweepSpec(thermalize=rows, measure_every=1 + mdiv,
                      n_measure=1 + rows) if with_sweep else None
    batch = BatchSpec(temperatures=(temp, temp + 0.5),
                      seeds=(seed, seed // 2)) if with_batch else None
    spec = RunSpec(lattice=LatticeSpec(2 * rows, 16 * mdiv,
                                       init_p_up=p_up),
                   engine=EngineSpec(engine),
                   temperature=temp, seed=seed, sweep=sweep, batch=batch)
    back = RunSpec.from_json(spec.to_json())
    assert back == spec


def test_from_dict_rejects_unknown_keys():
    """A typo'd spec document must fail loudly, not silently run a
    different run (e.g. a misspelled 'sweep' dropping thermalization)."""
    good = RunSpec(lattice=LatticeSpec(16, 16),
                   engine=EngineSpec("multispin")).to_dict()
    with pytest.raises(ValueError, match="unknown key"):
        RunSpec.from_dict({**good, "swep": {"n_measure": 5}})
    with pytest.raises(ValueError, match="unknown key"):
        EngineSpec.from_dict({"name": "multispin", "parms": {}})
    with pytest.raises(ValueError, match="unknown key"):
        BatchSpec.from_dict({"temperatures": [2.0], "sheeds": [1]})
    with pytest.raises(ValueError, match="unknown key"):
        SweepSpec.from_dict({"thermalise": 5, "n_measure": 2})
    with pytest.raises(ValueError, match="unknown key"):
        MeshSpec.from_dict({"shape": [1, 1], "axes": ["a", "b"]})
    with pytest.raises(ValueError, match="unknown key"):
        LatticeSpec.from_dict({"n": 16, "m": 16, "p_up": 1.0})


def test_load_spec_reads_checkpoint_without_state(tmp_path):
    spec = RunSpec(lattice=LatticeSpec(16, 16),
                   engine=EngineSpec("multispin"), temperature=2.2,
                   seed=3)
    s = Session.open(spec)
    s.run(1)
    path = str(tmp_path / "ck.npz")
    s.save(path)
    from repro.api.session import load_spec
    assert load_spec(path) == spec
    bad = str(tmp_path / "bad.npz")
    np.savez(bad, step_count=1)
    with pytest.raises(ValueError, match="pre-registry"):
        load_spec(bad)


def test_sim_config_lift_round_trip():
    cfg = SimConfig(n=16, m=32, temperature=2.25, seed=11,
                    engine="tensorcore", tc_block=4, init_p_up=1.0)
    spec = RunSpec.from_sim_config(cfg)
    assert spec.engine.param_dict == {"tc_block": 4}
    assert spec.sim_config() == cfg


# ---------------------------------------------------------------------------
# Session dispatch + unified checkpoints (acceptance criteria)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ACCEPT_ENGINES)
def test_session_single_checkpoint_roundtrip(engine, tmp_path):
    """spec -> Session -> save -> restore: lossless spec round-trip and
    bit-exact continuation (restart == uninterrupted run)."""
    spec = RunSpec(lattice=LatticeSpec(16, 16), engine=EngineSpec(engine),
                   temperature=2.2, seed=9)
    a = Session.open(spec)
    a.run(3)
    path = str(tmp_path / f"{engine}.npz")
    a.save(path)
    b = Session.restore(path)
    assert b.spec == spec
    assert b.step_count == a.step_count
    a.run(2)
    b.run(2)
    np.testing.assert_array_equal(np.asarray(a.full_lattice()),
                                  np.asarray(b.full_lattice()))


@pytest.mark.parametrize("engine", ACCEPT_ENGINES)
def test_session_ensemble_checkpoint_roundtrip(engine, tmp_path):
    """Batched states + step_count + spec checkpoint (PR 5 satellite):
    restart-exact for every member."""
    spec = RunSpec(lattice=LatticeSpec(16, 16), engine=EngineSpec(engine),
                   batch=BatchSpec(temperatures=(1.9, 2.6),
                                   seeds=(3, 4)))
    a = Session.open(spec)
    a.run(3)
    path = str(tmp_path / f"ens_{engine}.npz")
    a.save(path)
    b = Session.restore(path)
    assert b.spec == spec
    assert b.mode == "ensemble"
    assert b.step_count == a.step_count
    a.run(2)
    b.run(2)
    np.testing.assert_array_equal(a.full_lattice(), b.full_lattice())


def test_session_measure_uses_spec_sweep():
    spec = RunSpec(lattice=LatticeSpec(16, 16),
                   engine=EngineSpec("multispin"), temperature=2.1,
                   seed=5,
                   sweep=SweepSpec(thermalize=2, measure_every=2,
                                   n_measure=4, fields=("m", "e")))
    s = Session.open(spec)
    traj = s.measure()
    assert traj["m"].shape == (4,) and traj["e"].shape == (4,)
    assert s.step_count == spec.sweep.total_sweeps
    with pytest.raises(ValueError, match="no plan"):
        Session.open(RunSpec(lattice=LatticeSpec(16, 16),
                             engine=EngineSpec("multispin"))).measure()


def test_session_sharded_matches_single():
    """MeshSpec dispatch reproduces the single-device trajectory
    bit-for-bit (global-position-keyed Philox)."""
    for engine in ("basic_philox", "multispin"):
        kw = dict(lattice=LatticeSpec(16, 16),
                  engine=EngineSpec(engine), temperature=2.1, seed=7)
        sh = Session.open(RunSpec(mesh=MeshSpec((1, 1), ("data", "model")), **kw))
        si = Session.open(RunSpec(**kw))
        sh.run(2)
        si.run(2)
        sh.run(3)   # second chunk: offset bookkeeping across dispatches
        si.run(3)
        np.testing.assert_array_equal(np.asarray(sh.full_lattice()),
                                      np.asarray(si.full_lattice()),
                                      err_msg=engine)
        assert sh.magnetization() == pytest.approx(si.magnetization())


def test_session_sharded_checkpoint_roundtrip(tmp_path):
    spec = RunSpec(lattice=LatticeSpec(16, 16),
                   engine=EngineSpec("multispin"), temperature=2.1,
                   seed=7, mesh=MeshSpec((1, 1), ("data", "model")))
    a = Session.open(spec)
    a.run(3)
    path = str(tmp_path / "sharded.npz")
    a.save(path)
    b = Session.restore(path)
    assert b.spec == spec
    a.run(2)
    b.run(2)
    np.testing.assert_array_equal(np.asarray(a.full_lattice()),
                                  np.asarray(b.full_lattice()))


def test_describe_is_deviceless_plan():
    spec = RunSpec(lattice=LatticeSpec(64, 64),
                   engine=EngineSpec("stencil_pallas"),
                   batch=None, sweep=SweepSpec(thermalize=10,
                                               measure_every=2,
                                               n_measure=5))
    plan = describe(spec)
    assert plan["mode"] == "single"
    assert plan["counter_based"] is True
    assert plan["resident"]["family"] == "stencil"
    assert plan["total_sweeps"] == 20
    assert RunSpec.from_dict(plan["spec"]) == spec
    # a huge mesh describes fine without the devices existing
    big = RunSpec(lattice=LatticeSpec(1024, 1024),
                  engine=EngineSpec("multispin"),
                  mesh=MeshSpec((16, 16), ("data", "model")))
    assert describe(big)["mode"] == "sharded"


# ---------------------------------------------------------------------------
# shim equivalence: Simulation/Ensemble are bit-identical to the
# pre-refactor drivers (legacy logic re-enacted inline)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine",
                         ("basic", "basic_philox", "multispin",
                          "bitplane", "tensorcore"))
def test_simulation_shim_bitexact_vs_legacy_driver(engine):
    """The pre-refactor Simulation did: state = engine.init_state(
    PRNGKey(seed)); state = engine.sweeps(state, n, step).  The shim
    must reproduce it bit-for-bit, chunk boundaries included."""
    cfg = SimConfig(n=16, m=16, temperature=2.15, seed=13, engine=engine,
                    tc_block=4)
    sim = Simulation(cfg)
    sim.run(3)
    sim.run(2)

    eng = make_engine(cfg)
    state = eng.init_state(jax.random.PRNGKey(cfg.seed))
    state = eng.sweeps(state, 3, 0)
    state = eng.sweeps(state, 2, 3)
    np.testing.assert_array_equal(np.asarray(sim.full_lattice()),
                                  np.asarray(eng.full_lattice(state)))


def test_ensemble_shim_bitexact_vs_legacy_driver():
    """The pre-refactor Ensemble did: jit(vmap(sweep_fn + mag)) over
    (states, inv_temps (1/float(T)), uint32 seeds) from vmapped
    PRNGKeys.  The shim must reproduce members and returned mags
    bit-for-bit."""
    temps, seeds = [1.8, 2.5], [3, 4]
    ens = Ensemble(16, 16, temps, seeds, engine="multispin")
    mags = ens.run(3)

    cfg = SimConfig(n=16, m=16, engine="multispin")
    eng = make_engine(cfg)
    keys = jax.vmap(jax.random.PRNGKey)(jnp.asarray(seeds, jnp.int32))
    states = jax.jit(jax.vmap(eng.init_state))(keys)
    inv_temps = jnp.asarray([1.0 / float(t) for t in temps], jnp.float32)
    useeds = jnp.asarray(np.asarray(seeds, np.int64) & 0xFFFFFFFF,
                         jnp.uint32)

    def one(state, inv_temp, seed, start):
        state = eng.sweep_fn(state, inv_temp, seed, start, 3)
        return state, eng.magnetization(state)

    states, ref_mags = jax.jit(jax.vmap(one, in_axes=(0, 0, 0, None)))(
        states, inv_temps, useeds, jnp.uint32(0))
    np.testing.assert_array_equal(mags, np.asarray(ref_mags))
    fulls = jax.jit(jax.vmap(eng.full_lattice))(states)
    np.testing.assert_array_equal(ens.full_lattices(), np.asarray(fulls))


def test_ensemble_threads_member0_and_params_into_config():
    """PR 5 satellite: temperature/seed/tc_block/p_ferro no longer
    dropped on the floor when building the internal engine config."""
    ens = Ensemble(16, 16, [1.75, 2.5], seeds=[42, 43],
                   engine="multispin")
    assert ens.config.temperature == 1.75
    assert ens.config.seed == 42
    assert ens.config.engine == "multispin"


def test_ensemble_checkpoint_via_shim(tmp_path):
    ens = Ensemble(16, 16, [1.9, 2.4], seeds=[5, 6], engine="multispin")
    ens.run(3)
    path = str(tmp_path / "ens.npz")
    ens.save(path)
    back = Ensemble.restore(path)
    assert back.step_count == ens.step_count
    ens.run(2)
    back.run(2)
    np.testing.assert_array_equal(ens.full_lattices(),
                                  back.full_lattices())
    samples = back.trajectory(n_measure=2, sweeps_between=1)
    assert samples.shape == (2, 2)


def test_simulation_checkpoint_cross_restorable_by_session(tmp_path):
    """One unified layout: Simulation.save -> Session.restore and
    Session.save -> Simulation.restore both continue bit-exactly."""
    cfg = SimConfig(n=16, m=16, temperature=2.2, seed=7,
                    engine="multispin")
    sim = Simulation(cfg)
    sim.run(4)
    p1 = str(tmp_path / "sim.npz")
    sim.save(p1)
    sess = Session.restore(p1)
    sim.run(3)
    sess.run(3)
    np.testing.assert_array_equal(np.asarray(sim.full_lattice()),
                                  np.asarray(sess.full_lattice()))

    p2 = str(tmp_path / "sess.npz")
    sess.save(p2)
    back = Simulation.restore(p2)
    assert back.config == cfg
    back.run(1)
    sess.run(1)
    np.testing.assert_array_equal(np.asarray(back.full_lattice()),
                                  np.asarray(sess.full_lattice()))


def test_simulation_restore_rejects_ensemble_checkpoint(tmp_path):
    ens = Ensemble(16, 16, [2.0], seeds=[1], engine="multispin")
    path = str(tmp_path / "e.npz")
    ens.save(path)
    with pytest.raises(ValueError, match="ensemble"):
        Simulation.restore(path)


# ---------------------------------------------------------------------------
# CLI: python -m repro run (in-process: spawning interpreters is slow)
# ---------------------------------------------------------------------------

def _cli(*argv):
    from repro.__main__ import main
    return main(list(argv))


def test_cli_dry_run_prints_plan(capsys):
    rc = _cli("run", "--dry-run", "--n", "16", "--engine", "multispin",
              "--temps", "1.8,2.2", "--n-measure", "3")
    assert rc == 0
    plan = json.loads(capsys.readouterr().out)
    assert plan["mode"] == "ensemble" and plan["batch_size"] == 2


def test_cli_dry_run_rejects_invalid_spec(tmp_path):
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({
        "engine": {"name": "wolff"},
        "lattice": {"n": 16, "m": 16},
        "batch": {"temperatures": [2.0]}}))
    with pytest.raises(ValueError, match="not counter-based"):
        _cli("run", "--dry-run", str(bad))


@pytest.mark.parametrize("engine", ACCEPT_ENGINES)
def test_cli_roundtrip_records_identical_spec(engine, tmp_path, capsys):
    """The acceptance chain: spec JSON -> CLI run -> record; the
    recorded spec is byte-identical to the canonical input spec, and
    the CLI checkpoint restores to the same spec."""
    spec = RunSpec(lattice=LatticeSpec(16, 16), engine=EngineSpec(engine),
                   temperature=2.1, seed=3,
                   sweep=SweepSpec(thermalize=1, measure_every=1,
                                   n_measure=2, fields=("m",)))
    spec_path = tmp_path / "spec.json"
    spec_path.write_text(spec.to_json())
    record = tmp_path / "rec.json"
    ckpt = tmp_path / "ck.npz"
    rc = _cli("run", str(spec_path), "--record", str(record),
              "--save", str(ckpt))
    capsys.readouterr()
    assert rc == 0
    rec = json.loads(record.read_text())
    assert rec["meta"]["spec"] == spec.to_dict()
    assert json.loads(rec["rows"][0]["spec"]) == spec.to_dict()
    assert Session.restore(str(ckpt)).spec == spec


def test_cli_restore_continues(tmp_path, capsys):
    spec = RunSpec(lattice=LatticeSpec(16, 16),
                   engine=EngineSpec("multispin"), temperature=2.0,
                   seed=5)
    spec_path = tmp_path / "spec.json"
    spec_path.write_text(spec.to_json())
    ckpt = tmp_path / "ck.npz"
    assert _cli("run", str(spec_path), "--sweeps", "3",
                "--save", str(ckpt)) == 0
    assert _cli("run", "--restore", str(ckpt), "--sweeps", "2") == 0
    capsys.readouterr()
    ref = Session.open(spec)
    ref.run(3)
