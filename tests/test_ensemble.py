"""Batched ensemble driver: bit-exactness vs single sims + physics."""
import numpy as np
import pytest

from repro.core.ensemble import Ensemble
from repro.core.sim import SimConfig, Simulation

COUNTER_ENGINES = ("basic_philox", "multispin", "stencil_pallas",
                   "bitplane")


@pytest.mark.parametrize("engine", COUNTER_ENGINES)
def test_ensemble_member_matches_simulation_bitexact(engine):
    """Every vmapped member follows its Simulation trajectory exactly."""
    temps, seeds = [1.8, 2.5], [3, 4]
    ens = Ensemble(16, 16, temps, seeds, engine=engine)
    ens.run(3)
    lattices = ens.full_lattices()
    for i, (temp, seed) in enumerate(zip(temps, seeds)):
        sim = Simulation(SimConfig(n=16, m=16, temperature=temp, seed=seed,
                                   engine=engine))
        sim.run(3)
        np.testing.assert_array_equal(np.asarray(sim.full_lattice()),
                                      lattices[i], err_msg=f"member {i}")


def test_ensemble_run_returns_magnetization_curve():
    """One vmapped call yields m(T): ordered below Tc, disordered above."""
    temps = [1.5, 5.0]
    ens = Ensemble(32, 32, temps, seeds=[11, 12], engine="multispin",
                   init_p_up=1.0)
    mags = ens.run(200)
    assert mags.shape == (2,)
    assert abs(mags[0]) > 0.9, mags      # T=1.5 < Tc stays ordered
    assert abs(mags[1]) < 0.15, mags     # T=5.0 >> Tc disorders


def test_ensemble_trajectory_shape_and_offsets():
    ens = Ensemble(16, 16, [2.0, 2.0, 2.0], seeds=[1, 2, 3],
                   engine="basic_philox")
    samples = ens.trajectory(n_measure=4, sweeps_between=2, thermalize=2)
    assert samples.shape == (4, 3)
    assert ens.step_count == 2 + 4 * 2
    # distinct seeds at the same temperature give distinct trajectories
    assert (ens.full_lattices()[0] != ens.full_lattices()[1]).any()


def test_ensemble_rejects_key_based_engines():
    for engine in ("basic", "tensorcore", "wolff", "spinglass"):
        with pytest.raises(ValueError, match="not counter-based"):
            Ensemble(16, 16, [2.0], engine=engine)


def test_ensemble_default_seeds_and_size():
    ens = Ensemble(16, 16, [1.9, 2.3], engine="multispin")
    assert ens.size == 2
    assert ens.run(1).shape == (2,)
