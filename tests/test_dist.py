"""repro.dist -- sharded resident tier (DESIGN.md S15).

Three layers:

* planner unit tests (in-process, no devices): halo algebra, VMEM
  fit, overlap cap, demotion reasons;
* a 1x1-mesh session in the default single-device pytest process:
  digest parity with the unsharded session, halo counter accounting
  (resident: one exchange per k sweeps; demoted: two per sweep), and
  the dispatch-span / describe attributes;
* an 8-forced-host-device subprocess (the ``test_distributed.py``
  convention): driver bit-exactness vs the single-device resident
  kernels for all three families at k in {1, 3} on two mesh shapes,
  Session digest parity on real multi-device meshes, and cross-mesh
  supervised checkpoint portability.
"""
import json
import math
import os
import subprocess
import sys
import textwrap

import pytest

import repro.telemetry as tel
from repro.dist import plan_shard_resident, shard_decision_attrs
from repro.dist.planner import K_CAP, ShardPlan, shard_working_set_bytes


# ---------------------------------------------------------------------------
# planner
# ---------------------------------------------------------------------------

def test_plan_picks_largest_feasible_k():
    plan = plan_shard_resident("stencil", 64, 128, 2, 1)
    assert plan is not None
    assert plan.k == K_CAP and plan.halo == 2 * K_CAP
    assert plan.n_loc == 32 and plan.w_loc == 64


def test_plan_halo_always_even():
    for k_cap in range(1, K_CAP + 1):
        plan = plan_shard_resident("stencil", 64, 128, 2, 1,
                                   k_cap=k_cap, max_overlap=100.0)
        assert plan is not None and plan.k == k_cap
        assert plan.halo == 2 * plan.k and plan.halo % 2 == 0


def test_plan_rejects_non_divisible_grid():
    # 64 rows do not tile 3 device rows; stencil width 64 not 5 cols
    assert plan_shard_resident("stencil", 64, 128, 3, 1) is None
    assert plan_shard_resident("stencil", 64, 128, 1, 5) is None
    # odd per-shard rows break checkerboard parity uniformity
    assert plan_shard_resident("stencil", 34, 128, 2, 1) is None


def test_plan_overlap_cap_demotes_small_shards():
    # 16-row shards of a 32x64 stencil plane: even k=1 (h=2) inflates
    # the extended area past 2x owned -> demoted under the default cap
    assert plan_shard_resident("stencil", 32, 64, 4, 4) is None
    plan = plan_shard_resident("stencil", 32, 64, 4, 4,
                               max_overlap=100.0)
    assert plan is not None  # the cap is pure perf policy


def test_plan_vmem_budget_demotes():
    assert plan_shard_resident("stencil", 64, 128, 2, 1,
                               budget_bytes=64) is None


def test_plan_working_set_counts_index_planes():
    # bitplane carries two uint32 index planes (group + lane); the
    # extended working set must include them
    ws = shard_working_set_bytes("bitplane", 8, 8, 2)
    ext = (8 + 4) * (8 + 4)
    assert ws >= ext * (4 + 4 * 2)  # >= 1x plane + both index planes


def test_plan_exchanges_ceil_semantics():
    plan = plan_shard_resident("stencil", 64, 128, 2, 1, k_cap=3,
                               max_overlap=100.0)
    assert plan.k == 3
    assert plan.exchanges(6) == 2
    assert plan.exchanges(7) == 3   # remainder block exchanges too
    assert plan.exchanges(1) == 1


def test_plan_halo_bytes_formula():
    plan = plan_shard_resident("stencil", 64, 128, 2, 2, k_cap=1,
                               max_overlap=100.0)
    h, nl, wl = plan.halo, plan.n_loc, plan.w_loc
    per_plane = 2 * nl * h + 2 * h * (wl + 2 * h)
    assert plan.halo_bytes_per_exchange == 2 * per_plane * 1 * 4


def test_decision_attrs_positive_and_demoted():
    attrs = shard_decision_attrs("stencil", 64, 128, 2, 1)
    assert attrs["sharded_resident"] is True
    assert attrs["grid"] == "2x1"
    assert attrs["halo_width"] == 2 * attrs["halo_k"]
    attrs = shard_decision_attrs("stencil", 64, 128, 3, 1)
    assert attrs["sharded_resident"] is False
    assert "tile the device grid" in attrs["reason"]


def test_unknown_family_raises():
    with pytest.raises(ValueError, match="unknown resident family"):
        plan_shard_resident("nope", 64, 64, 2, 1)


# ---------------------------------------------------------------------------
# 1x1-mesh sessions in the default single-device process
# ---------------------------------------------------------------------------

def _spec(engine, n, m, mesh_shape=None):
    from repro.api import EngineSpec, LatticeSpec, MeshSpec, RunSpec
    mesh = None if mesh_shape is None else MeshSpec(shape=mesh_shape)
    return RunSpec(lattice=LatticeSpec(n=n, m=m),
                   engine=EngineSpec(engine), temperature=2.27,
                   seed=9, mesh=mesh)


def test_1x1_mesh_resident_digest_matches_unsharded():
    from repro.api.session import Session
    ref = Session.open(_spec("stencil_pallas", 32, 32))
    ref.run(5)
    s = Session.open(_spec("stencil_pallas", 32, 32, (1, 1)))
    plan = s._runner._dist_plan
    assert plan is not None and plan.k >= 1
    tel.reset()
    s.run(5)
    assert s.state_digest() == ref.state_digest()
    ex = tel.HALO_EXCHANGES.value
    assert ex == math.ceil(5 / plan.k)
    assert tel.HALO_BYTES.value == ex * plan.halo_bytes_per_exchange


def test_demoted_mesh_counts_per_half_sweep_exchanges():
    from repro.api.session import Session
    # multispin at m=32 packs to a 2-word row: the overlap cap demotes
    # every k, so the per-half-sweep tier runs -> 2 exchanges per sweep
    s = Session.open(_spec("multispin_pallas", 32, 32, (1, 1)))
    assert s._runner._dist_plan is None
    tel.reset()
    s.run(3)
    assert tel.HALO_EXCHANGES.value == 2 * 3
    assert tel.HALO_BYTES.value > 0
    ref = Session.open(_spec("multispin_pallas", 32, 32))
    ref.run(3)
    assert s.state_digest() == ref.state_digest()


def test_dispatch_span_carries_halo_attrs():
    from repro.api.session import Session
    tel.reset()
    tel.enable()
    try:
        s = Session.open(_spec("stencil_pallas", 32, 32, (1, 1)))
        s.run(4)
        spans = [e for e in tel.TRACER.events
                 if e["name"] == "dispatch"]
        assert spans, [e["name"] for e in tel.TRACER.events]
        args = spans[-1]["args"]
        assert args["sharded_resident"] is True
        assert args["halo_width"] == 2 * args["halo_k"]
        plan = s._runner._dist_plan
        assert args["halo_exchanges"] == plan.exchanges(4)
    finally:
        tel.disable()
        tel.reset()


def test_describe_reports_shard_decision():
    from repro.api.session import describe
    d = describe(_spec("stencil_pallas", 32, 32, (1, 1)))
    assert d["dist"]["sharded_resident"] is True
    assert d["dist"]["halo_k"] >= 1
    d = describe(_spec("multispin_pallas", 32, 32, (1, 1)))
    assert d["dist"]["sharded_resident"] is False
    assert "reason" in d["dist"]
    d = describe(_spec("stencil_pallas", 32, 32))
    assert d["dist"] is None


# ---------------------------------------------------------------------------
# 8-device subprocess: exactness, real meshes, cross-mesh portability
# ---------------------------------------------------------------------------

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import math, tempfile
    import jax, jax.numpy as jnp, numpy as np, json
    import repro.telemetry as tel
    from repro.launch.mesh import make_mesh
    from repro.dist import plan_shard_resident, make_resident_step
    from repro.kernels.stencil.resident import stencil_sweeps_resident
    from repro.kernels.multispin.resident import multispin_sweeps_resident
    from repro.kernels.bitplane.resident import bitplane_sweeps_resident
    from repro.core import bitplane as bpc, lattice as lat, multispin as ms
    from repro.api import EngineSpec, LatticeSpec, MeshSpec, RunSpec
    from repro.api.session import Session
    from repro.resilience import Supervisor

    rng = np.random.default_rng(0)
    SEED, BETA = 12345, 1.0 / 2.27
    out = {}

    def stencil_planes(n, m):
        full = rng.integers(0, 2, (n, m)).astype(np.int8) * 2 - 1
        return lat.split_checkerboard(jnp.asarray(full))

    def ms_planes(n, m):
        full = rng.integers(0, 2, (n, m)).astype(np.int8) * 2 - 1
        return ms.pack_lattice(*lat.split_checkerboard(jnp.asarray(full)))

    def bp_planes(n, m):
        full = rng.integers(0, 2, (32, n, m)).astype(np.int8) * 2 - 1
        return bpc.pack_lattices(jnp.asarray(full))

    # -- driver bit-exactness: families x grids x k in {1, 3}, with a
    #    remainder block (5 = 1*3 + 2) and a nonzero start offset
    CASES = [("stencil", 48, 48, stencil_sweeps_resident, stencil_planes),
             ("multispin", 48, 384, multispin_sweeps_resident, ms_planes),
             ("bitplane", 48, 48, bitplane_sweeps_resident, bp_planes)]
    for k in (1, 3):
        ns = 5 if k == 3 else 2
        for family, n, m, ref_fn, mk in CASES:
            b, w = mk(n, m)
            ref = ref_fn(b, w, jnp.float32(BETA), n_sweeps=ns,
                         seed=SEED, start_offset=6, interpret=True)
            ref = tuple(np.asarray(x) for x in ref)
            for grid in [(4, 2), (2, 4)]:
                mesh = make_mesh(grid, ("rows", "cols"))
                plan = plan_shard_resident(family, n, m, grid[0],
                                           grid[1], k_cap=k,
                                           max_overlap=100.0)
                assert plan is not None and plan.k == k, (family, grid, k)
                step, sh = make_resident_step(mesh, plan, seed=SEED,
                                              n_sweeps=ns)
                ob, ow = step(jax.device_put(b, sh),
                              jax.device_put(w, sh),
                              jnp.float32(BETA), jnp.uint32(6))
                key = f"exact_{family}_{grid[0]}x{grid[1]}_k{k}"
                out[key] = bool(
                    (np.asarray(ob) == ref[0]).all()
                    and (np.asarray(ow) == ref[1]).all())

    # -- Session digest parity + halo counters on real meshes
    def spec_for(engine, n, m, shape=None):
        mesh = None if shape is None else MeshSpec(shape=shape)
        return RunSpec(lattice=LatticeSpec(n=n, m=m),
                       engine=EngineSpec(engine), temperature=2.27,
                       seed=9, mesh=mesh)

    for engine, n, m in [("stencil_pallas", 48, 48),
                         ("multispin_pallas", 48, 384),
                         ("bitplane_pallas", 48, 48)]:
        ref = Session.open(spec_for(engine, n, m))
        ref.run(7)
        want = ref.state_digest()
        for shape in [(4, 2), (2, 4)]:
            s = Session.open(spec_for(engine, n, m, shape))
            plan = s._runner._dist_plan
            assert plan is not None, (engine, shape)
            tel.reset()
            s.run(7)
            key = f"session_{engine}_{shape[0]}x{shape[1]}"
            out[key] = bool(s.state_digest() == want)
            out[key + "_exchanges"] = (
                tel.HALO_EXCHANGES.value == math.ceil(7 / plan.k))

    # -- cross-mesh supervised checkpoint portability: save on 1x4,
    #    resume on 4x2 AND unsharded; both must match the uninterrupted
    #    single-device reference digest
    ref = Session.open(spec_for("stencil_pallas", 48, 48))
    ref.run(8)
    want = ref.state_digest()
    for resume_shape in [(4, 2), None]:
        d = tempfile.mkdtemp(prefix="dist_xmesh_")
        sup = Supervisor(spec_for("stencil_pallas", 48, 48, (1, 4)),
                         d, every_sweeps=2, chunk=2,
                         install_signal_handlers=False,
                         on_chunk=lambda s: s.request_stop())
        r1 = sup.run(8)
        assert r1.status == "preempted", r1
        sup2 = Supervisor(spec_for("stencil_pallas", 48, 48,
                                   resume_shape), d, every_sweeps=2,
                          chunk=2, install_signal_handlers=False)
        r2 = sup2.run(8)
        tag = "4x2" if resume_shape else "unsharded"
        out[f"xmesh_resumed_{tag}"] = r2.resumed_from is not None
        out[f"xmesh_digest_{tag}"] = bool(r2.completed
                                          and r2.digest == want)

    print(json.dumps(out))
""")


@pytest.fixture(scope="module")
def dist_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


@pytest.mark.parametrize("family", ["stencil", "multispin", "bitplane"])
@pytest.mark.parametrize("grid", ["4x2", "2x4"])
@pytest.mark.parametrize("k", [1, 3])
def test_driver_bit_exact(dist_results, family, grid, k):
    assert dist_results[f"exact_{family}_{grid}_k{k}"]


@pytest.mark.parametrize("engine", ["stencil_pallas",
                                    "multispin_pallas",
                                    "bitplane_pallas"])
@pytest.mark.parametrize("grid", ["4x2", "2x4"])
def test_session_digest_parity(dist_results, engine, grid):
    assert dist_results[f"session_{engine}_{grid}"]
    assert dist_results[f"session_{engine}_{grid}_exchanges"]


@pytest.mark.parametrize("tag", ["4x2", "unsharded"])
def test_cross_mesh_checkpoint_portability(dist_results, tag):
    assert dist_results[f"xmesh_resumed_{tag}"]
    assert dist_results[f"xmesh_digest_{tag}"]
