"""Training loop: convergence, optimizer, checkpoint/restart equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_smoke_config
from repro.data import DataIterator, make_batch
from repro.models import init_model
from repro.train import OptConfig, make_train_step, opt_init
from repro.train.optim import global_norm, schedule, update


def _tiny_setup(arch="internlm2-1.8b", steps=None):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = init_model(cfg, key)
    opt = opt_init(params)
    ocfg = OptConfig(lr=1e-2, warmup=5, total_steps=100, clip_norm=1.0)
    step = jax.jit(make_train_step(cfg, ocfg))
    return cfg, params, opt, step


def test_loss_decreases():
    cfg, params, opt, step = _tiny_setup()
    shape = SHAPES["train_4k"]
    losses = []
    for i in range(30):
        batch = make_batch(cfg, shape, step=0, seed=1, batch_override=4,
                           seq_override=32)  # same batch: must memorize
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.7, losses[::6]
    assert np.isfinite(losses).all()


def test_moe_train_step_runs():
    cfg, params, opt, step = _tiny_setup("deepseek-moe-16b")
    batch = make_batch(cfg, SHAPES["train_4k"], step=0, seed=1,
                       batch_override=2, seq_override=16)
    params, opt, m = step(params, opt, batch)
    assert np.isfinite(float(m["loss"]))
    assert np.isfinite(float(m["aux"]))


def test_grad_clip_bounds_update():
    x = {"w": jnp.ones((4, 4)) * 1e6}
    assert float(global_norm(x)) == pytest.approx(4e6)
    ocfg = OptConfig(clip_norm=1.0, lr=1.0, warmup=0, weight_decay=0.0)
    state = opt_init(x)
    new_x, _, metrics = update(ocfg, x, x, state)
    assert float(metrics["grad_norm"]) == pytest.approx(4e6, rel=1e-3)
    # clipped: per-element grad after scale is tiny -> update bounded by lr
    assert float(jnp.abs(new_x["w"] - x["w"]).max()) <= 1.01 * 1.0 * 2


def test_schedule_warmup_and_decay():
    ocfg = OptConfig(lr=1.0, warmup=10, total_steps=100)
    assert float(schedule(ocfg, jnp.int32(5))) == pytest.approx(0.5)
    assert float(schedule(ocfg, jnp.int32(10))) == pytest.approx(1.0)
    assert float(schedule(ocfg, jnp.int32(100))) == pytest.approx(0.1,
                                                                  abs=1e-3)


def test_data_pipeline_deterministic_skip():
    cfg = get_smoke_config("internlm2-1.8b")
    it1 = DataIterator(cfg, SHAPES["train_4k"], seed=3, batch_override=2,
                       seq_override=8)
    for _ in range(5):
        next(it1)
    s5, b5 = next(it1)
    it2 = DataIterator(cfg, SHAPES["train_4k"], seed=3, batch_override=2,
                       seq_override=8)
    it2.skip_to(5)
    s5b, b5b = next(it2)
    assert s5 == s5b == 5
    np.testing.assert_array_equal(np.asarray(b5["tokens"]),
                                  np.asarray(b5b["tokens"]))


def test_train_restart_equivalence(tmp_path):
    """10 straight steps == 5 steps + checkpoint + restore + 5 steps."""
    from repro.ckpt import Checkpointer
    cfg, params, opt, step = _tiny_setup()
    shape = SHAPES["train_4k"]

    def run(params, opt, start, n):
        it = DataIterator(cfg, shape, seed=5, batch_override=2,
                          seq_override=16)
        it.skip_to(start)
        for _ in range(n):
            _, batch = next(it)
            params, opt, m = step(params, opt, batch)
        return params, opt

    pa, oa = run(params, opt, 0, 10)

    pb, ob = run(params, opt, 0, 5)
    ck = Checkpointer(str(tmp_path / "ck"))
    ck.save(5, {"params": pb, "opt": ob})
    st, restored = ck.restore({"params": pb, "opt": ob})
    assert st == 5
    pc, oc = run(restored["params"], restored["opt"], 5, 5)

    for la, lc in zip(jax.tree.leaves(pa), jax.tree.leaves(pc)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lc))


def test_serve_step_greedy():
    from repro.models import init_cache
    from repro.train import make_serve_step
    cfg = get_smoke_config("internlm2-1.8b")
    params = init_model(cfg, jax.random.PRNGKey(0))
    serve = jax.jit(make_serve_step(cfg))
    cache = init_cache(cfg, 2, 16)
    tok = jnp.ones((2, 1), jnp.int32)
    for _ in range(4):
        tok, cache = serve(params, cache, tok)
    assert tok.shape == (2, 1)
    assert int(cache["length"]) == 4


def test_microbatched_grads_match_full_batch():
    """H9: 4-way gradient accumulation == full-batch step (same update)."""
    import jax
    cfg = get_smoke_config("internlm2-1.8b")
    key = jax.random.PRNGKey(9)
    params = init_model(cfg, key)
    opt = opt_init(params)
    ocfg = OptConfig(lr=1e-2, warmup=0, total_steps=10)
    batch = make_batch(cfg, SHAPES["train_4k"], step=0, seed=2,
                       batch_override=8, seq_override=16)
    full = jax.jit(make_train_step(cfg, ocfg))
    micro = jax.jit(make_train_step(cfg, ocfg, microbatches=4))
    pf, of, mf = full(params, opt, batch)
    pm, om, mm = micro(params, opt, batch)
    assert abs(float(mf["loss"]) - float(mm["loss"])) < 1e-4
    assert abs(float(mf["grad_norm"]) - float(mm["grad_norm"])) < 1e-3
    # Adam's first-step update is ~sign(g)*lr, so near-zero grads that
    # flip sign under bf16 accumulation-order noise move a param by
    # up to 2*lr; bound by that, and require the bulk to be tight.
    lr = 1e-2
    for a, b in zip(jax.tree.leaves(pf), jax.tree.leaves(pm)):
        a, b = np.asarray(a), np.asarray(b)
        np.testing.assert_allclose(a, b, atol=2.1 * lr, rtol=0)
        frac_tight = np.mean(np.abs(a - b) < 1e-4)
        assert frac_tight > 0.99, frac_tight
