"""End-to-end behaviour tests: the paper's claims on small lattices,
plus the full sim driver + trajectory machinery."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import observables as obs
from repro.core.sim import SimConfig, Simulation


def test_magnetization_tracks_onsager():
    """Fig. 5 analogue: simulated steady-state |m| vs the exact solution
    at temperatures away from Tc (finite-size effects are small there)."""
    for temp in (1.5, 2.0):
        sim = Simulation(SimConfig(n=96, m=96, temperature=temp, seed=11,
                                   engine="multispin", init_p_up=1.0))
        sim.run(400)
        samples = sim.trajectory(n_measure=20, sweeps_between=10)
        m = float(np.abs(samples).mean())
        exact = float(obs.onsager_magnetization(temp))
        assert abs(m - exact) < 0.05, (temp, m, exact)


def test_disorder_above_tc():
    sim = Simulation(SimConfig(n=96, m=96, temperature=4.0, seed=13,
                               engine="multispin"))
    sim.run(200)
    samples = sim.trajectory(n_measure=20, sweeps_between=5)
    assert float(np.abs(samples).mean()) < 0.12


def test_binder_ordering_across_tc():
    """Fig. 6 analogue: U_L ~ 2/3 below Tc, small above Tc."""
    below = Simulation(SimConfig(n=48, m=48, temperature=1.8, seed=17,
                                 engine="multispin", init_p_up=1.0))
    below.run(300)
    u_below = float(obs.binder_cumulant(jnp.asarray(
        below.trajectory(30, 5))))
    above = Simulation(SimConfig(n=48, m=48, temperature=4.5, seed=19,
                                 engine="multispin"))
    above.run(300)
    u_above = float(obs.binder_cumulant(jnp.asarray(
        above.trajectory(30, 5))))
    assert u_below > 0.6
    assert u_above < 0.35
    assert u_below > u_above


def test_engines_statistically_agree():
    """All engines sample the same distribution: steady-state |m| within
    tolerance of each other at T=2.0."""
    mags = {}
    for engine in ("basic", "basic_philox", "multispin", "tensorcore"):
        sim = Simulation(SimConfig(n=64, m=64, temperature=2.0, seed=23,
                                   engine=engine, tc_block=8,
                                   init_p_up=1.0))
        sim.run(300)
        samples = sim.trajectory(15, 5)
        mags[engine] = float(np.abs(samples).mean())
    exact = float(obs.onsager_magnetization(2.0))
    for engine, m in mags.items():
        assert abs(m - exact) < 0.06, (engine, mags)


def test_sim_energy_decreases_on_quench():
    sim = Simulation(SimConfig(n=64, m=64, temperature=1.2, seed=29,
                               engine="basic_philox"))
    e0 = sim.energy()
    sim.run(100)
    assert sim.energy() < e0
