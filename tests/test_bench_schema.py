"""Perf-record schema (repro.perf.schema): golden-file validation of
the committed BENCH baselines, every ``benchmarks/run.py --json``
emission, RunRecorder output, and the violation catalogue."""
import copy
import glob
import json
import os
import subprocess
import sys

import pytest

from repro.analysis.recorder import RunRecorder, timing_stats
from repro.api import EngineSpec, LatticeSpec, RunSpec, SweepSpec
from repro.perf.schema import SchemaError, validate_record, validate_row

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _valid_row(**over):
    row = {"name": "t1_x", "us_per_call": 10.0,
           "derived": {"flips_per_ns": 1.5, "engine": "multispin"}}
    row.update(over)
    return row


def _valid_record(rows=None):
    return {"meta": {"stamp": "20260807_000000", "backend": "cpu",
                     "device_count": 1},
            "rows": rows if rows is not None else [_valid_row()]}


# ---------------------------------------------------------------------------
# golden files: the committed baselines are history and must stay valid
# ---------------------------------------------------------------------------

def test_committed_baselines_validate():
    paths = sorted(glob.glob(os.path.join(REPO, "benchmarks",
                                          "BENCH_*.json")))
    assert len(paths) >= 2, \
        "trend needs >= 2 committed BENCH records"
    for path in paths:
        with open(path) as f:
            validate_record(json.load(f), ctx=os.path.basename(path))


def test_newest_committed_baseline_carries_noise_model():
    path = sorted(glob.glob(os.path.join(REPO, "benchmarks",
                                         "BENCH_*.json")))[-1]
    with open(path) as f:
        rec = json.load(f)
    timed = [r for r in rec["rows"] if r["us_per_call"] > 0]
    with_stats = [r for r in timed if "n_trials" in r]
    assert with_stats, f"{path}: no noise-model rows"
    for r in with_stats:
        if r["n_trials"] >= 2:
            assert "iqr_us_per_call" in r
    # roofline attribution: every timed engine row self-reports its
    # fraction of the flip-cost-model peak
    with_pct = [r for r in timed
                if "pct_of_roofline" in r.get("derived", {})]
    assert with_pct, f"{path}: no pct_of_roofline attribution"
    for r in with_pct:
        assert 0.0 <= r["derived"]["pct_of_roofline"] <= 100.0


# ---------------------------------------------------------------------------
# emission paths: RunRecorder and the run.py CLI
# ---------------------------------------------------------------------------

def test_recorder_emission_validates():
    rec = RunRecorder(meta={"stamp": "20260807_000000",
                            "backend": "cpu", "device_count": 1})
    rec.record("legacy_row", 12.5, flips_per_ns=0.5)
    rec.record("noisy_row", 10.0,
               times_us=[9.0, 10.0, 11.0, 10.5, 9.5],
               flips_per_ns=1.0, engine="multispin")
    spec = RunSpec(lattice=LatticeSpec(64, 64),
                   engine=EngineSpec("multispin"),
                   temperature=2.27, seed=7,
                   sweep=SweepSpec(thermalize=5, measure_every=2,
                                   n_measure=3))
    rec.record("spec_row", 20.0, spec=spec.to_json(),
               times_us=[20.0], flips_per_ns=2.0)
    validate_record({"meta": rec.meta, "rows": rec.rows})
    noisy = rec.rows[1]
    assert noisy["n_trials"] == 5
    assert noisy["median_us_per_call"] == pytest.approx(10.0)
    assert "iqr_us_per_call" in noisy
    # single-trial rows get a median but never an IQR
    assert rec.rows[2]["n_trials"] == 1
    assert "iqr_us_per_call" not in rec.rows[2]


def test_timing_stats_single_trial_has_no_iqr():
    assert timing_stats([42.0]) == {"n_trials": 1,
                                    "median_us_per_call": 42.0}
    assert timing_stats([]) == {}
    stats = timing_stats([1.0, 2.0, 3.0, 4.0])
    assert stats["n_trials"] == 4
    assert stats["median_us_per_call"] == pytest.approx(2.5)
    assert stats["iqr_us_per_call"] == pytest.approx(1.5)


@pytest.mark.slow
def test_run_py_json_emission_validates(tmp_path):
    """Every `run.py --json` emission passes the schema -- exercised
    end-to-end on the cheapest bench subset."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"),
               JAX_PLATFORMS="cpu")
    subprocess.run(
        [sys.executable, os.path.join(REPO, "benchmarks", "run.py"),
         "--only", "kernel_block", "--trials", "2",
         "--json", str(tmp_path)],
        check=True, env=env, timeout=600, cwd=REPO)
    (path,) = glob.glob(str(tmp_path / "BENCH_*.json"))
    with open(path) as f:
        rec = json.load(f)
    validate_record(rec)
    assert rec["meta"]["trials"] == 2
    assert rec["meta"]["only"] == "kernel_block"
    for row in rec["rows"]:
        assert row["n_trials"] == 2
        assert "iqr_us_per_call" in row


# ---------------------------------------------------------------------------
# violation catalogue
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mutate, match", [
    (lambda r: r.pop("name"), "name"),
    (lambda r: r.update(name=""), "name"),
    (lambda r: r.update(typo_key=1), "unknown row keys"),
    (lambda r: r.pop("us_per_call"), "us_per_call"),
    (lambda r: r.update(us_per_call=-1.0), ">= 0"),
    (lambda r: r.update(us_per_call=float("nan")), "finite"),
    (lambda r: r.update(us_per_call="fast"), "number"),
    (lambda r: r.update(derived=[1, 2]), "derived must be a dict"),
    (lambda r: r.update(derived={"flips_per_ns": -2.0}), ">= 0"),
    (lambda r: r.update(derived={"note": None}), "str or number"),
    (lambda r: r.update(n_trials=0, median_us_per_call=1.0), ">= 1"),
    (lambda r: r.update(n_trials=True, median_us_per_call=1.0), "int"),
    (lambda r: r.update(n_trials=3, median_us_per_call=1.0),
     "requires iqr"),
    (lambda r: r.update(n_trials=5), "median"),
    (lambda r: r.update(n_trials=1, median_us_per_call=1.0,
                        iqr_us_per_call=0.0), "single trial"),
    (lambda r: r.update(median_us_per_call=1.0), "without n_trials"),
    (lambda r: r.update(iqr_us_per_call=1.0), "without n_trials"),
    (lambda r: r.update(spec=123), "JSON string"),
    (lambda r: r.update(spec="not json"), "not valid JSON"),
    (lambda r: r.update(spec="[1, 2]"), "object"),
    (lambda r: r.update(spec='{"lattice": "nope"}'), "RunSpec"),
])
def test_invalid_rows_raise(mutate, match):
    row = _valid_row()
    mutate(row)
    with pytest.raises(SchemaError, match=match):
        validate_row(row)


def test_valid_spec_row_passes():
    spec = RunSpec(lattice=LatticeSpec(32, 32),
                   engine=EngineSpec("basic"), temperature=2.0, seed=1,
                   sweep=SweepSpec(thermalize=1, measure_every=1,
                                   n_measure=1))
    validate_row(_valid_row(spec=spec.to_json()))


@pytest.mark.parametrize("mutate, match", [
    (lambda r: r.pop("meta"), "meta"),
    (lambda r: r["meta"].pop("stamp"), "stamp"),
    (lambda r: r["meta"].pop("backend"), "backend"),
    (lambda r: r["meta"].pop("device_count"), "device_count"),
    (lambda r: r.update(rows=[]), "non-empty"),
    (lambda r: r.update(rows={}), "non-empty"),
    (lambda r: r.update(extra_top=1), "unknown top-level"),
])
def test_invalid_records_raise(mutate, match):
    rec = _valid_record()
    mutate(rec)
    with pytest.raises(SchemaError, match=match):
        validate_record(rec)


def test_duplicate_row_names_raise():
    rec = _valid_record(rows=[_valid_row(), copy.deepcopy(_valid_row())])
    with pytest.raises(SchemaError, match="duplicate row name"):
        validate_record(rec)
