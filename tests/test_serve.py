"""Serve subsystem: durable journal, typed admission, coalescing, and
the exactly-once sweep farm (DESIGN.md S14)."""
import json
import os
import time

import numpy as np
import pytest

import repro.telemetry as tel
from repro.api import (BatchSpec, EngineSpec, LatticeSpec, RunSpec,
                       SweepSpec)
from repro.api.session import Session
from repro.api.spec import MAX_BATCH_SEED
from repro.resilience import TransientDispatchError, degrade, faults
from repro.serve import (AdmissionError, DrainingError, Journal,
                         JournalError, QueueFullError, SweepFarm)
from repro.serve.journal import JOURNAL_NAME, job_table, replay
from repro.serve.scheduler import (Job, coalesce_key, parse_envelope,
                                   plan_batches)
from repro.serve import server as serve_server


@pytest.fixture(autouse=True)
def _clean_resilience_state():
    """Faults and demotions are process-global by design; tests must
    not leak them into each other."""
    faults.clear()
    degrade.reset_demotions()
    yield
    faults.clear()
    degrade.reset_demotions()


@pytest.fixture
def nosleep(monkeypatch):
    """Retry without wall-clock backoff."""
    monkeypatch.setattr(degrade, "DEFAULT_POLICY",
                        degrade.RetryPolicy(sleep=lambda d: None))


def _spec(engine="multispin", n=16, m=32, temperature=2.1, seed=7,
          **kw):
    return RunSpec(lattice=LatticeSpec(n, m),
                   engine=EngineSpec(engine),
                   temperature=temperature, seed=seed, **kw)


def _job(jid, spec, sweeps=32, timeout_s=None):
    return Job(id=jid, spec=spec, sweeps=sweeps, timeout_s=timeout_s,
               submitted_t=0.0)


def _direct_digest(spec, sweeps):
    s = Session.open(spec)
    s.run(sweeps)
    return s.state_digest()


# ---------------------------------------------------------------------------
# journal: durability framing + torn-write recovery (the resilience
# corrupters reproduce the crash topologies)
# ---------------------------------------------------------------------------

_RECORDS = [{"kind": "submit", "job": "j1", "x": 1},
            {"kind": "start", "batch": "b1", "jobs": ["j1"]},
            {"kind": "done", "job": "j1", "status": "completed"}]


def _write_journal(path, records=_RECORDS):
    with Journal(str(path)) as j:
        for r in records:
            j.append(r)
    return str(path)


def test_journal_roundtrip(tmp_path):
    path = _write_journal(tmp_path / JOURNAL_NAME)
    with Journal(path) as j:
        assert j.records == _RECORDS
        assert j.recovered_tail is None
    assert list(replay(path)) == _RECORDS


def test_journal_append_validation(tmp_path):
    with Journal(str(tmp_path / JOURNAL_NAME)) as j:
        with pytest.raises(JournalError, match="dicts with a 'kind'"):
            j.append(["not", "a", "dict"])
        with pytest.raises(JournalError, match="dicts with a 'kind'"):
            j.append({"job": "j1"})


def test_journal_torn_tail_recovers_to_last_whole_record(tmp_path):
    path = _write_journal(tmp_path / JOURNAL_NAME)
    size = os.path.getsize(path)
    faults.truncate_file(path, size - 7)  # tear the final record
    with Journal(path) as j:
        assert j.records == _RECORDS[:2]
        assert j.recovered_tail is not None
        assert os.path.exists(j.recovered_tail)
        # the torn bytes are quarantined, not destroyed
        with open(j.recovered_tail, "rb") as f:
            assert b"done" in f.read()
        j.append(_RECORDS[2])  # appending after recovery is normal
    with Journal(path) as j:
        assert j.records == _RECORDS
        assert j.recovered_tail is None


def test_journal_bitrot_in_tail_is_quarantined(tmp_path):
    path = _write_journal(tmp_path / JOURNAL_NAME)
    size = os.path.getsize(path)
    faults.flip_byte_in_file(path, offset=size - 5)
    with Journal(path) as j:
        assert j.records == _RECORDS[:2]
        assert j.recovered_tail is not None


def test_journal_midfile_corruption_raises(tmp_path):
    path = _write_journal(tmp_path / JOURNAL_NAME)
    # damage the FIRST record while valid ones follow: an append-only
    # fsync'd writer cannot produce this, so replay must refuse to
    # silently drop the acknowledged tail
    faults.flip_byte_in_file(path, offset=12)
    with pytest.raises(JournalError, match="AFTER damaged"):
        Journal(path)


def test_job_table_enforces_exactly_once():
    sub = {"kind": "submit", "job": "j1"}
    done = {"kind": "done", "job": "j1", "status": "completed"}
    jobs, dones = job_table([sub, done])
    assert list(jobs) == ["j1"] and dones["j1"] is done
    with pytest.raises(JournalError, match="duplicate submit"):
        job_table([sub, sub])
    with pytest.raises(JournalError, match="unknown job"):
        job_table([done])
    with pytest.raises(JournalError, match="exactly-once"):
        job_table([sub, done, done])


# ---------------------------------------------------------------------------
# admission: every malformation is a typed reject, never a crash
# ---------------------------------------------------------------------------

def test_parse_envelope_accepts_envelope_and_bare_spec():
    spec = _spec()
    got, sweeps, timeout = parse_envelope(
        {"spec": spec.to_dict(), "sweeps": 64, "timeout_s": 5})
    assert got.to_dict() == spec.to_dict()
    assert sweeps == 64 and timeout == 5.0
    bare = _spec(sweep=SweepSpec(thermalize=8, n_measure=4))
    got, sweeps, timeout = parse_envelope(bare.to_dict())
    assert sweeps == bare.sweep.total_sweeps and timeout is None


@pytest.mark.parametrize("doc,match", [
    ("not a dict", "must be a JSON object"),
    ({"spec": {}, "swweeps": 3}, "unknown key"),
    ({"spec": {"bogus": 1}, "sweeps": 3}, "bad RunSpec"),
    ({"spec": _spec().to_dict()}, "no sweep target"),
    ({"spec": _spec().to_dict(), "sweeps": 0}, "positive integer"),
    ({"spec": _spec().to_dict(), "sweeps": True}, "positive integer"),
    ({"spec": _spec().to_dict(), "sweeps": 4, "timeout_s": -1},
     "positive number"),
])
def test_parse_envelope_rejects_typed(doc, match):
    with pytest.raises(AdmissionError, match=match):
        parse_envelope(doc)


# ---------------------------------------------------------------------------
# coalescing: deterministic grouping, bit-exactness preconditions
# ---------------------------------------------------------------------------

def test_coalesce_key_preconditions():
    assert coalesce_key(_job("j1", _spec())) is not None
    # key-based engines' digests depend on the chunk grid: never fuse
    assert coalesce_key(_job("j2", _spec(engine="basic"))) is None
    # the ensemble bit-exactness contract bounds member seeds
    assert coalesce_key(
        _job("j3", _spec(seed=MAX_BATCH_SEED))) is None
    assert coalesce_key(_job("j4", _spec(
        batch=BatchSpec(temperatures=(2.0, 2.2))))) is None
    # the sweep target is part of the key: members must stop together
    a = coalesce_key(_job("j5", _spec(), sweeps=32))
    b = coalesce_key(_job("j6", _spec(), sweeps=64))
    assert a is not None and b is not None and a != b


def test_plan_batches_groups_chunks_and_orders():
    co = [_job(f"j{i}", _spec(temperature=2.0 + 0.1 * i, seed=i))
          for i in range(3)]
    solo = _job("j9", _spec(engine="basic"))
    batches = plan_batches([co[0], co[1], solo, co[2]], max_batch=2)
    assert [[j.id for j in b.jobs] for b in batches] \
        == [["j0", "j1"], ["j2"], ["j9"]]
    assert [b.coalesced for b in batches] == [True, True, False]
    fused = batches[0].spec()
    assert fused.mode == "ensemble"
    assert fused.batch.temperatures == (2.0, 2.1)
    assert fused.batch.seeds == (0, 1)


def test_plan_batches_is_deterministic():
    jobs = [_job(f"j{i}", _spec(seed=i)) for i in range(4)]
    a = plan_batches(jobs, max_batch=8)
    b = plan_batches(list(jobs), max_batch=8)
    assert [x.id for x in a] == [y.id for y in b]
    # ids hash (key, member ids): a different grouping is a new batch
    c = plan_batches(jobs[:3], max_batch=8)
    assert c[0].id != a[0].id
    with pytest.raises(ValueError, match="max_batch"):
        plan_batches(jobs, max_batch=0)


# ---------------------------------------------------------------------------
# the farm: coalesced dispatch is digest-preserving and exactly-once
# ---------------------------------------------------------------------------

SWEEPS = 32


def _farm(tmp_path, **kw):
    kw.setdefault("chunk", SWEEPS)  # one compiled dispatch per batch
    return SweepFarm(str(tmp_path / "farm"), **kw)


def _submit(farm, spec, sweeps=SWEEPS, **extra):
    return farm.submit({"spec": spec.to_dict(), "sweeps": sweeps,
                        **extra})


def test_farm_coalesces_and_preserves_digests(tmp_path):
    specs = [_spec(temperature=2.0 + 0.1 * i, seed=20 + i)
             for i in range(3)]
    refs = [_direct_digest(s, SWEEPS) for s in specs]
    farm = _farm(tmp_path)
    jids = [_submit(farm, s) for s in specs]
    before = tel.DISPATCHES.value
    assert farm.run_until_idle() == 1  # one fused batch
    assert tel.DISPATCHES.value - before == 1  # one compiled dispatch
    for jid, want in zip(jids, refs):
        job = farm.job(jid)
        assert job["status"] == "completed"
        assert job["digest"] == want
        assert job["summary"]["coalesced"] == 3
        # the result file is the queryable artifact
        with open(os.path.join(farm.results_dir,
                               f"{jid}.json")) as f:
            assert json.load(f)["digest"] == want
    assert farm.idle
    farm.close()


def test_farm_keeps_incompatible_jobs_apart(tmp_path):
    farm = _farm(tmp_path)
    _submit(farm, _spec(seed=1))
    _submit(farm, _spec(engine="basic", seed=2))  # key-based: solo
    assert farm.run_until_idle() == 2
    assert all(j.terminal for j in farm.jobs.values())
    farm.close()


def test_farm_restart_is_exactly_once(tmp_path):
    specs = [_spec(temperature=2.0 + 0.1 * i, seed=30 + i)
             for i in range(2)]
    farm = _farm(tmp_path)
    jids = [_submit(farm, s) for s in specs]
    farm.run_until_idle()
    digests = [farm.job(j)["digest"] for j in jids]
    farm.close()
    # restart: replay must restore the terminal states and re-run
    # NOTHING (dispatches delta 0)
    before = tel.DISPATCHES.value
    farm2 = _farm(tmp_path)
    assert farm2.run_until_idle() == 0
    assert tel.DISPATCHES.value - before == 0
    assert [farm2.job(j)["digest"] for j in jids] == digests
    # the only path to a terminal state refuses a second done record
    with pytest.raises(JournalError, match="exactly-once"):
        farm2._finish(farm2.jobs[jids[0]], "completed")
    farm2.close()


def test_farm_runner_pool_reuses_compiled_dispatch(tmp_path):
    farm = _farm(tmp_path)
    for i in range(2):
        _submit(farm, _spec(temperature=2.0 + 0.1 * i, seed=40 + i))
    farm.run_until_idle()
    assert farm.status()["runner_pool"] == 1
    # a second wave of the same dispatch shape rebinds the pooled
    # runner: zero recompiles, one dispatch, digests still bit-exact
    spec2 = [_spec(temperature=2.3 + 0.1 * i, seed=50 + i)
             for i in range(2)]
    hits = serve_server.CACHE_HITS.value
    before = tel.DISPATCHES.value
    jids = [_submit(farm, s) for s in spec2]
    farm.run_until_idle()
    assert serve_server.CACHE_HITS.value - hits == 1
    assert tel.DISPATCHES.value - before == 1
    for jid, s in zip(jids, spec2):
        assert farm.job(jid)["digest"] == _direct_digest(s, SWEEPS)
    farm.close()


def test_farm_backpressure_and_drain_rejects(tmp_path):
    farm = _farm(tmp_path, max_queue=1)
    rejected = serve_server.REJECTED.value
    with pytest.raises(AdmissionError):
        farm.submit({"spec": {"bogus": 1}, "sweeps": 4})
    _submit(farm, _spec())
    with pytest.raises(QueueFullError, match="capacity"):
        _submit(farm, _spec(seed=8))
    farm.request_drain()
    assert farm.status()["draining"]
    with pytest.raises(DrainingError, match="draining"):
        _submit(farm, _spec(seed=9))
    assert serve_server.REJECTED.value - rejected == 3
    farm.close()


def test_farm_deadline_fails_queued_job_without_running_it(tmp_path):
    farm = _farm(tmp_path)
    jid = _submit(farm, _spec(), timeout_s=1e-6)
    time.sleep(0.01)
    before = tel.DISPATCHES.value
    assert farm.run_until_idle() == 0  # expired before dispatch
    assert tel.DISPATCHES.value - before == 0
    job = farm.job(jid)
    assert job["status"] == "failed"
    assert "deadline exceeded" in job["error"]
    farm.close()


def test_farm_transient_fault_retries_bit_exact(tmp_path, nosleep):
    want = _direct_digest(_spec(seed=61), SWEEPS)
    farm = _farm(tmp_path)
    retries = tel.REGISTRY.counter("resilience.retry").value
    with faults.injected(faults.FaultPlan(transient_dispatches=1)):
        jid = _submit(farm, _spec(seed=61))
        farm.run_until_idle()
    assert tel.REGISTRY.counter("resilience.retry").value > retries
    job = farm.job(jid)
    assert job["status"] == "completed" and job["digest"] == want
    farm.close()


def test_farm_job_failure_is_contained(tmp_path, nosleep):
    farm = _farm(tmp_path)
    # enough injected faults to exhaust the bounded retry budget: the
    # job fails, the farm survives and keeps serving
    with faults.injected(faults.FaultPlan(transient_dispatches=100)):
        jid = _submit(farm, _spec(seed=62))
        farm.run_until_idle()
    job = farm.job(jid)
    assert job["status"] == "failed"
    assert TransientDispatchError.__name__ in job["error"]
    jid2 = _submit(farm, _spec(seed=63))
    farm.run_until_idle()
    assert farm.job(jid2)["status"] == "completed"
    farm.close()


def test_farm_recovers_from_torn_journal(tmp_path):
    farm = _farm(tmp_path)
    jid = _submit(farm, _spec(seed=64))
    farm.close()
    path = os.path.join(farm.dir, JOURNAL_NAME)
    size = os.path.getsize(path)
    with open(path, "ab") as f:  # a submit append the crash tore
        f.write(b"deadbeef {\"kind\": \"sub")
    farm2 = _farm(tmp_path)
    assert list(farm2.jobs) == [jid]  # the acked job survived
    assert farm2.jobs[jid].status == "queued"
    assert os.path.getsize(path) == size
    farm2.run_until_idle()
    assert farm2.job(jid)["status"] == "completed"
    farm2.close()


# ---------------------------------------------------------------------------
# the session primitives the farm's bit-exactness rests on
# ---------------------------------------------------------------------------

def test_state_digest_member_matches_single_runs():
    temps, seeds = (2.0, 2.4), (3, 5)
    ens = Session.open(_spec(batch=BatchSpec(temperatures=temps,
                                             seeds=seeds)))
    ens.run(SWEEPS)
    for i, (t, s) in enumerate(zip(temps, seeds)):
        want = _direct_digest(_spec(temperature=t, seed=s), SWEEPS)
        assert ens.state_digest(member=i) == want
    with pytest.raises(ValueError, match="member"):
        ens.state_digest(member=7)
    single = Session.open(_spec())
    with pytest.raises(ValueError, match="member"):
        single.state_digest(member=0)


def test_rebind_validates_shape_and_is_bit_exact():
    ens = Session.open(_spec(batch=BatchSpec(temperatures=(2.0, 2.2),
                                             seeds=(1, 2))))
    runner = ens._runner
    with pytest.raises(ValueError, match="ensemble"):
        runner.rebind(_spec())
    with pytest.raises(ValueError):  # batch size is part of the shape
        runner.rebind(_spec(batch=BatchSpec(
            temperatures=(2.0, 2.2, 2.4), seeds=(1, 2, 3))))
    with pytest.raises(ValueError):  # so is the lattice
        runner.rebind(_spec(n=32, m=32, batch=BatchSpec(
            temperatures=(2.0, 2.2), seeds=(1, 2))))
    # a shape-compatible rebind replays the new members bit-exactly
    spec2 = _spec(batch=BatchSpec(temperatures=(2.1, 2.5),
                                  seeds=(8, 9)))
    runner.rebind(spec2)
    rebound = Session(spec2, runner=runner)
    rebound.run(SWEEPS)
    fresh = Session.open(spec2)
    fresh.run(SWEEPS)
    assert rebound.state_digest() == fresh.state_digest()


# ---------------------------------------------------------------------------
# MeshSpec submissions: solo execution or typed rejection (never a crash)
# ---------------------------------------------------------------------------

def test_farm_mesh_job_runs_solo_bit_exact(tmp_path):
    from repro.api import MeshSpec
    spec = _spec(engine="stencil_pallas", n=32, m=32,
                 mesh=MeshSpec(shape=(1, 1)))
    want = _direct_digest(spec, SWEEPS)
    farm = _farm(tmp_path)
    jid = _submit(farm, spec)
    _submit(farm, _spec(seed=40))      # a coalescible job alongside
    assert coalesce_key(farm.jobs[jid]) is None  # mesh -> never fused
    assert farm.run_until_idle() == 2  # two batches: mesh job ran solo
    job = farm.job(jid)
    assert job["status"] == "completed"
    assert job["digest"] == want       # sharded digest == direct run
    farm.close()


def test_farm_rejects_oversized_mesh_typed(tmp_path):
    from repro.api import MeshSpec
    farm = _farm(tmp_path)
    with pytest.raises(AdmissionError, match="devices"):
        _submit(farm, _spec(mesh=MeshSpec(shape=(2, 4))))
    # the typed rejection queued nothing and the farm still serves
    ok = _submit(farm, _spec(seed=50))
    assert farm.run_until_idle() == 1
    assert farm.job(ok)["status"] == "completed"
    farm.close()
