"""Measurement & analysis subsystem: fused scan contract + estimators."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import (MeasurementPlan, RunRecorder, Welford, binder,
                            binder_crossing, blocking_error, jackknife,
                            parse_derived, specific_heat, susceptibility,
                            tau_int)
from repro.core import observables as obs
from repro.core.engine import ENGINES
from repro.core.ensemble import Ensemble
from repro.core.sim import SimConfig, Simulation

# ---------------------------------------------------------------------------
# fused scan: bit-identity with the legacy python loop, one dispatch
# ---------------------------------------------------------------------------


def _legacy_trajectory(sim, n_measure, sweeps_between, thermalize=0):
    """The pre-analysis-subsystem measurement loop: one dispatch and one
    host round-trip per sample."""
    if thermalize:
        sim.run(thermalize)
    out = np.empty(n_measure, np.float32)
    for i in range(n_measure):
        sim.run(sweeps_between)
        out[i] = sim.magnetization()
    return out


@pytest.mark.parametrize("engine", ["multispin", "basic_philox"])
def test_scan_trajectory_bitexact_vs_python_loop(engine):
    cfg = dict(n=16, m=16, temperature=2.2, seed=7, engine=engine)
    a = Simulation(SimConfig(**cfg))
    legacy = _legacy_trajectory(a, 12, 2, thermalize=4)
    b = Simulation(SimConfig(**cfg))
    scan = b.trajectory(12, 2, thermalize=4)
    np.testing.assert_array_equal(legacy, scan)
    # the final engine states agree too, so a checkpoint after a fused
    # measurement continues the identical Philox stream
    np.testing.assert_array_equal(np.asarray(a.full_lattice()),
                                  np.asarray(b.full_lattice()))
    assert a.step_count == b.step_count == 4 + 12 * 2


def test_scan_trajectory_is_one_dispatch():
    import repro.telemetry as tel
    sim = Simulation(SimConfig(n=16, m=16, temperature=2.0, seed=1,
                               engine="multispin"))
    before = tel.DISPATCHES.value
    sim.trajectory(32, 2, thermalize=8)
    assert tel.DISPATCHES.value - before == 1  # legacy: 33 dispatches


def test_measure_fields_and_step_accounting():
    sim = Simulation(SimConfig(n=16, m=16, temperature=2.0, seed=2,
                               engine="basic_philox"))
    plan = MeasurementPlan(n_measure=5, sweeps_between=3, thermalize=4)
    traj = sim.measure(plan)
    assert set(traj) == {"m", "e"}
    assert traj["m"].shape == traj["e"].shape == (5,)
    assert traj["m"].dtype == np.float32
    assert sim.step_count == plan.total_sweeps == 4 + 5 * 3


def test_ensemble_measure_matches_member_simulations():
    temps, seeds = [1.8, 2.5], [3, 4]
    ens = Ensemble(16, 16, temps, seeds, engine="multispin")
    traj = ens.trajectory(6, 2, thermalize=2)
    assert traj.shape == (6, 2)
    for i, (T, s) in enumerate(zip(temps, seeds)):
        sim = Simulation(SimConfig(n=16, m=16, temperature=T, seed=s,
                                   engine="multispin"))
        np.testing.assert_array_equal(sim.trajectory(6, 2, thermalize=2),
                                      traj[:, i], err_msg=f"member {i}")


def test_measurement_plan_validation():
    with pytest.raises(AssertionError):
        MeasurementPlan(0, 1)
    with pytest.raises(AssertionError):
        MeasurementPlan(1, 1, thermalize=-1)
    assert MeasurementPlan(1, 1, fields=["m"]).fields == ("m",)


# ---------------------------------------------------------------------------
# engine observables hook: energy correct for every state layout
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("engine", sorted(ENGINES))
def test_observables_hook_energy_ground_state(engine):
    """All-up lattice: e = -2 for every uniform-J engine (each spin has 4
    aligned bonds counted once per pair); spinglass weights its quenched
    couplings instead, so e = -<J> over bonds.  Replicated engines
    (bitplane) return per-replica vectors; from_full broadcasts, so
    every replica must agree."""
    cfg = SimConfig(n=16, m=16, temperature=2.0, seed=5, engine=engine,
                    tc_block=4)
    sim = Simulation(cfg)
    state = sim.engine.from_full(jnp.ones((16, 16), jnp.int8))
    o = sim.engine.observables(state, jnp.float32(cfg.inv_temp))
    m = np.asarray(o["m"], np.float32)
    assert m.size == sim.engine.replicas
    assert (m == 1.0).all()
    if engine == "spinglass":
        _, j_up, j_left = state
        expect = -(np.asarray(j_up, np.float32).sum()
                   + np.asarray(j_left, np.float32).sum()) / 256.0
        assert float(o["e"]) == pytest.approx(expect)
    else:
        assert (np.asarray(o["e"], np.float32) == -2.0).all()


@pytest.mark.parametrize("engine", sorted(ENGINES))
def test_sim_energy_routes_through_hook(engine):
    sim = Simulation(SimConfig(n=16, m=16, temperature=2.0, seed=6,
                               engine=engine, tc_block=4))
    sim.run(2)
    hook = np.asarray(sim.engine.observables(
        sim.state, jnp.float32(sim.config.inv_temp))["e"], np.float32)
    # scalar engines: exact identity; replicated engines: replica mean
    assert sim.energy() == pytest.approx(float(hook.mean()), rel=1e-6)
    # layout-independent oracle on the full-lattice view (replica 0 for
    # replicated engines -- full_lattice is the replica-0 view)
    if engine != "spinglass":
        full = sim.full_lattice()
        assert hook.reshape(-1)[0] == float(obs.energy_per_spin_full(full))


# ---------------------------------------------------------------------------
# estimators
# ---------------------------------------------------------------------------


def test_welford_matches_numpy_and_merges():
    rng = np.random.default_rng(0)
    x = rng.normal(2.0, 3.0, size=10_000)
    w = Welford().push(x[:3000])
    w.merge(Welford().push(x[3000:]))
    assert w.n == x.size
    assert w.mean == pytest.approx(x.mean(), rel=1e-12)
    assert w.var == pytest.approx(x.var(ddof=1), rel=1e-9)
    assert w.sq_mean == pytest.approx((x ** 2).mean(), rel=1e-12)
    assert w.quad_mean == pytest.approx((x ** 4).mean(), rel=1e-12)
    assert w.abs_mean == pytest.approx(np.abs(x).mean(), rel=1e-12)


def test_tau_int_recovers_ar1_autocorrelation():
    """AR(1) with coefficient phi has tau_int = (1 + phi) / (1 - phi)."""
    rng = np.random.default_rng(1)
    phi, n = 0.7, 200_000
    x = np.empty(n)
    x[0] = 0.0
    noise = rng.normal(size=n)
    for t in range(1, n):
        x[t] = phi * x[t - 1] + noise[t]
    expect = (1 + phi) / (1 - phi)   # ~5.67
    assert tau_int(x) == pytest.approx(expect, rel=0.15)
    # iid series: tau_int ~ 1
    assert tau_int(rng.normal(size=50_000)) == pytest.approx(1.0,
                                                             abs=0.15)


def test_jackknife_and_blocking_errors_shrink_as_sqrt_n():
    """On iid data both error bars track sigma/sqrt(N): averaging over
    independent realizations, err(16N)/err(N) ~ 1/4."""
    rng = np.random.default_rng(2)

    def mean_err(estimator, n, reps=30):
        return np.mean([estimator(rng.normal(size=n)) for _ in range(reps)])

    for est in (lambda s: jackknife(s)[1], blocking_error):
        e_small = mean_err(est, 1_000)
        e_big = mean_err(est, 16_000)
        assert e_small / e_big == pytest.approx(4.0, rel=0.25), est
    # and the absolute scale is sigma/sqrt(N)
    assert mean_err(lambda s: jackknife(s)[1], 4_000) == pytest.approx(
        1.0 / np.sqrt(4_000), rel=0.2)


def test_jackknife_mean_is_plain_mean():
    x = np.arange(100, dtype=np.float64)
    est, err = jackknife(x)
    assert est == pytest.approx(x.mean())
    assert err > 0


def test_chi_and_cv_nonnegative_on_simulation_data():
    sim = Simulation(SimConfig(n=16, m=16, temperature=2.3, seed=9,
                               engine="multispin"))
    traj = sim.measure(MeasurementPlan(64, 1, thermalize=50))
    chi = susceptibility(traj["m"], 2.3, 256)
    cv = specific_heat(traj["e"], 2.3, 256)
    assert chi >= 0.0 and cv >= 0.0
    assert np.isfinite(chi) and np.isfinite(cv)
    # adversarial inputs cannot push them negative either
    rng = np.random.default_rng(3)
    for _ in range(20):
        s = rng.normal(size=32)
        assert susceptibility(s, 2.0, 64) >= 0.0
        assert specific_heat(s, 2.0, 64) >= 0.0


def test_binder_limits_and_crossing():
    # ordered phase: constant |m| -> U = 2/3; gaussian m -> U = 0
    assert binder(np.full(500, 0.8)) == pytest.approx(2.0 / 3.0)
    rng = np.random.default_rng(4)
    assert binder(rng.normal(size=400_000)) == pytest.approx(0.0,
                                                             abs=0.02)
    t = [2.0, 2.2, 2.4, 2.6]
    assert binder_crossing(t, [0.60, 0.50, 0.40, 0.30],
                           [0.65, 0.55, 0.35, 0.20]) == pytest.approx(2.3)
    assert binder_crossing(t, [0.6, 0.5, 0.4, 0.3],
                           [0.7, 0.6, 0.5, 0.4]) is None


def test_binder_crossing_brackets_tc_on_ensemble_scan():
    """Small two-size Ensemble scan: the U_L crossing lands near the
    exact T_c = 2.269185 (the examples/figures.py physics gate at
    sub-smoke scale)."""
    temps = [2.0, 2.1, 2.2, 2.3, 2.4, 2.6]
    plan = MeasurementPlan(n_measure=150, sweeps_between=2,
                           thermalize=200)
    u = {}
    for k, L in enumerate((16, 32)):
        ens = Ensemble(n=L, m=L, temperatures=temps,
                       seeds=[41 + 100 * k + i for i in range(len(temps))],
                       engine="multispin", init_p_up=1.0)
        m = ens.measure(plan)["m"]
        u[L] = [binder(m[:, i]) for i in range(len(temps))]
    tc = binder_crossing(temps, u[16], u[32])
    assert tc is not None
    assert abs(tc - obs.T_CRITICAL) < 0.15, (tc, u)


# ---------------------------------------------------------------------------
# recorder
# ---------------------------------------------------------------------------


def test_recorder_csv_schema_and_json_roundtrip(tmp_path):
    rec = RunRecorder(meta={"stamp": "test"})
    rec.record("fig5_L16_T2.000", 12.5, m=0.91234567, m_err=0.0123,
               note="x")
    row = rec.format_row(rec.rows[0])
    assert row == "fig5_L16_T2.000,12.5,m=0.912346;m_err=0.0123;note=x"
    assert parse_derived(row.split(",", 2)[2]) == {
        "m": 0.912346, "m_err": 0.0123, "note": "x"}
    csv = rec.write_csv(str(tmp_path / "out.csv"))
    lines = open(csv).read().splitlines()
    assert lines[0] == "name,us_per_call,derived" and lines[1] == row
    jpath = rec.write_json(str(tmp_path) + "/")
    assert "BENCH_test.json" in jpath
    import json
    with open(jpath) as f:
        data = json.load(f)
    assert data["rows"][0]["name"] == "fig5_L16_T2.000"
    assert data["meta"]["stamp"] == "test"
