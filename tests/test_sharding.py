"""Sharding rules: divisibility fallbacks, spec shapes, roofline parsing."""
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, get_smoke_config
from repro.launch import roofline
from repro.launch.mesh import make_debug_mesh
from repro.models import init_model
from repro.train.sharding import (batch_specs, cache_specs, mesh_axes,
                                  param_spec, param_shardings)


class FakeMesh:
    """Minimal mesh stand-in for rule unit tests (no devices needed)."""
    def __init__(self, shape_map):
        self.shape = shape_map
        self.axis_names = tuple(shape_map)


MESH16 = FakeMesh({"data": 16, "model": 16})


def test_heads_shard_when_divisible():
    spec = param_spec("blocks/attn/wq", (2048, 32, 64), MESH16, fsdp=False)
    assert spec == P(None, "model", None)


def test_whisper_heads_fall_back_to_head_dim():
    """20 heads don't divide 16 -> the model axis moves to head_dim (H7)."""
    spec = param_spec("dec_blocks/attn/wq", (1280, 20, 64), MESH16,
                      fsdp=False)
    assert spec == P(None, None, "model")
    # and if neither divides, fully replicated
    spec = param_spec("dec_blocks/attn/wq", (1280, 20, 63), MESH16,
                      fsdp=False)
    assert spec == P(None, None, None)


def test_vocab_shard_and_fallback():
    assert param_spec("embed/table", (102400, 2048), MESH16,
                      fsdp=False) == P("model", None)
    # whisper vocab 51866 % 16 != 0 -> replicated
    assert param_spec("embed/table", (51866, 1280), MESH16,
                      fsdp=False) == P(None, None)


def test_fsdp_shards_dmodel():
    spec = param_spec("blocks/mlp/wi", (8192, 22528), MESH16, fsdp=True)
    assert spec == P("data", "model")


def test_expert_parallel():
    spec = param_spec("moe_blocks/moe/wi", (26, 64, 2048, 1408), MESH16,
                      fsdp=False)
    assert spec == P(None, "model", None, None)


def test_stacked_leading_axis_never_sharded():
    spec = param_spec("blocks/attn/wo", (40, 64, 128, 8192), MESH16,
                      fsdp=True)
    assert spec[0] is None


def test_norms_replicated():
    assert param_spec("blocks/norm1/scale", (2048,), MESH16,
                      fsdp=True) == P()


def test_param_shardings_on_real_mesh():
    mesh = make_debug_mesh()
    cfg = get_smoke_config("internlm2-1.8b")
    params = init_model(cfg, jax.random.PRNGKey(0))
    sh = param_shardings(cfg, params, mesh)
    assert len(jax.tree.leaves(sh)) == len(jax.tree.leaves(params))


def test_collective_bytes_parser():
    hlo = """
HloModule test
ENTRY main {
  %p0 = f32[128,256]{1,0} parameter(0)
  %ag = f32[2048,256]{1,0} all-gather(%p0), replica_groups={}
  %ar = f32[128,256]{1,0} all-reduce(%p0), to_apply=%sum
  %cp = f32[128,256]{1,0} collective-permute(%p0), source_target_pairs={}
}
"""
    out = roofline.collective_bytes(hlo)
    assert out["all-gather"] == 2048 * 256 * 4
    assert out["all-reduce"] == 128 * 256 * 4
    assert out["collective-permute"] == 128 * 256 * 4
    assert out["reduce-scatter"] == 0


def test_roofline_terms_dominance():
    t = roofline.roofline_terms(197e12, 0.0, {"all-reduce": 0}, 1)
    assert t["dominant"] == "compute"
    assert t["t_compute_s"] == 1.0
    t = roofline.roofline_terms(0.0, 819e9, {}, 1)
    assert t["dominant"] == "memory"


def test_count_params_moe_active():
    cfg = get_smoke_config("deepseek-moe-16b")
    params = jax.eval_shape(
        lambda k: init_model(cfg, k), jax.random.PRNGKey(0))
    counts = roofline.count_params(params,
                                   active_moe_frac=cfg.top_k / cfg.n_routed)
    assert 0 < counts["active"] < counts["total"]
