"""Per-arch smoke tests (reduced configs) + prefill/decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import decode_step, forward, init_cache, init_model

B, S = 2, 16


def _batch(cfg, key):
    if cfg.family == "audio":
        return {"frames": jax.random.normal(key, (B, cfg.enc_seq,
                                                  cfg.d_model)),
                "tokens": jnp.ones((B, S), jnp.int32)}
    if cfg.family == "vlm":
        return {"tokens": jnp.ones((B, S - cfg.prefix_len), jnp.int32),
                "patch_emb": jax.random.normal(
                    key, (B, cfg.prefix_len, cfg.d_model))}
    return {"tokens": jnp.ones((B, S), jnp.int32)}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = init_model(cfg, key)
    logits, aux = jax.jit(lambda p, b: forward(cfg, p, b))(
        params, _batch(cfg, key))
    assert logits.shape == (B, S, cfg.vocab)
    assert not bool(jnp.isnan(logits).any())
    assert jnp.isfinite(aux)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = init_model(cfg, key)
    cache = init_cache(cfg, B, 32)
    step = jax.jit(lambda p, c, t: decode_step(cfg, p, c, t))
    tok = jnp.ones((B, 1), jnp.int32)
    for _ in range(3):
        logits, cache = step(params, cache, tok)
        assert logits.shape == (B, 1, cfg.vocab)
        assert not bool(jnp.isnan(logits).any())
    assert int(cache["length"]) == 3


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "chatglm3-6b",
                                  "deepseek-v2-lite-16b", "xlstm-125m",
                                  "zamba2-1.2b"])
def test_prefill_decode_consistency(arch):
    """Teacher-forced decode reproduces the forward pass logits.

    This is the KV-cache / recurrent-state correctness test: chunked
    (train) and stepwise (decode) formulations must agree.
    """
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(1)
    params = init_model(cfg, key)
    tokens = jax.random.randint(key, (B, 8), 0, cfg.vocab)
    # dropless MoE so capacity policy can't differ between the two paths
    full_logits, _ = forward(cfg, params, {"tokens": tokens}, remat=False,
                             dropless_moe=True)

    cache = init_cache(cfg, B, 8)
    outs = []
    step = jax.jit(lambda p, c, t: decode_step(cfg, p, c, t))
    for i in range(8):
        lg, cache = step(params, cache, tokens[:, i:i + 1])
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits, np.float32),
                               np.asarray(full_logits, np.float32),
                               rtol=0.05, atol=0.05)


def test_full_configs_match_spec():
    """The full (non-smoke) configs carry the exact assigned hyperparams."""
    spec = {
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "deepseek-v2-lite-16b": (27, 2048, 16, 16, 1408, 102400),
        "deepseek-moe-16b": (28, 2048, 16, 16, 1408, 102400),
        "phi4-mini-3.8b": (32, 3072, 24, 8, 8192, 200064),
        "command-r-35b": (40, 8192, 64, 8, 22528, 256000),
        "chatglm3-6b": (28, 4096, 32, 2, 13696, 65024),
        "internlm2-1.8b": (24, 2048, 16, 8, 8192, 92544),
        "internvl2-26b": (48, 6144, 48, 8, 16384, 92553),
        "xlstm-125m": (12, 768, 4, 4, 0, 50304),
        "whisper-large-v3": (32, 1280, 20, 20, 5120, 51866),
    }
    for arch, (l, d, h, kv, ff, v) in spec.items():
        cfg = get_config(arch)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.d_ff, cfg.vocab) == (l, d, h, kv, ff, v), arch
    assert get_config("zamba2-1.2b").ssm_state == 64
    assert get_config("deepseek-v2-lite-16b").kv_lora == 512
    assert get_config("deepseek-v2-lite-16b").top_k == 6
    assert get_config("deepseek-moe-16b").n_routed == 64


def test_moe_token_mass_conservation():
    """Dispatch+combine with huge capacity == every token routed."""
    from repro.models.moe import init_moe, moe_block
    key = jax.random.PRNGKey(3)
    p = init_moe(key, 16, 32, 4, 0, 2)
    x = jax.random.normal(key, (2, 8, 16))
    y1, _ = moe_block(p, x, top_k=2, capacity_factor=8.0)
    y2, _ = moe_block(p, x, top_k=2, capacity_factor=8.0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2))
    assert not bool(jnp.isnan(y1).any())
    # capacity 0-ish: routed path contributes ~nothing but never NaNs
    y3, _ = moe_block(p, x, top_k=2, capacity_factor=1e-9)
    assert not bool(jnp.isnan(y3).any())


def test_chunked_linear_attention_matches_stepwise():
    """The SSD core: chunk-parallel == sequential recurrence."""
    from repro.models.ssm import (chunked_linear_attention,
                                  linear_attention_step)
    key = jax.random.PRNGKey(4)
    b, s, h, dk, dv = 2, 16, 3, 5, 7
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (b, s, h, dk))
    k = jax.random.normal(ks[1], (b, s, h, dk))
    v = jax.random.normal(ks[2], (b, s, h, dv))
    log_a = -jax.nn.softplus(jax.random.normal(ks[3], (b, s, h)))
    scale = jax.nn.sigmoid(jax.random.normal(ks[4], (b, s, h)))

    y_chunk, final_chunk = chunked_linear_attention(q, k, v, log_a, scale,
                                                    chunk=4)
    state = jnp.zeros((b, h, dk, dv))
    ys = []
    for t in range(s):
        yt, state = linear_attention_step(
            q[:, t:t + 1], k[:, t:t + 1], v[:, t:t + 1],
            log_a[:, t:t + 1], scale[:, t:t + 1], state)
        ys.append(yt[:, 0])
    y_seq = jnp.stack(ys, axis=1)[..., None, :].reshape(b, s, h, dv)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_seq),
                               rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(np.asarray(final_chunk), np.asarray(state),
                               rtol=2e-2, atol=2e-2)


def test_whisper_prefill_decode_consistency():
    """Enc-dec: cached decode (self KV + precomputed cross KV) matches the
    teacher-forced joint forward."""
    from repro.models.model import encode_audio
    cfg = get_smoke_config("whisper-large-v3")
    key = jax.random.PRNGKey(6)
    params = init_model(cfg, key)
    frames = jax.random.normal(key, (B, cfg.enc_seq, cfg.d_model))
    tokens = jax.random.randint(key, (B, 6), 0, cfg.vocab)
    full_logits, _ = forward(cfg, params,
                             {"frames": frames, "tokens": tokens},
                             remat=False)
    enc = encode_audio(cfg, params, frames)
    cache = init_cache(cfg, B, 6, enc_out=enc, params=params)
    step = jax.jit(lambda p, c, t: decode_step(cfg, p, c, t))
    outs = []
    for i in range(6):
        lg, cache = step(params, cache, tokens[:, i:i + 1])
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full_logits, np.float32),
                               rtol=0.05, atol=0.05)


def test_ring_cache_matches_full_cache():
    """H3 correctness: ring-buffer windowed decode == full cache + window
    mask, once enough tokens have been generated to wrap the ring."""
    cfg = get_smoke_config("internlm2-1.8b")
    key = jax.random.PRNGKey(5)
    params = init_model(cfg, key)
    window, steps = 4, 10
    tokens = jax.random.randint(key, (B, steps), 0, cfg.vocab)

    full = init_cache(cfg, B, steps)                    # full-length cache
    ring = init_cache(cfg, B, steps, window=window)     # ring buffer
    assert ring["kv"]["k"].shape[2] == window
    step_full = jax.jit(lambda p, c, t: decode_step(
        cfg, p, c, t, sliding_window=window))
    step_ring = jax.jit(lambda p, c, t: decode_step(
        cfg, p, c, t, sliding_window=window))
    for i in range(steps):
        t = tokens[:, i:i + 1]
        lf, full = step_full(params, full, t)
        lr, ring = step_ring(params, ring, t)
        np.testing.assert_allclose(np.asarray(lr, np.float32),
                                   np.asarray(lf, np.float32),
                                   rtol=2e-2, atol=2e-2), i
