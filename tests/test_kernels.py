"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret=True."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import lattice as lat
from repro.core import multispin as ms
from repro.core import tensorcore as tc
from repro.kernels.multispin.multispin import multispin_update
from repro.kernels.multispin.ops import run_sweeps_multispin
from repro.kernels.multispin.ref import multispin_update_ref
from repro.kernels.stencil.ops import run_sweeps_stencil
from repro.kernels.stencil.ref import stencil_update_ref
from repro.kernels.stencil.stencil import stencil_update
from repro.kernels.tensorcore.ref import tensorcore_update_ref
from repro.kernels.tensorcore.tensorcore import tensorcore_update

SHAPES = [(16, 32), (64, 64), (32, 128), (128, 256)]


@pytest.mark.parametrize("n,m", SHAPES)
@pytest.mark.parametrize("is_black", [True, False])
def test_stencil_kernel_philox(n, m, is_black):
    full = lat.init_lattice(jax.random.PRNGKey(0), n, m)
    b, w = lat.split_checkerboard(full)
    t, op = (b, w) if is_black else (w, b)
    beta = jnp.float32(1 / 2.2)
    out_k = stencil_update(t, op, beta, is_black=is_black, seed=9, offset=5,
                           block_rows=8, interpret=True)
    out_r = stencil_update_ref(t, op, beta, is_black=is_black, seed=9,
                               offset=5)
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))


@pytest.mark.parametrize("n,m", SHAPES[:2])
@pytest.mark.parametrize("dtype", [jnp.int8, jnp.int32])
def test_stencil_kernel_uniforms_dtypes(n, m, dtype):
    full = lat.init_lattice(jax.random.PRNGKey(1), n, m).astype(dtype)
    b, w = lat.split_checkerboard(full)
    u = jax.random.uniform(jax.random.PRNGKey(2), b.shape)
    beta = jnp.float32(0.7)
    out_k = stencil_update(b, w, beta, is_black=True, uniforms=u,
                           block_rows=8, interpret=True)
    out_r = stencil_update_ref(b, w, beta, is_black=True, uniforms=u)
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))
    assert out_k.dtype == dtype


@pytest.mark.parametrize("n,m", SHAPES)
@pytest.mark.parametrize("is_black", [True, False])
def test_multispin_kernel(n, m, is_black):
    full = lat.init_lattice(jax.random.PRNGKey(3), n, m)
    bw, ww = ms.pack_lattice(*lat.split_checkerboard(full))
    t, op = (bw, ww) if is_black else (ww, bw)
    beta = jnp.float32(1 / 2.3)
    out_k = multispin_update(t, op, beta, is_black=is_black, seed=11,
                             offset=3, block_rows=8, interpret=True)
    out_r = multispin_update_ref(t, op, beta, is_black=is_black, seed=11,
                                 offset=3)
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))


@pytest.mark.parametrize("n,block", [(32, 8), (64, 16), (64, 8), (128, 32)])
@pytest.mark.parametrize("color", ["black", "white"])
def test_tensorcore_kernel(n, block, color):
    full = lat.init_lattice(jax.random.PRNGKey(4), n, n)
    planes = {k: v.astype(jnp.bfloat16)
              for k, v in tc.decompose(full).items()}
    beta = jnp.float32(1 / 2.27)
    out_k = tensorcore_update(planes, color, beta, seed=21, offset=7,
                              block=block, interpret=True)
    out_r = tensorcore_update_ref(planes, color, beta, seed=21, offset=7,
                                  block=block)
    for pk in out_k:
        np.testing.assert_array_equal(
            np.asarray(out_k[pk], np.float32),
            np.asarray(out_r[pk], np.float32), err_msg=f"{pk}")


def test_multisweep_wrappers_match_core():
    """ops.py sweep loops == core engine sweep loops, multi-iteration."""
    full = lat.init_lattice(jax.random.PRNGKey(5), 32, 64)
    b, w = lat.split_checkerboard(full)
    bw, ww = ms.pack_lattice(b, w)  # before the donating philox call
    beta = jnp.float32(1 / 2.0)
    bk, wk = run_sweeps_stencil(b.copy(), w.copy(), beta, 5, seed=2,
                                block_rows=8, interpret=True)  # donates
    from repro.core.metropolis import run_sweeps_philox
    br, wr = run_sweeps_philox(b, w, beta, 5, seed=2)  # donates b, w
    np.testing.assert_array_equal(np.asarray(bk), np.asarray(br))

    bk2, wk2 = run_sweeps_multispin(bw.copy(), ww.copy(), beta, 5, seed=2,
                                    block_rows=8, interpret=True)  # donates
    br2, wr2 = ms.run_sweeps_packed(bw, ww, beta, 5, seed=2)  # donates
    np.testing.assert_array_equal(np.asarray(bk2), np.asarray(br2))
    np.testing.assert_array_equal(np.asarray(wk2), np.asarray(wr2))


def test_kernel_physics_lowT():
    """Steady state: an ordered lattice stays ordered under the kernel at
    T=1.5 (cold starts can fall into the striped metastable states the
    paper reports in S5.3, so we start from the ground state)."""
    full = jnp.ones((64, 64), jnp.int8)
    bw, ww = ms.pack_lattice(*lat.split_checkerboard(full))
    beta = jnp.float32(1 / 1.5)
    bw, ww = run_sweeps_multispin(bw, ww, beta, 100, seed=3, block_rows=8,
                                  interpret=True)
    b, w = ms.unpack_lattice(bw, ww)
    m = float(jnp.abs(b.astype(jnp.float32).mean()
                      + w.astype(jnp.float32).mean()) / 2)
    assert m > 0.95
