"""Use real hypothesis when installed; otherwise a tiny deterministic stand-in.

The container the tier-1 suite runs in does not ship ``hypothesis`` (it is
declared in requirements-dev.txt / pyproject.toml for dev machines and CI).
So property tests import ``given/settings/st`` from this module: with
hypothesis installed they get the real thing (shrinking, example database,
edge-case generation); without it they get a seeded-random fallback that
draws ``max_examples`` samples from the same strategy combinators --
enough to keep the properties exercised everywhere.

Only the strategy surface the test-suite uses is implemented:
``integers``, ``floats``, ``booleans``, ``tuples``, and ``.map``.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
except ModuleNotFoundError:
    import random

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

        def map(self, fn):
            return _Strategy(lambda r: fn(self.draw(r)))

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda r: r.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda r: r.uniform(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda r: r.random() < 0.5)

        @staticmethod
        def tuples(*strategies):
            return _Strategy(
                lambda r: tuple(s.draw(r) for s in strategies))

    st = _Strategies()

    def settings(max_examples: int = 20, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(**strategies):
        def deco(fn):
            # NB: no functools.wraps -- the wrapper must expose a
            # zero-parameter signature or pytest treats the strategy
            # names as missing fixtures
            def wrapper():
                rng = random.Random(0xC0FFEE)
                for _ in range(getattr(fn, "_max_examples", 20)):
                    drawn = {k: s.draw(rng) for k, s in strategies.items()}
                    fn(**drawn)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco
