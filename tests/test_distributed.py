"""Distributed Ising engine: shard_map halos vs single-device reference.

These tests run in a subprocess with XLA_FLAGS forcing 8 host devices
(the main pytest process must keep the default 1-device platform for all
other tests), exercising the same ring_shift/halo code the 512-chip
dry-run lowers.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np, json
    from repro.core import lattice as lat, distributed as dist, \
        metropolis as metro, rng as crng

    N, M = 32, 32
    full = lat.init_lattice(jax.random.PRNGKey(7), N, M)
    b, w = lat.split_checkerboard(full)

    def ref_sweeps(b, w, beta, seed, nswp):
        half = M // 2
        idx = jnp.arange(N * half, dtype=jnp.uint32).reshape(N, half)
        for s in range(nswp):
            u = crng.uniforms(seed, idx, jnp.uint32(2 * s))[0]
            b = metro.update_color(b, w, u, beta, True)
            u = crng.uniforms(seed, idx, jnp.uint32(2 * s + 1))[0]
            w = metro.update_color(w, b, u, beta, False)
        return b, w

    beta = jnp.float32(1 / 2.0)
    br, wr = ref_sweeps(b, w, beta, 5, 3)
    out = {}

    for shape, axes in [((2, 2, 2), ("pod", "data", "model")),
                        ((4, 2), ("data", "model")),
                        ((1, 8), ("data", "model"))]:
        from repro.launch.mesh import make_mesh
        mesh = make_mesh(shape, axes)
        step, sh = dist.make_ising_step(mesh, n=N, m=M, seed=5, n_sweeps=3)
        b1, w1 = step(jax.device_put(b, sh), jax.device_put(w, sh),
                      beta, jnp.uint32(0))
        key = "x".join(map(str, shape))
        out["match_" + key] = bool(
            (np.asarray(b1) == np.asarray(br)).all()
            and (np.asarray(w1) == np.asarray(wr)).all())
        mag = dist.magnetization_dist(mesh)
        out["mag_" + key] = float(mag(b1, w1))

    expect_mag = float((br.astype(jnp.float32).sum()
                        + wr.astype(jnp.float32).sum()) / (N * M))
    out["expect_mag"] = expect_mag
    print(json.dumps(out))
""")


@pytest.fixture(scope="module")
def dist_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


def test_multipod_mesh_matches_reference(dist_results):
    assert dist_results["match_2x2x2"]


def test_flat_mesh_matches_reference(dist_results):
    assert dist_results["match_4x2"]
    assert dist_results["match_1x8"]


def test_grid_independence(dist_results):
    """Same trajectory regardless of device grid (global-keyed Philox)."""
    mags = [v for k, v in dist_results.items() if k.startswith("mag_")]
    assert len(set(round(m, 6) for m in mags)) == 1
    assert mags[0] == pytest.approx(dist_results["expect_mag"], abs=1e-6)
