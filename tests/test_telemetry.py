"""Telemetry subsystem: spans, counters, trace schema, CLI (DESIGN.md S12).

Covers the counter semantics contract (dispatches / sweeps / spin_flips /
philox_draws) across every engine family, span nesting and fencing, both
export formats, the schema validators (golden file + violation catalogue
+ property round-trips), the summarize/validate CLI, and the
``DISPATCH_COUNT`` deprecation shim.
"""
import io
import json
import os
import subprocess
import sys

import pytest

import repro.telemetry as tel
from _hypothesis_compat import given, settings, st
from repro.api import EngineSpec, LatticeSpec, RunSpec, Session, SweepSpec
from repro.api import describe
from repro.kernels.resident import decision_attrs
from repro.telemetry.__main__ import _load, main as telemetry_cli
from repro.telemetry.metrics import MetricsRegistry, diff_counters
from repro.telemetry.schema import (TelemetryError, validate_event,
                                    validate_snapshot, validate_trace)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
GOLDEN = os.path.join(REPO, "tests", "data", "trace_golden.json")


@pytest.fixture
def traced():
    """Tracing on with a clean event list; always off again afterwards."""
    tel.TRACER.clear()
    tel.enable()
    yield tel.TRACER
    tel.disable()
    tel.TRACER.clear()


def _counters():
    return tel.REGISTRY.snapshot()


# ---------------------------------------------------------------------------
# metrics primitives
# ---------------------------------------------------------------------------


def test_counter_monotone_and_rejects_negative():
    reg = MetricsRegistry()
    c = reg.counter("c")
    c.inc()
    c.inc(41)
    assert c.value == 42
    with pytest.raises(ValueError):
        c.inc(-1)
    assert c.value == 42


def test_gauge_set_and_rejects_nonfinite():
    reg = MetricsRegistry()
    g = reg.gauge("g")
    assert g.value is None
    g.set(2.5)
    assert g.value == 2.5
    for bad in (float("nan"), float("inf")):
        with pytest.raises(ValueError):
            g.set(bad)


def test_histogram_stats():
    reg = MetricsRegistry()
    h = reg.histogram("h")
    assert h.stats() == {"count": 0}
    for v in (1.0, 3.0, 2.0):
        h.observe(v)
    s = h.stats()
    assert s == {"count": 3, "sum": 6.0, "min": 1.0, "max": 3.0,
                 "mean": 2.0}


def test_registry_kind_collision_and_identity():
    reg = MetricsRegistry()
    c = reg.counter("x")
    assert reg.counter("x") is c
    with pytest.raises(ValueError):
        reg.gauge("x")
    with pytest.raises(ValueError):
        reg.histogram("x")


def test_registry_reset_zeroes_in_place():
    """reset() must zero the *existing* instruments, not replace them --
    module-held references like tel.DISPATCHES survive."""
    reg = MetricsRegistry()
    c, g, h = reg.counter("c"), reg.gauge("g"), reg.histogram("h")
    c.inc(5)
    g.set(1.0)
    h.observe(2.0)
    reg.reset()
    assert reg.counter("c") is c and c.value == 0
    assert reg.gauge("g") is g and g.value is None
    assert reg.histogram("h") is h and h.stats() == {"count": 0}


def test_snapshot_shape_and_diff_counters():
    reg = MetricsRegistry()
    reg.counter("a").inc(3)
    base = reg.snapshot()
    validate_snapshot(base)
    assert set(base) == {"counters", "gauges", "histograms"}
    assert base["gauges"] == {}  # unset gauges are omitted
    reg.counter("a").inc(4)
    reg.counter("b").inc(1)
    assert diff_counters(base, reg.snapshot()) == {"a": 4, "b": 1}


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------


def test_span_nesting_depth_and_close_order(traced):
    with tel.span("outer", tag="o"):
        with tel.span("inner"):
            pass
        tel.instant("mark", x=1)
    names = [e["name"] for e in traced.events]
    # spans append at close: child first, instant in the middle
    assert names == ["inner", "mark", "outer"]
    by_name = {e["name"]: e for e in traced.events}
    assert by_name["outer"]["depth"] == 0
    assert by_name["inner"]["depth"] == 1
    assert by_name["mark"]["kind"] == "instant"
    assert by_name["outer"]["args"] == {"tag": "o"}
    # child interval contained in the parent's
    o, i = by_name["outer"], by_name["inner"]
    assert o["ts_us"] <= i["ts_us"]
    assert i["ts_us"] + i["dur_us"] <= o["ts_us"] + o["dur_us"] + 1e-3


def test_span_attrs_normalized_and_set(traced):
    with tel.span("s", lattice=(16, 16)) as sp:
        sp.set(batch=2, obj=object())
    (e,) = traced.events
    assert e["args"]["lattice"] == [16, 16]
    assert e["args"]["batch"] == 2
    assert isinstance(e["args"]["obj"], str)  # stringified, not dropped
    assert sp.duration_ns is not None and sp.duration_ns >= 0


def test_span_error_attr(traced):
    with pytest.raises(RuntimeError):
        with tel.span("boom"):
            raise RuntimeError("x")
    (e,) = traced.events
    assert e["args"]["error"] is True


def test_disabled_tracing_is_inert():
    tel.TRACER.clear()
    assert not tel.enabled()
    with tel.span("ghost") as sp:
        sp.set(a=1)
        sp.fence(object())  # must NOT try to block_until_ready this
    assert sp is tel.NULL_SPAN and sp.duration_ns is None
    tel.instant("ghost")
    assert tel.TRACER.events == []


def test_span_feeds_timing_histogram(traced):
    before = tel.REGISTRY.histogram("span_ms.histspan").stats()["count"]
    with tel.span("histspan"):
        pass
    s = tel.REGISTRY.histogram("span_ms.histspan").stats()
    assert s["count"] == before + 1


# ---------------------------------------------------------------------------
# export round-trips
# ---------------------------------------------------------------------------


def test_export_chrome_and_jsonl_agree(tmp_path, traced):
    with tel.span("a", k=3):
        tel.instant("p", family="stencil")
    cj = str(tmp_path / "t.json")
    jl = str(tmp_path / "t.jsonl")
    tel.export(cj, meta={"who": "test"})
    tel.export(jl, meta={"who": "test"})
    chrome = json.load(open(cj))
    validate_trace(chrome)
    stream = _load(jl)  # JSONL re-rendered to the chrome shape
    validate_trace(stream)
    strip = lambda evs: [{k: e[k] for k in ("name", "ph", "ts", "args")}
                         for e in evs]
    assert strip(chrome["traceEvents"]) == strip(stream["traceEvents"])
    assert chrome["meta"]["who"] == stream["meta"]["who"] == "test"
    assert chrome["metrics"] == stream["metrics"]
    phs = {e["name"]: e["ph"] for e in chrome["traceEvents"]}
    assert phs == {"a": "X", "p": "i"}


# ---------------------------------------------------------------------------
# schema: golden file, violation catalogue, property round-trips
# ---------------------------------------------------------------------------


def test_golden_trace_validates():
    """The committed trace of the acceptance run::

        python -m repro run --n 16 --engine multispin --n-measure 3 \\
            --measure-every 2 --thermalize 2 --trace ...

    stays loadable forever: >= 5 span types, counters exactly matching
    the spec's sweep plan (thermalize 2 + 3 x every-2 = 8 sweeps, ONE
    fused dispatch, 8 x 256 site updates)."""
    doc = json.load(open(GOLDEN))
    validate_trace(doc)
    names = {e["name"] for e in doc["traceEvents"]}
    assert len(names) >= 5
    assert {"session.open", "session.measure", "measure_scan",
            "dispatch", "spec.validate"} <= names
    assert doc["metrics"]["counters"] == {
        "dispatches": 1, "sweeps": 8,
        "spin_flips": 2048, "philox_draws": 2048}
    spec = RunSpec.from_json(doc["meta"]["spec_json"])
    assert spec.engine.name == "multispin"
    assert spec.sweep.total_sweeps == 8
    # and the summarize renderer digests it
    buf = io.StringIO()
    from repro.telemetry.__main__ import summarize
    summarize(doc, out=buf)
    assert "dispatches" in buf.getvalue()


_BAD_SNAPSHOTS = [
    ("not-a-dict", []),
    ("unknown-key", {"counters": {}, "gauges": {}, "histograms": {},
                     "extra": {}}),
    ("missing-section", {"counters": {}, "gauges": {}}),
    ("negative-counter", {"counters": {"c": -1}, "gauges": {},
                          "histograms": {}}),
    ("bool-counter", {"counters": {"c": True}, "gauges": {},
                      "histograms": {}}),
    ("float-counter", {"counters": {"c": 1.5}, "gauges": {},
                       "histograms": {}}),
    ("nonfinite-gauge", {"counters": {}, "gauges": {"g": float("inf")},
                         "histograms": {}}),
    ("empty-name", {"counters": {"": 1}, "gauges": {},
                    "histograms": {}}),
    ("empty-hist-extra-keys", {"counters": {}, "gauges": {},
                               "histograms": {"h": {"count": 0,
                                                    "sum": 0.0}}}),
    ("hist-missing-mean", {"counters": {}, "gauges": {},
                           "histograms": {"h": {"count": 1, "sum": 1.0,
                                                "min": 1.0,
                                                "max": 1.0}}}),
    ("hist-order-violated", {"counters": {}, "gauges": {},
                             "histograms": {"h": {"count": 2, "sum": 3.0,
                                                  "min": 2.0, "max": 1.0,
                                                  "mean": 1.5}}}),
]


@pytest.mark.parametrize(
    "snap", [s for _, s in _BAD_SNAPSHOTS],
    ids=[n for n, _ in _BAD_SNAPSHOTS])
def test_snapshot_violations_rejected(snap):
    with pytest.raises(TelemetryError):
        validate_snapshot(snap)


def _ev(**over):
    ev = {"name": "s", "cat": "repro", "ph": "X", "ts": 1.0, "dur": 2.0,
          "pid": 0, "tid": 1, "args": {}}
    ev.update(over)
    return {k: v for k, v in ev.items() if v is not ...}


_BAD_EVENTS = [
    ("bad-ph", _ev(ph="B")),
    ("no-name", _ev(name="")),
    ("unknown-key", _ev(bogus=1)),
    ("complete-missing-dur", _ev(dur=...)),
    ("instant-with-dur", _ev(ph="i", s="t")),
    ("negative-ts", _ev(ts=-1.0)),
    ("nonfinite-dur", _ev(dur=float("nan"))),
    ("tid-not-int", _ev(tid="main")),
    ("args-nested-dict", _ev(args={"k": {"nested": 1}})),
    ("args-list-of-dicts", _ev(args={"k": [{"nested": 1}]})),
]


@pytest.mark.parametrize(
    "ev", [e for _, e in _BAD_EVENTS], ids=[n for n, _ in _BAD_EVENTS])
def test_event_violations_rejected(ev):
    with pytest.raises(TelemetryError):
        validate_event(ev)
    with pytest.raises(TelemetryError):
        validate_trace({"traceEvents": [ev]})


def test_trace_document_violations_rejected():
    with pytest.raises(TelemetryError):
        validate_trace([])
    with pytest.raises(TelemetryError):
        validate_trace({"traceEvents": [], "bogus": 1})
    with pytest.raises(TelemetryError):
        validate_trace({"traceEvents": {}})
    with pytest.raises(TelemetryError):
        validate_trace({"traceEvents": [], "meta": "not-a-dict"})
    with pytest.raises(TelemetryError):  # embedded snapshot validated too
        validate_trace({"traceEvents": [],
                        "metrics": {"counters": {"c": -1}, "gauges": {},
                                    "histograms": {}}})


@settings(max_examples=30)
@given(a=st.integers(min_value=0, max_value=2 ** 62),
       b=st.integers(min_value=0, max_value=2 ** 62),
       g=st.floats(min_value=-1e12, max_value=1e12))
def test_snapshot_roundtrip_property(a, b, g):
    reg = MetricsRegistry()
    reg.counter("a").inc(a)
    reg.counter("b").inc(b)
    reg.gauge("g").set(g)
    snap = reg.snapshot()
    validate_snapshot(snap)
    back = json.loads(json.dumps(snap))
    validate_snapshot(back)
    assert back["counters"] == {"a": a, "b": b}


@settings(max_examples=30)
@given(xs=st.tuples(st.floats(min_value=-1e6, max_value=1e6),
                    st.floats(min_value=-1e6, max_value=1e6),
                    st.floats(min_value=-1e6, max_value=1e6)))
def test_histogram_summary_property(xs):
    reg = MetricsRegistry()
    h = reg.histogram("h")
    for v in xs:
        h.observe(v)
    validate_snapshot(reg.snapshot())
    s = h.stats()
    assert s["min"] <= s["mean"] <= s["max"]
    assert s["count"] == len(xs)


@settings(max_examples=30)
@given(ts=st.floats(min_value=0.0, max_value=1e12),
       dur=st.floats(min_value=0.0, max_value=1e9),
       instant=st.booleans())
def test_event_roundtrip_property(ts, dur, instant):
    ev = {"name": "s", "cat": "repro", "ts": ts, "pid": 0, "tid": 7,
          "args": {"k": 1}}
    if instant:
        ev.update(ph="i", s="t")
    else:
        ev.update(ph="X", dur=dur)
    validate_trace(json.loads(json.dumps({"traceEvents": [ev]})))


# ---------------------------------------------------------------------------
# engine-family integration: counters + span nesting for Session.run
# ---------------------------------------------------------------------------

FAMILIES = [("stencil_pallas", {}), ("multispin", {}),
            ("bitplane", {}), ("tensorcore", {"tc_block": 4})]


@pytest.mark.parametrize("engine,params", FAMILIES,
                         ids=[f for f, _ in FAMILIES])
def test_session_run_counters_and_spans(engine, params, traced):
    spec = RunSpec(lattice=LatticeSpec(n=16, m=16),
                   engine=EngineSpec(name=engine, params=params),
                   temperature=2.0, seed=3)
    info = describe(spec)
    base = _counters()
    session = Session.open(spec)
    session.run(2)
    d = diff_counters(base, _counters())
    sites = 16 * 16
    assert d["dispatches"] == 1, engine
    assert d["sweeps"] == 2, engine  # lattice time, NOT x replicas
    assert d["spin_flips"] == 2 * sites * info["replicas"], engine
    assert d["philox_draws"] == \
        (2 * sites if info["counter_based"] else 0), engine

    by_name = {}
    for e in traced.events:
        by_name.setdefault(e["name"], []).append(e)
    assert {"session.open", "session.run", "dispatch"} <= set(by_name)
    dsp, run = by_name["dispatch"][-1], by_name["session.run"][-1]
    assert dsp["args"]["engine"] == engine
    assert dsp["args"]["k"] == 2
    assert dsp["args"]["lattice"] == [16, 16]
    # the dispatch interval nests inside session.run's
    assert run["ts_us"] <= dsp["ts_us"]
    assert dsp["ts_us"] + dsp["dur_us"] \
        <= run["ts_us"] + run["dur_us"] + 1e-3
    # traced runs feed the rolling throughput gauge
    assert tel.REGISTRY.gauge("rolling_flips_per_ns").value is not None


def test_session_measure_counts_one_fused_dispatch(traced):
    spec = RunSpec(lattice=LatticeSpec(n=16, m=16),
                   engine=EngineSpec(name="multispin"),
                   temperature=2.2, seed=5,
                   sweep=SweepSpec(thermalize=4, measure_every=3,
                                   n_measure=5))
    base = _counters()
    session = Session.open(spec)
    session.measure()
    d = diff_counters(base, _counters())
    assert d["dispatches"] == 1  # the whole plan is ONE fused scan
    assert d["sweeps"] == spec.sweep.total_sweeps == 4 + 5 * 3
    names = {e["name"] for e in traced.events}
    assert {"session.measure", "measure_scan", "dispatch"} <= names
    scan = [e for e in traced.events if e["name"] == "measure_scan"][-1]
    assert scan["args"]["n_measure"] == 5
    assert scan["args"]["sweeps_between"] == 3
    assert scan["args"]["thermalize"] == 4
    assert scan["args"]["compile"] in ("first", "steady")


def test_planner_decision_instant_matches_dry_run(traced):
    """The planner.decide instant, describe()['resident'] (the --dry-run
    plan), and decision_attrs() are the same rendering -- a trace can
    never disagree with the printed plan."""
    spec = RunSpec(lattice=LatticeSpec(n=16, m=16),
                   engine=EngineSpec(name="stencil_pallas"),
                   temperature=2.0, seed=1)
    plan = describe(spec)
    decides = [e for e in traced.events
               if e["name"] == "planner.decide" and e["kind"] == "instant"]
    assert decides, "describe() must emit the planner.decide instant"
    assert decides[-1]["args"] == plan["resident"]
    assert plan["resident"] == decision_attrs("stencil", 16, 16)
    assert plan["resident"]["fits_vmem"] is True


# ---------------------------------------------------------------------------
# CLI: python -m repro run --trace / python -m repro.telemetry
# ---------------------------------------------------------------------------


def test_telemetry_cli_validate_rejects_malformed(tmp_path, capsys):
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"traceEvents": [{"name": "x"}]}))
    assert telemetry_cli(["validate", str(bad)]) == 1
    assert "INVALID" in capsys.readouterr().err
    notjson = tmp_path / "nope.jsonl"
    notjson.write_text("{malformed\n")
    assert telemetry_cli(["validate", str(notjson)]) == 1


def test_telemetry_cli_summarize_golden(capsys):
    assert telemetry_cli(["summarize", GOLDEN]) == 0
    out = capsys.readouterr().out
    assert "== spans ==" in out and "== counters ==" in out
    assert "measure_scan" in out and "dispatches" in out
    assert telemetry_cli(["validate", GOLDEN]) == 0


@pytest.mark.slow
def test_cli_traced_run_acceptance(tmp_path):
    """End-to-end acceptance: one traced CLI run produces a
    Perfetto-loadable trace with >= 5 span types whose counters match
    the spec's sweep plan exactly (fresh process => absolute totals)."""
    trace = str(tmp_path / "t.json")
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    subprocess.run(
        [sys.executable, "-m", "repro", "run", "--n", "16",
         "--engine", "multispin", "--n-measure", "3",
         "--measure-every", "2", "--thermalize", "2",
         "--trace", trace],
        check=True, env=env, timeout=600, cwd=str(tmp_path))
    doc = json.load(open(trace))
    validate_trace(doc)
    assert len({e["name"] for e in doc["traceEvents"]}) >= 5
    counters = doc["metrics"]["counters"]
    # the resilience counters register at import time and must all be
    # zero on a clean run (no retries/demotions/quarantines happened)
    recovery = {k: v for k, v in counters.items()
                if k.startswith(("resilience.", "resident.", "ckpt."))}
    assert all(v == 0 for v in recovery.values()), recovery
    assert {k: v for k, v in counters.items()
            if k not in recovery} == {
        "dispatches": 1, "sweeps": 8,
        "spin_flips": 2048, "philox_draws": 2048,
        # unsharded run: the S15 halo counters exist but never fire
        "halo_exchanges": 0, "halo_bytes": 0}
    out = subprocess.run(
        [sys.executable, "-m", "repro.telemetry", "summarize", trace],
        check=True, env=env, timeout=120, capture_output=True, text=True)
    assert "dispatches" in out.stdout


# ---------------------------------------------------------------------------
# deprecation shim
# ---------------------------------------------------------------------------


def test_dispatch_count_shim_warns_and_tracks_counter():
    from repro.analysis import measure as msr
    with pytest.warns(DeprecationWarning, match="DISPATCH_COUNT"):
        v = msr.DISPATCH_COUNT
    assert v == tel.DISPATCHES.value
    tel.DISPATCHES.inc(0)  # no-op, but the shim is live, not a copy
    with pytest.warns(DeprecationWarning):
        assert msr.DISPATCH_COUNT == tel.DISPATCHES.value
    with pytest.raises(AttributeError):
        msr.NO_SUCH_NAME
