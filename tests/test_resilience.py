"""Resilience subsystem: CRC32C integrity, crash topologies, fault
injection, retry/demotion recovery, and bit-exact supervised resume
(DESIGN.md S13)."""
import os
import signal

import numpy as np
import pytest

import repro.telemetry as tel
from repro.api import (BatchSpec, EngineSpec, LatticeSpec, MeshSpec,
                       RunSpec)
from repro.api.session import Session
from repro.ckpt import (Checkpointer, CheckpointError,
                        CheckpointIntegrityError)
from repro.resilience import (FaultPlanError, SimulatedResourceExhausted,
                              Supervisor, SupervisorError,
                              TransientDispatchError, degrade, faults,
                              integrity)


@pytest.fixture(autouse=True)
def _clean_resilience_state():
    """Faults and demotions are process-global by design; tests must
    not leak them into each other."""
    faults.clear()
    degrade.reset_demotions()
    yield
    faults.clear()
    degrade.reset_demotions()


@pytest.fixture
def nosleep(monkeypatch):
    """Retry without wall-clock backoff."""
    monkeypatch.setattr(degrade, "DEFAULT_POLICY",
                        degrade.RetryPolicy(sleep=lambda d: None))


def _spec(engine="multispin", n=16, m=32, seed=7, **kw):
    return RunSpec(lattice=LatticeSpec(n, m),
                   engine=EngineSpec(engine),
                   temperature=2.1, seed=seed, **kw)


# ---------------------------------------------------------------------------
# CRC32C
# ---------------------------------------------------------------------------

def test_crc32c_known_vectors():
    # canonical CRC-32C check values (RFC 3720 appendix / kernel tests)
    assert integrity.crc32c(b"") == 0
    assert integrity.crc32c(b"123456789") == 0xE3069283
    assert integrity.crc32c(b"The quick brown fox jumps over "
                            b"the lazy dog") == 0x22620404


def test_crc32c_incremental_chaining():
    a, b = b"hello, ", b"world" * 500
    assert integrity.crc32c(b, integrity.crc32c(a)) \
        == integrity.crc32c(a + b)


def test_crc32c_ladder_matches_scalar_oracle():
    """The vectorized numpy ladder is property-tested against the
    byte-walk oracle across the threshold and odd lengths."""
    rng = np.random.default_rng(0)
    for n in (1, 7, 8, 2047, 2048, 2049, 65537):
        data = rng.integers(0, 256, n, dtype=np.uint8).tobytes()
        init = int(rng.integers(0, 2 ** 32))
        assert integrity._crc32c_numpy(data, init) \
            == integrity._crc32c_scalar(data, init), n


# ---------------------------------------------------------------------------
# crash topologies: latest_step must skip every invalid shape
# ---------------------------------------------------------------------------

def _save_steps(tmp_path, steps=(10, 20, 30)):
    ck = Checkpointer(str(tmp_path), keep=0)
    for s in steps:
        ck.save(s, {"a": np.arange(s, dtype=np.int64)})
    return ck


def test_latest_step_skips_kill_mid_write(tmp_path):
    ck = _save_steps(tmp_path)
    faults.kill_mid_write(ck.dir, 40)  # torn write: no DONE marker
    assert ck.latest_step() == 30


def test_latest_step_skips_truncated_arrays(tmp_path):
    ck = _save_steps(tmp_path)
    faults.truncate_arrays(ck.dir, 30)  # DONE present, payload torn
    problems = ck.validate_step(30)
    assert any("truncated" in p for p in problems), problems
    assert ck.latest_step() == 20


def test_latest_step_skips_stale_done(tmp_path):
    ck = _save_steps(tmp_path)
    faults.stale_done(ck.dir, 30)  # marker outlived its arrays
    assert any("stale" in p for p in ck.validate_step(30))
    assert ck.latest_step() == 20


def test_latest_step_skips_flipped_byte(tmp_path):
    ck = _save_steps(tmp_path)
    faults.flip_byte(ck.dir, 30)  # silent bit rot under a valid DONE
    assert any("CRC32C" in p for p in ck.validate_step(30))
    assert ck.latest_step() == 20


def test_latest_step_survives_pruning_race(tmp_path, monkeypatch):
    """``keep``-GC deleting a step between discovery and validation
    must make the walk move on, not crash."""
    ck = _save_steps(tmp_path)
    real = Checkpointer.all_steps

    def racy(self):
        return real(self) + [40]  # 40 was pruned right after listing

    monkeypatch.setattr(Checkpointer, "all_steps", racy)
    assert ck.latest_step() == 30
    step, arrays = ck.load_arrays()
    assert step == 30


def test_quarantine_and_fallback_restore(tmp_path):
    """A corrupt newest step is quarantined (kept for post-mortem,
    renamed out of discovery) and restore falls back to the previous
    good step; ``ckpt.quarantine`` accounts the action."""
    ck = _save_steps(tmp_path)
    faults.flip_byte(ck.dir, 30)
    before = tel.REGISTRY.counter("ckpt.quarantine").value
    step, arrays = ck.load_arrays()
    assert step == 20
    np.testing.assert_array_equal(arrays["a"],
                                  np.arange(20, dtype=np.int64))
    assert tel.REGISTRY.counter("ckpt.quarantine").value == before + 1
    names = sorted(os.listdir(ck.dir))
    assert "quarantine_step_0000000030" in names
    assert "step_0000000030" not in names


def test_explicit_step_integrity_error_names_problem(tmp_path):
    """Asking for exact bytes that fail verification must raise, not
    silently substitute another step."""
    ck = _save_steps(tmp_path)
    faults.flip_byte(ck.dir, 30)
    with pytest.raises(CheckpointIntegrityError, match="CRC32C"):
        ck.load_arrays(step=30)
    assert ck.all_steps() == [10, 20, 30]  # explicit: NOT quarantined


def test_verify_arrays_names_offending_key():
    a = {"good": np.arange(4), "bad": np.arange(8)}
    manifest = {"arrays": {k: integrity._array_record(v)
                           for k, v in a.items()}}
    a["bad"] = a["bad"] + 1
    problems = integrity.verify_arrays(a, manifest)
    assert len(problems) == 1 and "'bad'" in problems[0]
    assert integrity.verify_arrays(a, None) == []  # legacy: no manifest


def test_exhausted_checkpoints_raise_typed_error(tmp_path):
    ck = _save_steps(tmp_path, steps=(10,))
    faults.flip_byte(ck.dir, 10)
    with pytest.raises(CheckpointError, match="failed verification"):
        ck.load_arrays()
    with pytest.raises(CheckpointError, match="no checkpoint"):
        Checkpointer(str(tmp_path / "empty")).load_arrays()


# ---------------------------------------------------------------------------
# fault plans
# ---------------------------------------------------------------------------

def test_fault_plan_from_env(monkeypatch):
    monkeypatch.setenv("REPRO_FAULTS", '{"transient_dispatches": 2}')
    plan = faults.install_from_env()
    assert plan.transient_dispatches == 2
    assert faults.active_plan() is plan
    monkeypatch.setenv("REPRO_FAULTS", '{"bogus": 1}')
    with pytest.raises(FaultPlanError, match="unknown fault kind"):
        faults.install_from_env()
    monkeypatch.delenv("REPRO_FAULTS")
    faults.clear()
    assert faults.install_from_env() is None


@pytest.mark.parametrize("text,match", [
    ('{"transient_dispatches": 2', "malformed JSON"),
    ('[1, 2]', "must be a JSON object"),
    ('{"bogus": 1}', "unknown fault kind"),
    ('{"transient_dispatches": "two"}', "must be an integer"),
    ('{"transient_dispatches": true}', "must be an integer"),
    ('{"resident_oom": -1}', "must be >= 0"),
])
def test_fault_plan_failures_are_typed_and_diagnosable(text, match):
    """Every malformation is a FaultPlanError CARRYING the offending
    text -- a chaos job with a bad REPRO_FAULTS must fail loudly, not
    run faultless and pass vacuously."""
    with pytest.raises(FaultPlanError, match=match) as ei:
        faults.FaultPlan.from_json(text)
    assert ei.value.text == text
    assert repr(text) in str(ei.value)


# ---------------------------------------------------------------------------
# dispatch recovery: retry + demotion, bit-exact
# ---------------------------------------------------------------------------

def test_transient_retry_is_bit_exact(nosleep):
    ref = Session.open(_spec())
    ref.run(6)
    before = tel.REGISTRY.counter("resilience.retry").value
    s = Session.open(_spec())
    with faults.injected(faults.FaultPlan(transient_dispatches=2)) as p:
        s.run(6)
    assert p.fired == {"transient_dispatch": 2}
    assert tel.REGISTRY.counter("resilience.retry").value == before + 2
    assert s.state_digest() == ref.state_digest()


def test_retry_budget_exhausts(nosleep):
    s = Session.open(_spec())
    with faults.injected(faults.FaultPlan(transient_dispatches=99)):
        with pytest.raises(TransientDispatchError):
            s.run(4)
    # the default policy allows max_retries retries = 4 attempts
    assert faults.active_plan() is None  # fixture restores


def test_resident_oom_demotes_bit_exact():
    """A RESOURCE_EXHAUSTED launch demotes the (family, lattice) to the
    fallback tier, retries immediately, and the trajectory does not
    fork; a FRESH engine on the same lattice starts demoted too."""
    ref = Session.open(_spec("multispin_pallas"))
    assert ref.engine.resident_plan is not None
    ref.run(6)
    before = tel.REGISTRY.counter("resident.demote").value
    s = Session.open(_spec("multispin_pallas"))
    with faults.injected(faults.FaultPlan(resident_oom=1)) as p:
        s.run(6)
    assert p.fired == {"resident_oom": 1}
    assert s.engine.resident_plan is None
    assert s.state_digest() == ref.state_digest()
    assert tel.REGISTRY.counter("resident.demote").value == before + 1
    assert degrade.demotion_reason("multispin", 16, 32) is not None
    fresh = Session.open(_spec("multispin_pallas"))
    assert fresh.engine.resident_plan is None
    assert fresh.engine.resident_attrs["demoted"] is True
    assert "fallback" in fresh.engine.resident_attrs["reason"]


def test_ensemble_demotion_bit_exact():
    """The vmapped ensemble runner clears ITS jit cache on demotion
    (on_demote) so the retry re-traces the fallback tier."""
    batch = BatchSpec(temperatures=(2.0, 2.4))
    ref = Session.open(_spec("multispin_pallas", batch=batch))
    m_ref = ref.run(5)
    s = Session.open(_spec("multispin_pallas", batch=batch))
    with faults.injected(faults.FaultPlan(resident_oom=1)):
        m = s.run(5)
    np.testing.assert_array_equal(m, m_ref)
    assert s.state_digest() == ref.state_digest()


def test_simulated_oom_classifies_like_real():
    exc = SimulatedResourceExhausted()
    assert degrade.is_resident_oom(exc)
    assert not degrade.is_transient(exc)
    assert degrade.is_transient(TransientDispatchError("x"))
    assert degrade.is_transient(RuntimeError("UNAVAILABLE: queue"))


# ---------------------------------------------------------------------------
# supervisor: bit-exact resume across all three runner modes
# ---------------------------------------------------------------------------

def _stop_at(step):
    def hook(sup):
        if sup.session.step_count >= step:
            sup.request_stop()
    return hook


# key-based single (chunk-grid-sensitive), counter-based ensemble,
# sharded Philox -- one spec per Session runner mode
_MODE_SPECS = {
    "single": lambda: _spec("basic", n=16, m=16),
    "ensemble": lambda: _spec(batch=BatchSpec(temperatures=(2.0, 2.4))),
    "sharded": lambda: _spec("basic_philox", n=16, m=16,
                             mesh=MeshSpec((1, 1), ("data", "model"))),
}


@pytest.mark.parametrize("mode", sorted(_MODE_SPECS))
def test_supervised_resume_bit_exact(tmp_path, mode):
    """Interrupt at an arbitrary chunk, restore, continue: lattice and
    observables bit-for-bit vs an uninterrupted supervised run."""
    make = _MODE_SPECS[mode]
    ref = Supervisor(make(), str(tmp_path / "ref"), chunk=4,
                     every_sweeps=8).run(22)
    assert ref.completed and ref.status == "completed"

    d = str(tmp_path / "int")
    r1 = Supervisor(make(), d, chunk=4, every_sweeps=8,
                    on_chunk=_stop_at(12)).run(22)
    assert r1.status == "preempted" and r1.step_count == 12
    assert not r1.completed

    before = tel.REGISTRY.counter("resilience.resume").value
    sup2 = Supervisor(make(), d, chunk=4, every_sweeps=8)
    assert sup2.session.mode == mode
    assert sup2.resumed_from == 12
    assert tel.REGISTRY.counter("resilience.resume").value == before + 1
    r2 = sup2.run(22)
    assert r2.completed
    assert r2.digest == ref.digest
    # observables agree too, not just the digest
    ref_sess = Supervisor(make(), str(tmp_path / "ref"), chunk=4).session
    np.testing.assert_array_equal(
        np.asarray(sup2.session.full_lattice()),
        np.asarray(ref_sess.full_lattice()))
    np.testing.assert_array_equal(
        np.asarray(sup2.session.magnetization()),
        np.asarray(ref_sess.magnetization()))


@pytest.mark.parametrize("mode", sorted(_MODE_SPECS))
def test_supervised_resume_after_corruption(tmp_path, mode):
    """CRC-reject + fallback restore in every runner mode: the newest
    checkpoint is corrupted, resume falls back to the previous good
    step and still converges to the uninterrupted digest."""
    make = _MODE_SPECS[mode]
    ref = Supervisor(make(), str(tmp_path / "ref"), chunk=4).run(22)
    d = str(tmp_path / "chaos")
    r1 = Supervisor(make(), d, chunk=4, every_sweeps=4,
                    on_chunk=_stop_at(12)).run(22)
    assert r1.checkpoints_written[-2:] == [8, 12]
    faults.flip_byte(d, 12)
    sup = Supervisor(make(), d, chunk=4, every_sweeps=4)
    assert sup.resumed_from == 8
    assert sup.run(22).digest == ref.digest


def test_supervisor_rejects_spec_mismatch(tmp_path):
    d = str(tmp_path)
    Supervisor(_spec(seed=7), d, chunk=4, on_chunk=_stop_at(4)).run(8)
    with pytest.raises(SupervisorError, match="different spec"):
        Supervisor(_spec(seed=8), d, chunk=4)


def test_supervisor_requires_spec_or_checkpoint(tmp_path):
    with pytest.raises(SupervisorError, match="no spec"):
        Supervisor(None, str(tmp_path))


def test_supervisor_sigterm_checkpoints_and_resumes(tmp_path):
    """A real SIGTERM mid-run: the handler requests a stop, the loop
    checkpoints at the chunk boundary and reports preemption; rerunning
    resumes to the uninterrupted digest."""
    ref = Supervisor(_spec(), str(tmp_path / "ref"), chunk=4).run(12)
    d = str(tmp_path / "sig")

    def send_sigterm(sup):
        if sup.session.step_count == 4:
            os.kill(os.getpid(), signal.SIGTERM)

    r1 = Supervisor(_spec(), d, chunk=4,
                    on_chunk=send_sigterm).run(12)
    assert r1.status == "preempted"
    assert r1.stop_signal == signal.SIGTERM
    assert r1.checkpoints_written  # preemption persisted progress
    r2 = Supervisor(_spec(), d, chunk=4).run(12)
    assert r2.completed and r2.digest == ref.digest


def test_supervisor_resume_from_spec_in_checkpoint(tmp_path):
    """``Supervisor(None, dir)`` rebuilds the run entirely from the
    spec.json sidecar -- the CLI resume-without-flags path."""
    d = str(tmp_path)
    Supervisor(_spec(), d, chunk=4, on_chunk=_stop_at(4)).run(12)
    sup = Supervisor(None, d, chunk=4)
    assert sup.resumed_from == 4
    assert sup.session.spec.to_dict() == _spec().to_dict()
    assert sup.run(12).completed


def test_supervisor_zero_cadence_writes_no_periodic_steps(tmp_path):
    """Cadence off => no checkpoint I/O during the loop (the zero-
    hot-path-overhead contract the perf gate measures)."""
    d = str(tmp_path)
    res = Supervisor(_spec(), d, chunk=4).run(12)
    assert res.completed
    assert res.checkpoints_written == []  # fresh run, cadence off
    assert os.listdir(d) == []
