"""Resident-sweep tier (DESIGN.md S9): bit-exactness vs the
per-half-sweep oracles at several k and lattice sizes, the VMEM planner
fallback boundary (both sides), and the registry/measurement routing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bitplane as bp
from repro.core import lattice as lat
from repro.core import metropolis as metro
from repro.core import multispin as ms
from repro.core.sim import SimConfig, Simulation
from repro.kernels import resident
from repro.kernels.bitplane.resident import bitplane_sweeps_resident
from repro.kernels.multispin.resident import multispin_sweeps_resident
from repro.kernels.stencil.resident import stencil_sweeps_resident

SHAPES = [(16, 32), (32, 64)]
KS = [1, 3]
BETA = jnp.float32(1 / 2.2)


def _planes(n, m, key=0):
    full = lat.init_lattice(jax.random.PRNGKey(key), n, m)
    return lat.split_checkerboard(full)


# ---------------------------------------------------------------------------
# kernel-level bit-exactness: resident(k) == k x per-half-sweep oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,m", SHAPES)
@pytest.mark.parametrize("k", KS)
def test_stencil_resident_bitexact(n, m, k):
    b, w = _planes(n, m)
    out = stencil_sweeps_resident(b, w, BETA, n_sweeps=k, seed=9,
                                  start_offset=4, interpret=True)
    ref = metro.run_sweeps_philox(b, w, BETA, k, seed=9,
                                  start_offset=4)  # donates b, w
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(ref[0]))
    np.testing.assert_array_equal(np.asarray(out[1]), np.asarray(ref[1]))


@pytest.mark.parametrize("n,m", SHAPES)
@pytest.mark.parametrize("k", KS)
def test_multispin_resident_bitexact(n, m, k):
    bw, ww = ms.pack_lattice(*_planes(n, m, key=1))
    out = multispin_sweeps_resident(bw, ww, BETA, n_sweeps=k, seed=7,
                                    start_offset=2, interpret=True)
    ref = ms.run_sweeps_packed(bw, ww, BETA, k, seed=7,
                               start_offset=2)  # donates bw, ww
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(ref[0]))
    np.testing.assert_array_equal(np.asarray(out[1]), np.asarray(ref[1]))


@pytest.mark.parametrize("n,m", SHAPES)
@pytest.mark.parametrize("k", KS)
def test_bitplane_resident_bitexact(n, m, k):
    fulls = jnp.stack([lat.init_lattice(
        jax.random.fold_in(jax.random.PRNGKey(2), r), n, m)
        for r in range(bp.N_REPLICAS)])
    bw, ww = bp.pack_lattices(fulls)
    out = bitplane_sweeps_resident(bw, ww, BETA, n_sweeps=k, seed=5,
                                   start_offset=6, interpret=True)
    ref = bp.run_sweeps_bitplane(bw, ww, BETA, k, seed=5,
                                 start_offset=6)  # donates bw, ww
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(ref[0]))
    np.testing.assert_array_equal(np.asarray(out[1]), np.asarray(ref[1]))


def test_resident_64bit_seed_matches_oracle():
    """Full 64-bit python seeds reach both Philox key lanes (seed_keys)."""
    b, w = _planes(16, 32, key=3)
    big = (0xABCD << 32) | 0x1234
    out = stencil_sweeps_resident(b, w, BETA, n_sweeps=2, seed=big,
                                  interpret=True)
    ref = metro.run_sweeps_philox(b, w, BETA, 2, seed=big)
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(ref[0]))


# ---------------------------------------------------------------------------
# VMEM planner: fit decision and the fallback boundary
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", ["stencil", "multispin", "bitplane"])
def test_planner_boundary_both_sides(family):
    """max_square_lattice is the boundary: n fits, n+2 falls back."""
    n = resident.max_square_lattice(family)
    assert n > 0 and n % 2 == 0
    assert resident.plan_resident(family, n, n) is not None
    assert resident.plan_resident(family, n + 2, n + 2) is None
    # the plan carries the model numbers it was approved under
    plan = resident.plan_resident(family, n, n)
    assert plan.working_set_bytes <= plan.budget_bytes
    assert plan.plane_bytes == resident.plane_bytes(family, n, n)


def test_planner_rejects_unknown_family():
    with pytest.raises(ValueError, match="unknown resident family"):
        resident.plan_resident("nope", 16, 16)


@pytest.mark.parametrize("engine,family,fit_n,spill_n", [
    ("stencil_pallas", "stencil", 32, 64),
    ("bitplane_pallas", "bitplane", 16, 32),
])
def test_engine_fallback_boundary_bitexact(monkeypatch, engine, family,
                                           fit_n, spill_n):
    """A lattice on each side of the (budget-moved) fallback boundary:
    the fitting size routes resident, the spilling size falls back to
    the per-half-sweep kernels -- and BOTH produce the oracle
    trajectory, so the tier decision is unobservable in the physics."""
    budget = resident.working_set_bytes(family, fit_n, fit_n)
    assert budget < resident.working_set_bytes(family, spill_n, spill_n)
    monkeypatch.setattr(resident, "VMEM_BUDGET_BYTES", budget)

    oracle = {"stencil_pallas": "basic_philox",
              "bitplane_pallas": "bitplane"}[engine]
    for n, expect_resident in ((fit_n, True), (spill_n, False)):
        cfg = dict(n=n, m=n, temperature=2.2, seed=7)
        sim = Simulation(SimConfig(engine=engine, **cfg))
        assert (sim.engine.resident_plan is not None) == expect_resident, n
        ref = Simulation(SimConfig(engine=oracle, **cfg))
        sim.run(3)
        ref.run(3)
        np.testing.assert_array_equal(np.asarray(sim.full_lattice()),
                                      np.asarray(ref.full_lattice()),
                                      err_msg=f"n={n}")


# ---------------------------------------------------------------------------
# registry / measurement routing
# ---------------------------------------------------------------------------

def test_multispin_pallas_engine_matches_oracle_engine():
    cfg = dict(n=32, m=32, temperature=2.2, seed=7)
    a = Simulation(SimConfig(engine="multispin", **cfg))
    b = Simulation(SimConfig(engine="multispin_pallas", **cfg))
    assert b.engine.resident_plan is not None
    a.run(5)
    b.run(5)
    np.testing.assert_array_equal(np.asarray(a.full_lattice()),
                                  np.asarray(b.full_lattice()))


def test_measure_blocks_map_to_resident_dispatches():
    """measure_every-sized sweep blocks through measure_scan are
    bit-identical between the resident engine and its pure-jnp oracle:
    each interval is one k-sweep resident call (k = sweeps_between)."""
    from repro.analysis.measure import MeasurementPlan
    plan = MeasurementPlan(n_measure=4, sweeps_between=2, thermalize=2)
    cfg = dict(n=16, m=16, temperature=2.2, seed=7)
    res = Simulation(SimConfig(engine="multispin_pallas", **cfg))
    ref = Simulation(SimConfig(engine="multispin", **cfg))
    traj_res = res.measure(plan)
    traj_ref = ref.measure(plan)
    for f in plan.fields:
        np.testing.assert_array_equal(traj_res[f], traj_ref[f], err_msg=f)


def test_ensemble_vmaps_resident_tier():
    """Ensemble members vmapped through the resident kernel follow
    their Simulation trajectories exactly (DESIGN.md S3 contract)."""
    from repro.core.ensemble import Ensemble
    temps, seeds = [1.8, 2.5], [3, 4]
    ens = Ensemble(16, 16, temps, seeds, engine="multispin_pallas")
    assert ens.engine.resident_plan is not None
    ens.run(3)
    lattices = ens.full_lattices()
    for i, (temp, seed) in enumerate(zip(temps, seeds)):
        sim = Simulation(SimConfig(n=16, m=16, temperature=temp,
                                   seed=seed, engine="multispin_pallas"))
        sim.run(3)
        np.testing.assert_array_equal(np.asarray(sim.full_lattice()),
                                      lattices[i], err_msg=f"member {i}")


def test_zero_sweeps_noop_on_every_tier():
    """n_sweeps=0 routes to the fallback fori_loop (which no-ops), so
    the zero-sweep edge behaves identically on resident-capable and
    plain engines."""
    for engine in ("stencil_pallas", "multispin_pallas", "basic_philox"):
        sim = Simulation(SimConfig(n=16, m=16, temperature=2.2, seed=7,
                                   engine=engine))
        before = np.asarray(sim.full_lattice())
        sim.run(0)
        np.testing.assert_array_equal(
            before, np.asarray(sim.full_lattice()), err_msg=engine)


# ---------------------------------------------------------------------------
# H1.5: int8 neighbor sums leave flip decisions bit-identical
# ---------------------------------------------------------------------------

def test_int8_neighbor_sums_bitidentical_flips():
    b, w = _planes(32, 64, key=5)
    nn = metro.neighbor_sums(w, is_black=True)
    assert nn.dtype == jnp.int8
    # int32-widened reference of the same accept math
    u = jax.random.uniform(jax.random.PRNGKey(6), b.shape)
    out = metro.update_color(b, w, u, BETA, is_black=True)
    t32 = b.astype(jnp.int32)
    acc32 = jnp.exp(-2.0 * BETA * nn.astype(jnp.int32).astype(jnp.float32)
                    * t32.astype(jnp.float32))
    ref = jnp.where(u < acc32, -t32, t32).astype(b.dtype)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
