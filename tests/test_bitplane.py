"""Bitplane engine (32 replicas/word, DESIGN.md S8): packing properties,
carry-save adder, kernel/oracle bit-exactness, shared-draw budget,
per-replica measurement flow, and the statistical physics cross-check."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import bitplane as bp
from repro.core import lattice as lat
from repro.core import metropolis as metro
from repro.core import multispin as ms
from repro.core import rng as crng
from repro.core.sim import SimConfig, Simulation
from repro.kernels.bitplane.bitplane import bitplane_update
from repro.kernels.bitplane.ops import run_sweeps_bitplane_kernel
from repro.kernels.bitplane.ref import bitplane_update_ref

dims = st.tuples(st.integers(1, 8).map(lambda x: 2 * x),
                 st.integers(1, 8).map(lambda x: 4 * x))


def _replica_stack(key, n, m, n_rep=bp.N_REPLICAS):
    keys = jax.vmap(lambda r: jax.random.fold_in(key, r))(
        jnp.arange(n_rep))
    return jax.vmap(lambda k: lat.init_lattice(k, n, m))(keys)


# ---------------------------------------------------------------------------
# packing
# ---------------------------------------------------------------------------


@given(dims=dims, seed=st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_pack_unpack_roundtrip(dims, seed):
    n, c = dims
    rng = np.random.default_rng(seed)
    planes = rng.integers(0, 2, size=(bp.N_REPLICAS, n, c)).astype(np.uint32)
    words = bp.pack_replicas(jnp.asarray(planes))
    assert words.shape == (n, c) and words.dtype == jnp.uint32
    np.testing.assert_array_equal(np.asarray(bp.unpack_replicas(words)),
                                  planes)
    # and words -> planes -> words
    w2 = bp.pack_replicas(bp.unpack_replicas(words))
    np.testing.assert_array_equal(np.asarray(w2), np.asarray(words))


def test_pack_lattices_roundtrip_and_replica_view():
    fulls = _replica_stack(jax.random.PRNGKey(0), 16, 32)
    bw, ww = bp.pack_lattices(fulls)
    np.testing.assert_array_equal(np.asarray(bp.unpack_lattices(bw, ww)),
                                  np.asarray(fulls))
    for r in (0, 1, 31):
        np.testing.assert_array_equal(
            np.asarray(bp.replica_lattice(bw, ww, r=r)),
            np.asarray(fulls[r]), err_msg=f"replica {r}")


# ---------------------------------------------------------------------------
# carry-save adder
# ---------------------------------------------------------------------------


def test_carry_save_adder_matches_integer_sums():
    """n0 + 2*n1 + 4*n2 equals the per-replica integer sum of the four
    input bits, for every replica lane of random words."""
    rng = np.random.default_rng(1)
    words = rng.integers(0, 2**32, size=(4, 8, 16), dtype=np.uint64)
    a, b, c, d = (jnp.asarray(w.astype(np.uint32)) for w in words)
    n0, n1, n2 = (np.asarray(x) for x in bp.bit_count_neighbors(a, b, c, d))
    bits = [(words[i].astype(np.uint32)[None] >> np.arange(32)[:, None,
                                                             None]) & 1
            for i in range(4)]
    expect = sum(bits)                       # (32, 8, 16) in 0..4
    got = (((n0[None] >> np.arange(32)[:, None, None]) & 1)
           + 2 * ((n1[None] >> np.arange(32)[:, None, None]) & 1)
           + 4 * ((n2[None] >> np.arange(32)[:, None, None]) & 1))
    np.testing.assert_array_equal(got, expect)


def test_neighbor_counts_match_basic_engine_per_replica():
    """The bit-sliced neighbor count of every replica equals the basic
    engine's +-1 neighbor sums on that replica's plane."""
    fulls = _replica_stack(jax.random.PRNGKey(2), 8, 16)
    bw, ww = bp.pack_lattices(fulls)
    n0, n1, n2 = bp.neighbor_counts(ww, is_black=True)
    for r in (0, 5, 31):
        _, white = lat.split_checkerboard(fulls[r])
        nn_pm = np.asarray(metro.neighbor_sums(white, is_black=True))
        count = (np.asarray((n0 >> r) & 1).astype(np.int32)
                 + 2 * np.asarray((n1 >> r) & 1).astype(np.int32)
                 + 4 * np.asarray((n2 >> r) & 1).astype(np.int32))
        np.testing.assert_array_equal(2 * count - 4, nn_pm,
                                      err_msg=f"replica {r}")


# ---------------------------------------------------------------------------
# kernel vs oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,m", [(16, 32), (64, 64)])
@pytest.mark.parametrize("is_black", [True, False])
@pytest.mark.parametrize("block_rows", [8, 16])
def test_bitplane_kernel_bitexact(n, m, is_black, block_rows):
    fulls = _replica_stack(jax.random.PRNGKey(3), n, m)
    bw, ww = bp.pack_lattices(fulls)
    t, op = (bw, ww) if is_black else (ww, bw)
    beta = jnp.float32(1 / 2.3)
    out_k = bitplane_update(t, op, beta, is_black=is_black, seed=11,
                            offset=3, block_rows=block_rows, interpret=True)
    out_r = bitplane_update_ref(t, op, beta, is_black=is_black, seed=11,
                                offset=3)
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))


def test_bitplane_kernel_sweep_wrapper_matches_core():
    fulls = _replica_stack(jax.random.PRNGKey(4), 16, 32)
    bw, ww = bp.pack_lattices(fulls)
    beta = jnp.float32(1 / 2.0)
    # both wrappers donate their inputs: hand each its own copy
    bk, wk = run_sweeps_bitplane_kernel(bw.copy(), ww.copy(), beta, 5,
                                        seed=2, block_rows=8,
                                        interpret=True)
    br, wr = bp.run_sweeps_bitplane(bw, ww, beta, 5, seed=2)
    np.testing.assert_array_equal(np.asarray(bk), np.asarray(br))
    np.testing.assert_array_equal(np.asarray(wk), np.asarray(wr))


# ---------------------------------------------------------------------------
# randomness budget: ONE shared draw per site
# ---------------------------------------------------------------------------


def test_philox_draw_budget_one_per_word(monkeypatch):
    """The bitplane half-sweep consumes exactly ONE uint32 per site word
    (1/32 per replica-spin); nibble multispin consumes 8 per word (1 per
    spin) -- the 32x draw reduction, counted at the philox4x32 seam."""
    drawn = {"n": 0}
    real = crng.philox4x32

    def counting(c0, c1, c2, c3, k0, k1, rounds=10):
        drawn["n"] += 4 * int(np.prod(jnp.shape(c2)))
        return real(c0, c1, c2, c3, k0, k1, rounds)

    monkeypatch.setattr(crng, "philox4x32", counting)
    beta = jnp.float32(1 / 2.2)

    n, m = 16, 32
    fulls = _replica_stack(jax.random.PRNGKey(5), n, m)
    bw, ww = bp.pack_lattices(fulls)
    sites = n * (m // 2)
    drawn["n"] = 0
    bp.update_color_bitplane(bw, ww, beta, True, 3, jnp.uint32(0))
    assert drawn["n"] == sites          # 1 draw/word = 1/32 per replica
    bitplane_per_replica_spin = drawn["n"] / (bp.N_REPLICAS * sites)

    b, w = lat.split_checkerboard(lat.init_lattice(jax.random.PRNGKey(6),
                                                   n, m))
    pbw, pww = ms.pack_lattice(b, w)
    words = n * (m // 2) // lat.SPINS_PER_WORD
    drawn["n"] = 0
    ms.update_color_packed(pbw, pww, beta, True, 3, jnp.uint32(0))
    assert drawn["n"] == 8 * words      # 8 draws per 8-spin word
    multispin_per_spin = drawn["n"] / (lat.SPINS_PER_WORD * words)

    assert multispin_per_spin / bitplane_per_replica_spin == 32.0


# ---------------------------------------------------------------------------
# measurement flow: per-replica trajectories through measure_scan
# ---------------------------------------------------------------------------


def test_trajectory_is_per_replica_and_scan_matches_loop():
    """One simulation yields 32 per-replica magnetization series; the
    fused scan reproduces the stateful python loop bit-for-bit."""
    from repro.analysis import MeasurementPlan, jackknife
    cfg = dict(n=16, m=16, temperature=2.2, seed=7, engine="bitplane")
    a = Simulation(SimConfig(**cfg))
    a.run(4)
    legacy = []
    for _ in range(6):
        a.run(2)
        legacy.append(np.asarray(
            a.engine.observables(a.state, jnp.float32(1 / 2.2))["m"]))
    legacy = np.stack(legacy).astype(np.float32)

    b = Simulation(SimConfig(**cfg))
    traj = b.measure(MeasurementPlan(6, 2, thermalize=4, fields=("m", "e")))
    assert traj["m"].shape == traj["e"].shape == (6, bp.N_REPLICAS)
    np.testing.assert_array_equal(traj["m"], legacy)
    np.testing.assert_array_equal(np.asarray(a.state[0]),
                                  np.asarray(b.state[0]))
    # per-replica series feed the estimators unchanged
    ests = [jackknife(np.abs(traj["m"][:, r]), n_blocks=3)
            for r in range(bp.N_REPLICAS)]
    assert all(err >= 0 for _, err in ests)


def test_bitplane_pallas_engine_matches_oracle_engine():
    cfg = dict(n=32, m=32, temperature=2.2, seed=7)
    a = Simulation(SimConfig(engine="bitplane", **cfg))
    b = Simulation(SimConfig(engine="bitplane_pallas", **cfg))
    a.run(5)
    b.run(5)
    np.testing.assert_array_equal(np.asarray(a.state[0]),
                                  np.asarray(b.state[0]))
    np.testing.assert_array_equal(np.asarray(a.state[1]),
                                  np.asarray(b.state[1]))


def test_ensemble_batched_measure_keeps_replica_axis():
    from repro.analysis import MeasurementPlan
    from repro.core.ensemble import Ensemble
    temps, seeds = [1.8, 2.5], [3, 4]
    ens = Ensemble(16, 16, temps, seeds, engine="bitplane")
    traj = ens.measure(MeasurementPlan(4, 2, thermalize=2))
    assert traj["m"].shape == (4, 2, bp.N_REPLICAS)
    # member i reproduces its single Simulation (replica streams and all)
    for i, (T, s) in enumerate(zip(temps, seeds)):
        sim = Simulation(SimConfig(n=16, m=16, temperature=T, seed=s,
                                   engine="bitplane"))
        t1 = sim.measure(MeasurementPlan(4, 2, thermalize=2))
        np.testing.assert_array_equal(t1["m"], traj["m"][:, i],
                                      err_msg=f"member {i}")


# ---------------------------------------------------------------------------
# physics: replica-averaged observables vs basic_philox
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("temp,ordered", [(2.0, True), (2.5, False)])
def test_statistical_cross_check_vs_basic_philox(temp, ordered):
    """Replica-averaged <|m|> and <e> from ONE bitplane simulation agree
    with an independent basic_philox chain within jackknife error bars.

    The replica average is taken per time-sample FIRST (series y_t =
    mean_r x_{r,t}) and the error bar comes from a block jackknife over
    time -- the correct treatment under the shared-randoms caveat
    (replicas are correlated at equal (site, step), so they must not be
    counted as 32 independent measurements; see DESIGN.md S8)."""
    from repro.analysis import MeasurementPlan, jackknife
    p_up = 1.0 if ordered else 0.5
    plan = MeasurementPlan(n_measure=64, sweeps_between=2, thermalize=300)

    sim_b = Simulation(SimConfig(n=32, m=32, temperature=temp, seed=5,
                                 engine="bitplane", init_p_up=p_up))
    traj_b = sim_b.measure(plan)
    sim_p = Simulation(SimConfig(n=32, m=32, temperature=temp, seed=6,
                                 engine="basic_philox", init_p_up=p_up))
    traj_p = sim_p.measure(plan)

    for field, transform in (("m", np.abs), ("e", lambda x: x)):
        series_b = transform(traj_b[field]).mean(axis=1)  # replica-avg
        series_p = transform(traj_p[field])
        est_b, err_b = jackknife(series_b)
        est_p, err_p = jackknife(series_p)
        sigma = np.hypot(err_b, err_p)
        assert abs(est_b - est_p) < 4.0 * sigma + 0.02, (
            field, temp, est_b, err_b, est_p, err_p)


def _distinct_replicas(state):
    black, white = (np.asarray(p) for p in state)
    return len({(((black >> r) & 1).tobytes(), ((white >> r) & 1).tobytes())
                for r in range(bp.N_REPLICAS)})


def test_replica_coalescence_regimes():
    """Characterizes the shared-randoms coupling (DESIGN.md S8): above
    T_c the 32 chains stay distinct (the replica multiplier is real);
    below T_c same-well replicas coalesce to bit-identical lattices (at
    most the +-m pair plus stragglers survives); identical starts are
    clones forever."""
    hot = Simulation(SimConfig(n=32, m=32, temperature=2.5, seed=11,
                               engine="bitplane"))
    hot.run(400)
    assert _distinct_replicas(hot.state) == bp.N_REPLICAS

    cold = Simulation(SimConfig(n=32, m=32, temperature=2.0, seed=11,
                                engine="bitplane"))
    cold.run(400)
    assert _distinct_replicas(cold.state) <= 4

    clones = Simulation(SimConfig(n=32, m=32, temperature=2.5, seed=11,
                                  engine="bitplane", init_p_up=1.0))
    assert _distinct_replicas(clones.state) == 1
    clones.run(50)
    assert _distinct_replicas(clones.state) == 1


# ---------------------------------------------------------------------------
# distributed: 8-host-device mesh reproduces the single-device trajectory
# ---------------------------------------------------------------------------

_DIST_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np, json
    from repro.core import bitplane as bp, distributed as dist, \\
        lattice as lat
    from repro.launch.mesh import make_mesh

    N, M = 32, 32
    key = jax.random.PRNGKey(7)
    keys = jax.vmap(lambda r: jax.random.fold_in(key, r))(jnp.arange(32))
    fulls = jax.vmap(lambda k: lat.init_lattice(k, N, M))(keys)
    bw, ww = bp.pack_lattices(fulls)
    beta = jnp.float32(1 / 2.0)

    out = {}
    results = {}
    for shape, axes in [((2, 2, 2), ("pod", "data", "model")),
                        ((4, 2), ("data", "model")),
                        ((1, 8), ("data", "model"))]:
        mesh = make_mesh(shape, axes)
        step, sh = dist.make_bitplane_ising_step(mesh, n=N, m=M, seed=5,
                                                 n_sweeps=3)
        b1, w1 = step(jax.device_put(bw, sh), jax.device_put(ww, sh),
                      beta, jnp.uint32(0))
        results["x".join(map(str, shape))] = (np.asarray(b1),
                                              np.asarray(w1))

    # reference last: run_sweeps_bitplane donates bw/ww
    br, wr = bp.run_sweeps_bitplane(bw, ww, beta, 3, seed=5)
    br, wr = np.asarray(br), np.asarray(wr)
    for k, (b1, w1) in results.items():
        out["match_" + k] = bool((b1 == br).all() and (w1 == wr).all())
    print(json.dumps(out))
""")


@pytest.fixture(scope="module")
def bitplane_dist_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _DIST_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


def test_distributed_bitplane_bitexact_all_meshes(bitplane_dist_results):
    """Global (site//4, site%4) Philox keying makes the halo-exchanged
    step independent of the device grid: every mesh reproduces the
    single-device bitplane trajectory bit-for-bit."""
    assert bitplane_dist_results["match_2x2x2"]
    assert bitplane_dist_results["match_4x2"]
    assert bitplane_dist_results["match_1x8"]
